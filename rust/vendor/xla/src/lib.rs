//! Stub of the `xla-rs` API surface that `chunk_attention`'s PJRT runtime
//! compiles against (only with `--features pjrt`). Every operation that
//! would need the real XLA/PJRT runtime returns [`Error::Unavailable`];
//! literal construction and host-side reshapes work, so shape plumbing is
//! still exercised. Swap this path dependency for the real `xla` crate to
//! execute the AOT artifacts.

use std::fmt;

/// Stub error: either "this build has no XLA runtime" or a host-side
/// literal-shape problem.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
    Shape(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "{what}: xla stub (build with the real xla crate to run PJRT)")
            }
            Error::Shape(msg) => write!(f, "shape error: {msg}"),
        }
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types literals can hold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    I32,
}

/// Marker for native element types.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn to_f32(self) -> f32;
    fn from_f32(x: f32) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn to_f32(self) -> f32 {
        self
    }
    fn from_f32(x: f32) -> Self {
        x
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::I32;
    fn to_f32(self) -> f32 {
        self as f32
    }
    fn from_f32(x: f32) -> Self {
        x as i32
    }
}

/// Host literal: flat f32 payload plus shape and element type.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
    ty: ElementType,
}

impl Literal {
    /// Rank-1 literal from a flat slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            data: data.iter().map(|&x| x.to_f32()).collect(),
            dims: vec![data.len() as i64],
            ty: T::TY,
        }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal { data: vec![x.to_f32()], dims: Vec::new(), ty: T::TY }
    }

    /// Reshape without moving data; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec(), ty: self.ty })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(Error::Shape(format!("element type mismatch: literal is {:?}", self.ty)));
        }
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }

    /// Decompose a tuple literal. The stub never produces tuples.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("to_tuple"))
    }
}

/// Parsed HLO module (opaque in the stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation handle (opaque in the stub).
#[derive(Debug, Clone)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Device-side buffer handle.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert!(l.reshape(&[3]).is_err());
        let i = Literal::vec1(&[7i32, 8]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, 8]);
        assert!(i.to_vec::<f32>().is_err());
    }

    #[test]
    fn runtime_entry_points_are_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
