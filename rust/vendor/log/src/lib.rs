//! Offline shim of the `log` facade: the [`Log`] trait, level types, the
//! global logger registry, and the five level macros. API-compatible with
//! the subset `chunk_attention::util::logger` and the PJRT runtime use; the
//! container building this repo has no crates.io access.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single record, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Global maximum level: like [`Level`] plus `Off`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a record: level and target (module path by default).
#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus pre-formatted arguments.
#[derive(Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Returned by [`set_logger`] if a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

/// Install the global logger; fails if one is already set.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// The global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if level <= max_level() {
        if let Some(logger) = LOGGER.get() {
            let record = Record { metadata: Metadata { level, target }, args };
            if logger.enabled(&record.metadata) {
                logger.log(&record);
            }
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, ::std::module_path!(), ::std::format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= max_level()
        }
        fn log(&self, record: &Record) {
            let _ = format!("{}", record.args());
            HITS.fetch_add(1, Ordering::Relaxed);
        }
        fn flush(&self) {}
    }

    #[test]
    fn levels_compare_against_filters() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(LevelFilter::Trace >= Level::Trace);
    }

    #[test]
    fn macros_route_through_global_logger() {
        static COUNTER: Counter = Counter;
        let _ = set_logger(&COUNTER);
        set_max_level(LevelFilter::Info);
        info!("hello {}", 42);
        debug!("filtered out");
        assert!(HITS.load(Ordering::Relaxed) >= 1);
    }
}
