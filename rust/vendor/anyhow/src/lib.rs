//! Offline shim of the `anyhow` API subset used by this workspace: an opaque
//! string-backed [`Error`], the [`Result`] alias, and the `anyhow!` /
//! `bail!` / `ensure!` macros. The container building this repo has no
//! crates.io access, so the real crate is replaced by this message-only
//! implementation (no backtraces, no downcasting).

use std::fmt;

/// Opaque error carrying a rendered message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Debug prints the message itself so `fn main() -> anyhow::Result<()>`
// failures stay readable.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that keeps this blanket conversion coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macro_forms() {
        fn inner(x: usize) -> crate::Result<usize> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                crate::bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert!(inner(11).unwrap_err().to_string().contains("too big"));
        assert!(inner(5).unwrap_err().to_string().contains("five"));
        let from_string: crate::Error = crate::anyhow!(String::from("owned"));
        assert_eq!(from_string.to_string(), "owned");
    }

    #[test]
    fn std_errors_convert() {
        fn io_op() -> crate::Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io_op().is_err());
    }
}
