//! Figure 3: decode token rate as completion length n_c grows and
//! sequences diverge from the shared prefix (n_s = n_p shared tokens).
//!
//! Methodology: sequences are advanced token by token exactly as decoding
//! would; per-step latency is sampled at checkpoints and the cumulative
//! token rate at n_c is computed by trapezoidal integration of the sampled
//! step latencies (full decode at every point would take hours on one
//! core; the integrand is smooth in n_c).

use chunk_attention::coordinator::{KernelBench, MicroConfig};
use chunk_attention::perf_model::AttentionImpl;
use chunk_attention::util::bench::{print_table, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("fig3_completion_sweep");
    let mode = suite.mode();
    let (heads, batch, ns) = mode.pick((4, 16, 1024), (32, 32, 2048));
    let checkpoints: Vec<usize> = mode.pick(vec![0, 128, 256, 512, 1024], vec![0, 256, 512, 1024, 1536, 2048]);
    let impls = [
        AttentionImpl::Naive,
        AttentionImpl::PagedAttn,
        AttentionImpl::PagedAttnShared,
        AttentionImpl::ChunkAttn,
    ];

    // step_lat[impl][checkpoint] -> µs per decode step.
    let mut step_lat = vec![vec![0.0f64; checkpoints.len()]; impls.len()];
    for (ii, &imp) in impls.iter().enumerate() {
        let mut cfg = MicroConfig::paper(batch, ns, ns);
        cfg.heads = heads;
        cfg.max_new_tokens = *checkpoints.last().unwrap() + 8;
        let mut kb = KernelBench::new(cfg, imp);
        for (ci, &nc) in checkpoints.iter().enumerate() {
            while kb.decoded() < nc {
                kb.append_round();
            }
            suite.measure(
                &format!("{}@nc{nc}", imp.label()),
                &[("impl", imp.label().to_string()), ("nc", nc.to_string())],
                Some("tok/s"),
                || kb.decode_step(),
            );
            step_lat[ii][ci] = suite.rows().last().unwrap().stats.mean();
        }
    }

    // Cumulative token rate at each checkpoint via trapezoid integration.
    let mut table = Vec::new();
    for (ci, &nc) in checkpoints.iter().enumerate().skip(1) {
        let mut row = vec![nc.to_string()];
        let mut rates = Vec::new();
        for (ii, _) in impls.iter().enumerate() {
            let mut total_us = 0.0;
            for j in 1..=ci {
                let dt = (checkpoints[j] - checkpoints[j - 1]) as f64;
                total_us += dt * (step_lat[ii][j] + step_lat[ii][j - 1]) / 2.0;
            }
            let toks = (nc * batch) as f64;
            let rate = toks / (total_us / 1e6);
            rates.push(rate);
            row.push(if rate >= 10_000.0 { format!("{:.0}K", rate / 1e3) } else { format!("{rate:.0}") });
        }
        let chunk = *rates.last().unwrap();
        row.push(format!("{:.2}x", chunk / rates[1])); // vs PagedAttn
        table.push((row, String::new()));
    }
    print_table(
        &format!(
            "Figure 3 — cumulative decode token rate vs n_c, n_s={ns}, b={batch}, h={heads} \
             (paper @A100: ChunkAttn/PagedAttn 3.6x at nc=512 -> 2.3x at nc=2048)"
        ),
        &["nc", "Naive", "PagedAttn", "PagedAttn*", "ChunkAttn", "Chunk/Paged"],
        &table,
    );
    suite.finish();
}
