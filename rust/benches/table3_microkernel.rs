//! Table 3: decode self-attention latency across the six kernel
//! implementations for the (n_p, n_s) grid, measured on this host's memory
//! hierarchy (see DESIGN.md §2 for why CPU cache locality reproduces the
//! A100 shape).
//!
//! Quick mode (default): h=4, b=16 — minutes. Full mode
//! (`CHUNK_ATTN_BENCH_MODE=full`): the paper's h=32, b=32, c=64, d=128.

use chunk_attention::attention::{
    tpp_attention, tpp_attention_2d, Queries, Tpp2dScratch, TppScratch,
};
use chunk_attention::coordinator::{KernelBench, MicroConfig};
use chunk_attention::kvcache::{KvDtype, PrefixTree, SeqId};
use chunk_attention::perf_model::AttentionImpl;
use chunk_attention::util::bench::{print_table, BenchSuite};
use chunk_attention::util::json::Json;
use chunk_attention::util::rng::Pcg64;
use chunk_attention::util::threadpool::ThreadPool;
use chunk_attention::util::{simd, threadpool};

fn main() {
    let mut suite = BenchSuite::new("table3_microkernel");
    let mode = suite.mode();
    let (heads, batch) = mode.pick((4, 16), (32, 32));
    let grid: Vec<(usize, usize)> = vec![
        (1024, 0),
        (1024, 512),
        (1024, 768),
        (1024, 1024),
        (2048, 0),
        (2048, 1024),
        (2048, 1536),
        (2048, 2048),
        (4096, 0),
        (4096, 2048),
        (4096, 3072),
        (4096, 4096),
    ];

    let mut table: Vec<(Vec<String>, String)> = Vec::new();
    for &(np, ns) in &grid {
        let mut row = vec![np.to_string(), ns.to_string()];
        let mut chunk_lat = 0.0f64;
        let mut naive_lat = 0.0f64;
        for imp in AttentionImpl::ALL {
            let mut cfg = MicroConfig::paper(batch, np, ns);
            cfg.heads = heads;
            cfg.max_new_tokens = 4;
            let mut kb = KernelBench::new(cfg, imp);
            let id = format!("np{np}/ns{ns}/{}", imp.label());
            suite.measure(&id, &[("np", np.to_string()), ("ns", ns.to_string()), ("impl", imp.label().to_string())], Some("tok/s"), || kb.decode_step());
            let us = suite.rows().last().unwrap().stats.mean();
            if imp == AttentionImpl::ChunkAttn {
                chunk_lat = us;
            }
            if imp == AttentionImpl::Naive {
                naive_lat = us;
            }
            row.push(format!("{us:.0}"));
        }
        row.push(format!("{:.2}x", naive_lat / chunk_lat));
        table.push((row, String::new()));
    }

    print_table(
        &format!(
            "Table 3 — decode attention latency (µs), b={batch}, h={heads}, d=128, c=64 \
             (paper @A100: Naive/ChunkAttn = 6.6x at np=ns=4096, ~1.0x at ns=0)"
        ),
        &["np", "ns", "Naive", "xformers", "FlashAttn", "PagedAttn", "PagedAttn*", "ChunkAttn", "Naive/Chunk"],
        &table,
    );

    two_d_vs_head_only(&mut suite);
    dtype_sweep(&mut suite);
    emit_kernel_json(&mut suite);
    suite.finish();
}

/// Machine-readable perf record at the acceptance shape (h=8, d=128, c=64,
/// b=32, 1024-token shared prefix, ChunkAttn 2D schedule), written to
/// `BENCH_kernel.json` so the kernel-perf trajectory is comparable across
/// PRs: shape, which ISA path actually ran, thread count, ns/step and
/// bytes/step.
fn emit_kernel_json(suite: &mut BenchSuite) {
    let (heads, batch, np, ns) = (8usize, 32usize, 1024usize, 1024usize);
    let mut cfg = MicroConfig::paper(batch, np, ns);
    cfg.heads = heads;
    cfg.max_new_tokens = 4;
    let chunk = cfg.chunk_size;
    let head_dim = cfg.head_dim;
    let mut kb = KernelBench::new(cfg, AttentionImpl::ChunkAttn);
    suite.measure(
        "kernel_json/chunk_attn",
        &[("isa", simd::active().label().to_string()), ("np", np.to_string()), ("ns", ns.to_string())],
        Some("tok/s"),
        || kb.decode_step(),
    );
    let step_us = suite.rows().last().unwrap().stats.mean();

    let mut shape = Json::obj();
    shape
        .set("heads", heads)
        .set("head_dim", head_dim)
        .set("chunk_size", chunk)
        .set("batch", batch)
        .set("prefix_tokens", np)
        .set("suffix_tokens", ns);
    let mut doc = Json::obj();
    doc.set("bench", "table3_microkernel")
        .set("impl", "chunk_attn_2d")
        .set("shape", shape)
        .set("isa", simd::active().label())
        .set("simd_env", simd::env_request())
        .set("threads", kb.threads())
        .set("affinity", threadpool::affinity_mode())
        .set("ns_per_step", step_us * 1000.0)
        .set("ns_per_token", step_us * 1000.0 / batch as f64)
        .set("kv_bytes_per_step", kb.kv_bytes())
        .set("unit_note", "ns_per_step = one batched decode step; kv_bytes_per_step = resident KV streamed by the chunk-first phase");
    let path = "BENCH_kernel.json";
    std::fs::write(path, doc.pretty()).expect("write BENCH_kernel.json");
    println!("wrote {path}");
}

/// KV storage dtype at the acceptance shape (h=8, d=128, c=64, b=32,
/// 1024-token fully shared prefix): the chunk-first phase is
/// bandwidth-bound on the streamed `c×d` K/V blocks, so f16 storage halves
/// the bytes per step — acceptance requires f16 no slower than f32 here —
/// and always halves the resident KV bytes.
fn dtype_sweep(suite: &mut BenchSuite) {
    let (heads, batch, np, ns) = (8usize, 32usize, 1024usize, 1024usize);
    let mut table = Vec::new();
    let mut f32_us = 0.0f64;
    for dtype in KvDtype::ALL {
        let mut cfg = MicroConfig::paper(batch, np, ns);
        cfg.heads = heads;
        cfg.max_new_tokens = 4;
        cfg.dtype = dtype;
        let mut kb = KernelBench::new(cfg, AttentionImpl::ChunkAttn);
        suite.measure(
            &format!("dtype/{}", dtype.label()),
            &[("dtype", dtype.label().to_string()), ("np", np.to_string()), ("ns", ns.to_string())],
            Some("tok/s"),
            || kb.decode_step(),
        );
        let us = suite.rows().last().unwrap().stats.mean();
        if dtype == KvDtype::F32 {
            f32_us = us;
        }
        let kv = kb.kv_bytes();
        table.push((
            vec![
                dtype.label().to_string(),
                format!("{us:.0}"),
                format!("{:.2}x", f32_us / us),
                format!("{:.1}MiB", kv as f64 / (1 << 20) as f64),
            ],
            String::new(),
        ));
    }
    print_table(
        &format!(
            "KV storage dtype — ChunkAttn decode step (h={heads}, d=128, c=64, b={batch}, \
             {ns}-token shared prefix; acceptance: f16 no slower than f32)"
        ),
        &["dtype", "latency(us)", "vs f32", "kv bytes"],
        &table,
    );
}

/// The 2D (head × chunk-run) schedule vs the head-only 1D partition at the
/// acceptance shape: heads=8, workers=8, batch=32, 1024-token fully shared
/// prefix. With heads == workers the 1D kernel keeps the pool busy only
/// during its single fan-out dimension; the 2D schedule exposes head×run +
/// head×row tasks and rides the 8-row micro-kernel.
fn two_d_vs_head_only(suite: &mut BenchSuite) {
    let (heads, batch, np, ns, workers) = (8usize, 32usize, 1024usize, 1024usize, 8usize);
    let mut cfg = MicroConfig::paper(batch, np, ns);
    cfg.heads = heads;
    let shape = cfg.shape();
    let mut tree = PrefixTree::new(shape);
    let mut fill = |pos: usize, token: u32, k: &mut [f32], v: &mut [f32]| {
        let mut r = Pcg64::new(pos as u64 ^ 0xF111, token as u64);
        r.fill_uniform_f32(k, -1.0, 1.0);
        r.fill_uniform_f32(v, -1.0, 1.0);
    };
    for i in 0..batch {
        tree.insert_sequence(SeqId(i as u64), &cfg.prompt_of(i), &mut fill);
    }
    let ctx = tree.context();
    let b = ctx.seq_order.len();
    let mut rng = Pcg64::seeded(4242);
    let mut q = vec![0.0f32; heads * b * shape.head_dim];
    rng.fill_uniform_f32(&mut q, -1.0, 1.0);
    let queries = Queries::new(&q, heads, b, shape.head_dim);
    let pool = ThreadPool::new(workers);
    let mut out = vec![0.0f32; q.len()];

    let mut scratch1d = TppScratch::new(&shape, b);
    suite.measure(
        "2d_vs_head/head_only",
        &[("schedule", "head_only".to_string()), ("workers", workers.to_string())],
        Some("tok/s"),
        || {
            tpp_attention(&tree, &ctx, &queries, &pool, &mut scratch1d, &mut out);
            b as u64
        },
    );
    let head_only_us = suite.rows().last().unwrap().stats.mean();

    let mut scratch2d = Tpp2dScratch::new();
    suite.measure(
        "2d_vs_head/parallel_2d",
        &[("schedule", "parallel_2d".to_string()), ("workers", workers.to_string())],
        Some("tok/s"),
        || {
            tpp_attention_2d(&tree, &ctx, &queries, &pool, &mut scratch2d, &mut out);
            b as u64
        },
    );
    let two_d_us = suite.rows().last().unwrap().stats.mean();

    print_table(
        &format!(
            "2D schedule vs head-only partition (h={heads}, workers={workers}, b={batch}, \
             {ns}-token shared prefix; acceptance target ≥ 1.50x)"
        ),
        &["schedule", "latency(us)", "speedup"],
        &[
            (vec!["head_only".into(), format!("{head_only_us:.0}"), "1.00x".into()], String::new()),
            (
                vec![
                    "parallel_2d".into(),
                    format!("{two_d_us:.0}"),
                    format!("{:.2}x", head_only_us / two_d_us),
                ],
                String::new(),
            ),
        ],
    );
}
