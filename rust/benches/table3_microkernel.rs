//! Table 3: decode self-attention latency across the six kernel
//! implementations for the (n_p, n_s) grid, measured on this host's memory
//! hierarchy (see DESIGN.md §2 for why CPU cache locality reproduces the
//! A100 shape).
//!
//! Quick mode (default): h=4, b=16 — minutes. Full mode
//! (`CHUNK_ATTN_BENCH_MODE=full`): the paper's h=32, b=32, c=64, d=128.

use chunk_attention::coordinator::{KernelBench, MicroConfig};
use chunk_attention::perf_model::AttentionImpl;
use chunk_attention::util::bench::{print_table, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("table3_microkernel");
    let mode = suite.mode();
    let (heads, batch) = mode.pick((4, 16), (32, 32));
    let grid: Vec<(usize, usize)> = vec![
        (1024, 0),
        (1024, 512),
        (1024, 768),
        (1024, 1024),
        (2048, 0),
        (2048, 1024),
        (2048, 1536),
        (2048, 2048),
        (4096, 0),
        (4096, 2048),
        (4096, 3072),
        (4096, 4096),
    ];

    let mut table: Vec<(Vec<String>, String)> = Vec::new();
    for &(np, ns) in &grid {
        let mut row = vec![np.to_string(), ns.to_string()];
        let mut chunk_lat = 0.0f64;
        let mut naive_lat = 0.0f64;
        for imp in AttentionImpl::ALL {
            let mut cfg = MicroConfig::paper(batch, np, ns);
            cfg.heads = heads;
            cfg.max_new_tokens = 4;
            let mut kb = KernelBench::new(cfg, imp);
            let id = format!("np{np}/ns{ns}/{}", imp.label());
            suite.measure(&id, &[("np", np.to_string()), ("ns", ns.to_string()), ("impl", imp.label().to_string())], Some("tok/s"), || kb.decode_step());
            let us = suite.rows().last().unwrap().stats.mean();
            if imp == AttentionImpl::ChunkAttn {
                chunk_lat = us;
            }
            if imp == AttentionImpl::Naive {
                naive_lat = us;
            }
            row.push(format!("{us:.0}"));
        }
        row.push(format!("{:.2}x", naive_lat / chunk_lat));
        table.push((row, String::new()));
    }

    print_table(
        &format!(
            "Table 3 — decode attention latency (µs), b={batch}, h={heads}, d=128, c=64 \
             (paper @A100: Naive/ChunkAttn = 6.6x at np=ns=4096, ~1.0x at ns=0)"
        ),
        &["np", "ns", "Naive", "xformers", "FlashAttn", "PagedAttn", "PagedAttn*", "ChunkAttn", "Naive/Chunk"],
        &table,
    );
    suite.finish();
}
