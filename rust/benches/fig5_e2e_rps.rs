//! Figure 5: normalized end-to-end latency (ms/token) vs request arrival
//! rate (RPS) for ChunkLlama / vLLM / TGI with shared prompts of
//! n_s ∈ {0, 1024, 2048}, Poisson arrivals, max batch 32, n_c = 512.
//!
//! Virtual-time simulation at Llama2-7B scale: real scheduler + real cache
//! managers, kernel time priced by the calibrated A100 roofline
//! (DESIGN.md §2).

use chunk_attention::coordinator::{simulate, SimConfig, SystemKind};
use chunk_attention::model::ModelConfig;
use chunk_attention::perf_model::HardwareModel;
use chunk_attention::util::bench::{print_table, BenchSuite};
use chunk_attention::workload::{Trace, TraceConfig};

fn main() {
    let mut suite = BenchSuite::new("fig5_e2e_rps");
    let mode = suite.mode();
    let n_requests = mode.pick(60, 250);
    let completion = mode.pick(128, 512);
    let model = ModelConfig::llama2_7b();
    let hw = HardwareModel::a100_80g();
    let rps_grid = [0.25, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 4.0];
    let shared_grid = [0usize, 1024, 2048];
    let systems = [SystemKind::ChunkLlama, SystemKind::Vllm, SystemKind::Tgi];

    let mut table = Vec::new();
    for &rps in &rps_grid {
        let mut row = vec![format!("{rps:.2}")];
        for &ns in &shared_grid {
            let trace = Trace::poisson_synthetic(
                &TraceConfig {
                    rps,
                    n_requests,
                    n_tenants: 1, // one shared system prompt (paper setup)
                    tenant_skew: 0.0,
                    query_tokens: 128,
                    completion_tokens: completion,
                    seed: 1234,
                },
                ns,
            );
            for &sys in &systems {
                // n_s = 0 is modelled by making every request its own tenant.
                let trace = if ns == 0 {
                    let mut t = trace.clone();
                    for (i, r) in t.requests.iter_mut().enumerate() {
                        r.tenant = i;
                        r.shared_tokens = 0;
                    }
                    t
                } else {
                    trace.clone()
                };
                let r = simulate(&SimConfig::new(sys), &model, &hw, &trace);
                suite.record(
                    &format!("{}(ns={ns})@rps{rps}", sys.label()),
                    &[
                        ("system", sys.label().to_string()),
                        ("ns", ns.to_string()),
                        ("rps", format!("{rps}")),
                    ],
                    r.normalized_latency_ms_per_tok * 1e3, // µs for the suite
                    Some(("ms/tok", r.normalized_latency_ms_per_tok)),
                );
                row.push(format!("{:.1}", r.normalized_latency_ms_per_tok));
            }
        }
        table.push((row, String::new()));
    }

    let headers: Vec<String> = std::iter::once("RPS".to_string())
        .chain(shared_grid.iter().flat_map(|ns| {
            systems.iter().map(move |s| format!("{}({ns})", s.label()))
        }))
        .collect();
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    print_table(
        &format!(
            "Figure 5 — normalized latency (ms/tok) vs RPS, n_c={completion}, max_batch=32 \
             (paper @A100: ChunkLlama sustains 2.9 RPS at ns=1024 vs vLLM 1.8, <40ms/tok)"
        ),
        &header_refs,
        &table,
    );
    suite.finish();
}
