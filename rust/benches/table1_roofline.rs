//! Table 1: complexity analysis (FLOPs / MOPs / arithmetic intensity /
//! latency) of the per-layer decode modules for Llama2-7B with 2048 context
//! tokens on the A100 roofline model.
//!
//! Regenerates the exact FLOPs/MOPs/AI values analytically and the latency
//! column from the calibrated roofline. Run: `cargo bench --bench
//! table1_roofline`.

use chunk_attention::model::ModelConfig;
use chunk_attention::perf_model::HardwareModel;
use chunk_attention::util::bench::{print_table, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("table1_roofline");
    let model = ModelConfig::llama2_7b();
    let hw = HardwareModel::a100_80g();
    let context = 2048;

    let mut rows = Vec::new();
    for &batch in &[1usize, 32, 64] {
        let modules = [
            ("QKV Projection", model.qkv_projection_cost(batch)),
            ("Self Attention", model.self_attention_cost(batch, context)),
            ("MLP", model.mlp_cost(batch)),
        ];
        for (name, cost) in modules {
            let rep = hw.report(&cost);
            rows.push((
                vec![
                    batch.to_string(),
                    name.to_string(),
                    format!("{:.2}", rep.flops / 1e6),
                    format!("{:.2}", rep.mops / 1e6),
                    format!("{:.2}", rep.arithmetic_intensity),
                    format!("{:.2}", rep.latency_us),
                    format!("{:?}", rep.bound),
                ],
                String::new(),
            ));
            suite.record(
                &format!("b{batch}/{name}"),
                &[("batch", batch.to_string()), ("module", name.to_string())],
                rep.latency_us,
                None,
            );
        }
    }
    print_table(
        "Table 1 — per-layer decode complexity, Llama2-7B, n=2048 (paper: FLOPs/MOPs exact, latency modelled)",
        &["b", "module", "FLOPs(x1e6)", "MOPs(x1e6)", "AI", "latency(us)", "bound"],
        &rows,
    );
    println!(
        "\npaper reference (b=32): QKV 90.02us, SelfAttn 687.74us, MLP 209.82us; \
         AI: 31.67 / 0.99 / 31.66"
    );
    suite.finish();
}
