//! Figure 4: decode token rate vs batch size (n_c = 64). Prefix-agnostic
//! kernels plateau once memory-bound; ChunkAttention keeps scaling because
//! the shared-chunk traffic is batch-invariant.

use chunk_attention::coordinator::{KernelBench, MicroConfig};
use chunk_attention::perf_model::AttentionImpl;
use chunk_attention::util::bench::{print_table, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("fig4_batch_sweep");
    let mode = suite.mode();
    let (heads, ns) = mode.pick((4, 1024), (32, 2048));
    let batches: Vec<usize> = mode.pick(vec![1, 4, 8, 16, 32], vec![1, 4, 16, 32, 64, 96]);
    let nc = 64usize;
    let impls = [
        AttentionImpl::Naive,
        AttentionImpl::PagedAttn,
        AttentionImpl::PagedAttnShared,
        AttentionImpl::ChunkAttn,
    ];

    let mut table = Vec::new();
    for &b in &batches {
        let mut row = vec![b.to_string()];
        for &imp in &impls {
            let mut cfg = MicroConfig::paper(b, ns, ns);
            cfg.heads = heads;
            cfg.max_new_tokens = nc + 8;
            let mut kb = KernelBench::new(cfg, imp);
            // Advance to mid-decode (n_c/2) so divergence is realistic.
            for _ in 0..nc / 2 {
                kb.append_round();
            }
            suite.measure(
                &format!("{}@b{b}", imp.label()),
                &[("impl", imp.label().to_string()), ("b", b.to_string())],
                Some("tok/s"),
                || kb.decode_step(),
            );
            let us = suite.rows().last().unwrap().stats.mean();
            let rate = b as f64 / (us / 1e6);
            row.push(if rate >= 10_000.0 { format!("{:.0}K", rate / 1e3) } else { format!("{rate:.0}") });
        }
        table.push((row, String::new()));
    }
    print_table(
        &format!(
            "Figure 4 — decode token rate vs batch size, n_s={ns}, n_c={nc}, h={heads} \
             (paper @A100: baselines peak at b=16; ChunkAttn grows 155K -> 224K tok/s to b=96)"
        ),
        &["b", "Naive", "PagedAttn", "PagedAttn*", "ChunkAttn"],
        &table,
    );
    suite.finish();
}
