//! Table 4: normalized latency, peak KV-cache memory, and peak batch size
//! for vLLM vs ChunkLlama on the paper's (n_p, n_s, RPS) grid, n_c = 512.

use chunk_attention::coordinator::{simulate, SimConfig, SystemKind};
use chunk_attention::model::ModelConfig;
use chunk_attention::perf_model::HardwareModel;
use chunk_attention::util::bench::{print_table, BenchSuite};
use chunk_attention::util::stats::fmt_bytes;
use chunk_attention::workload::{Trace, TraceConfig};

fn main() {
    let mut suite = BenchSuite::new("table4_e2e_memory");
    let mode = suite.mode();
    let n_requests = mode.pick(50, 200);
    let completion = mode.pick(128, 512);
    let model = ModelConfig::llama2_7b();
    let hw = HardwareModel::a100_80g();
    // (n_p, n_s, rps) — the paper's Table 4 grid.
    let grid = [
        (1024usize, 0usize, 1.0f64),
        (1024, 1024, 1.0),
        (2048, 0, 0.6),
        (2048, 2048, 0.6),
        (4096, 0, 0.4),
        (4096, 4096, 0.4),
    ];

    let mut table = Vec::new();
    for &(np, ns, rps) in &grid {
        let query = np - ns.min(np);
        let mut trace = Trace::poisson_synthetic(
            &TraceConfig {
                rps,
                n_requests,
                n_tenants: 1,
                tenant_skew: 0.0,
                query_tokens: query.max(1),
                completion_tokens: completion,
                seed: 77,
            },
            ns,
        );
        if ns == 0 {
            for (i, r) in trace.requests.iter_mut().enumerate() {
                r.tenant = i;
                r.shared_tokens = 0;
            }
        }
        let vllm = simulate(&SimConfig::new(SystemKind::Vllm), &model, &hw, &trace);
        let chunk = simulate(&SimConfig::new(SystemKind::ChunkLlama), &model, &hw, &trace);
        for (sys, r) in [("vLLM", &vllm), ("ChunkLlama", &chunk)] {
            suite.record(
                &format!("{sys}/np{np}/ns{ns}"),
                &[
                    ("system", sys.to_string()),
                    ("np", np.to_string()),
                    ("ns", ns.to_string()),
                    ("rps", format!("{rps}")),
                ],
                r.normalized_latency_ms_per_tok * 1e3,
                Some(("ms/tok", r.normalized_latency_ms_per_tok)),
            );
        }
        table.push((
            vec![
                np.to_string(),
                ns.to_string(),
                format!("{rps:.1}"),
                format!("{:.2}", vllm.normalized_latency_ms_per_tok),
                format!("{:.2}", chunk.normalized_latency_ms_per_tok),
                fmt_bytes(vllm.peak_kv_bytes),
                fmt_bytes(chunk.peak_kv_bytes),
                vllm.peak_batch.to_string(),
                chunk.peak_batch.to_string(),
            ],
            String::new(),
        ));
    }
    print_table(
        &format!(
            "Table 4 — e2e latency / peak KV / peak batch, n_c={completion} \
             (paper @A100: KV cut 70-90% with full sharing; no regression at ns=0)"
        ),
        &[
            "np",
            "ns",
            "RPS",
            "vLLM ms/tok",
            "Chunk ms/tok",
            "vLLM KV",
            "Chunk KV",
            "vLLM b",
            "Chunk b",
        ],
        &table,
    );
    suite.finish();
}
