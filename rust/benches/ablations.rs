//! Ablations on the design choices DESIGN.md calls out:
//!
//! 1. TPP kernel variants — the production 2D (head × chunk-run) schedule
//!    vs the head-partitioned fused kernel vs Algorithms-1+2 buffered vs
//!    sequence-first-only (PAKV without the TPP batching).
//! 2. Chunk size c — the alignment-waste vs batching-granularity tradeoff.
//! 3. Lazy context copy (§3.3) — cached tree context vs rebuild-per-step.
//! 4. KV storage dtype — f32 vs f16 vs bf16 chunk slabs: resident bytes
//!    halve at half precision and the bandwidth-bound chunk-first phase
//!    streams half the K/V bytes per step.

use chunk_attention::coordinator::{KernelBench, MicroConfig, TppVariant};
use chunk_attention::kvcache::{KvDtype, KvShape, PrefixTree, SeqId};
use chunk_attention::perf_model::AttentionImpl;
use chunk_attention::util::bench::{print_table, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("ablations");
    let mode = suite.mode();
    let (heads, batch, ns) = mode.pick((4, 16, 1024), (32, 32, 2048));

    // --- 1. Kernel variants ---------------------------------------------
    let mut table = Vec::new();
    for (variant, label) in [
        (TppVariant::Parallel2d, "2d schedule (production)"),
        (TppVariant::Fused, "fused head-partition"),
        (TppVariant::Buffered, "buffered (Alg. 1+2)"),
        (TppVariant::SeqFirstOnly, "seq-first only (no TPP)"),
    ] {
        let mut cfg = MicroConfig::paper(batch, ns, ns);
        cfg.heads = heads;
        cfg.max_new_tokens = 4;
        let mut kb = KernelBench::new(cfg, AttentionImpl::ChunkAttn);
        suite.measure(&format!("variant/{label}"), &[("variant", label.to_string())], Some("tok/s"), || {
            kb.decode_step_variant(variant)
        });
        let us = suite.rows().last().unwrap().stats.mean();
        table.push((vec![label.to_string(), format!("{us:.0}")], String::new()));
    }
    print_table("Ablation 1 — TPP variants (µs/step, full sharing)", &["variant", "latency"], &table);

    // --- 2. Chunk size sweep ---------------------------------------------
    let mut table = Vec::new();
    for c in [16usize, 32, 64, 128, 256] {
        let mut cfg = MicroConfig::paper(batch, ns, ns);
        cfg.heads = heads;
        cfg.chunk_size = c;
        cfg.max_new_tokens = 4;
        let mut kb = KernelBench::new(cfg, AttentionImpl::ChunkAttn);
        suite.measure(&format!("chunk_size/{c}"), &[("c", c.to_string())], Some("tok/s"), || {
            kb.decode_step()
        });
        let us = suite.rows().last().unwrap().stats.mean();
        let kv = kb.kv_bytes();
        table.push((
            vec![c.to_string(), format!("{us:.0}"), format!("{:.1}MiB", kv as f64 / (1 << 20) as f64)],
            String::new(),
        ));
    }
    print_table(
        "Ablation 2 — chunk size c (latency vs KV footprint at f32; paper uses c=64)",
        &["c", "latency(us)", "kv bytes"],
        &table,
    );

    // --- 3. Lazy context copy --------------------------------------------
    let mut table = Vec::new();
    for lazy in [true, false] {
        let shape = KvShape::new(heads, 128, 64);
        let mut tree = PrefixTree::new(shape);
        tree.lazy_context = lazy;
        let sys: Vec<u32> = (0..ns as u32).collect();
        let mut fill = |_p: usize, t: u32, k: &mut [f32], v: &mut [f32]| {
            k.fill(t as f32 * 1e-3);
            v.fill(t as f32 * -1e-3);
        };
        for i in 0..batch as u64 {
            let mut p = sys.clone();
            p.extend([900_000 + i as u32]);
            tree.insert_sequence(SeqId(i), &p, &mut fill);
        }
        let row = vec![0.1f32; heads * 128];
        let mut step = 0u32;
        suite.measure(
            &format!("lazy_context/{lazy}"),
            &[("lazy", lazy.to_string())],
            Some("ctx/s"),
            || {
                // One decode iteration's tree work: context + appends.
                let ctx = tree.context();
                std::hint::black_box(ctx.entries.len());
                for i in 0..batch as u64 {
                    tree.append_token(SeqId(i), 1_000_000 + step, &row, &row);
                }
                step += 1;
                batch as u64
            },
        );
        let us = suite.rows().last().unwrap().stats.mean();
        let (rebuilds, hits) = tree.context_stats();
        table.push((
            vec![lazy.to_string(), format!("{us:.1}"), rebuilds.to_string(), hits.to_string()],
            String::new(),
        ));
    }
    print_table(
        "Ablation 3 — lazy context copy (tree work per decode iteration)",
        &["lazy", "latency(us)", "rebuilds", "cache hits"],
        &table,
    );

    // --- 4. KV storage dtype ---------------------------------------------
    let mut table = Vec::new();
    for dtype in KvDtype::ALL {
        let mut cfg = MicroConfig::paper(batch, ns, ns);
        cfg.heads = heads;
        cfg.max_new_tokens = 4;
        cfg.dtype = dtype;
        let mut kb = KernelBench::new(cfg, AttentionImpl::ChunkAttn);
        suite.measure(
            &format!("kv_dtype/{}", dtype.label()),
            &[("dtype", dtype.label().to_string())],
            Some("tok/s"),
            || kb.decode_step(),
        );
        let us = suite.rows().last().unwrap().stats.mean();
        let kv = kb.kv_bytes();
        table.push((
            vec![
                dtype.label().to_string(),
                format!("{us:.0}"),
                format!("{:.1}MiB", kv as f64 / (1 << 20) as f64),
            ],
            String::new(),
        ));
    }
    print_table(
        "Ablation 4 — KV storage dtype (full sharing; half precision halves \
         resident bytes and chunk-first K/V traffic)",
        &["dtype", "latency(us)", "kv bytes"],
        &table,
    );
    suite.finish();
}
