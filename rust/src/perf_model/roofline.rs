//! Roofline hardware model (Williams et al., 2009).
//!
//! `latency = max(flops / achieved_flops, mops / achieved_bandwidth) +
//! kernel_overhead`. Achieved rates are peak × an efficiency fraction; the
//! A100 preset is calibrated so Table 1's measured latencies are
//! approximated within ~20% (the paper's latency column is itself a
//! measurement, not a roofline bound).

use crate::model::ModuleCost;

/// A device for the analytical model.
#[derive(Debug, Clone, Copy)]
pub struct HardwareModel {
    pub name: &'static str,
    /// Peak memory bandwidth, bytes/s.
    pub peak_bw: f64,
    /// Peak FP16 tensor throughput, FLOP/s.
    pub peak_flops: f64,
    /// Fraction of peak bandwidth real kernels achieve.
    pub bw_efficiency: f64,
    /// Fraction of peak FLOPs real kernels achieve.
    pub flops_efficiency: f64,
    /// Fixed per-kernel launch/dispatch overhead, seconds.
    pub kernel_overhead_s: f64,
    /// On-chip cache bandwidth (L2 on A100), bytes/s — used by the
    /// attention cost model for re-reads of physically shared memory.
    pub cache_bw: f64,
}

impl HardwareModel {
    /// NVIDIA A100-SXM 80GB: 2039 GB/s HBM2e, 312 TFLOPS FP16 tensor core,
    /// ~4.8 TB/s L2. Efficiencies calibrated against the paper's Table 1.
    pub fn a100_80g() -> Self {
        HardwareModel {
            name: "a100-80g",
            peak_bw: 2.039e12,
            peak_flops: 312e12,
            bw_efficiency: 0.75,
            flops_efficiency: 0.60,
            kernel_overhead_s: 12e-6,
            cache_bw: 4.8e12,
        }
    }

    pub fn achieved_bw(&self) -> f64 {
        self.peak_bw * self.bw_efficiency
    }

    pub fn achieved_flops(&self) -> f64 {
        self.peak_flops * self.flops_efficiency
    }

    /// Roofline latency in seconds for one kernel.
    pub fn latency_s(&self, cost: &ModuleCost) -> f64 {
        let t_mem = cost.mops / self.achieved_bw();
        let t_compute = cost.flops / self.achieved_flops();
        t_mem.max(t_compute) + self.kernel_overhead_s
    }

    pub fn latency_us(&self, cost: &ModuleCost) -> f64 {
        self.latency_s(cost) * 1e6
    }

    /// Latency for a kernel whose memory traffic is split between HBM
    /// (`hbm_bytes`) and on-chip cache re-reads (`cache_bytes`) — the
    /// PagedAttn\*/ChunkAttn situation where shared KV is re-read from L2.
    pub fn latency_split_s(&self, flops: f64, hbm_bytes: f64, cache_bytes: f64) -> f64 {
        let t_mem = hbm_bytes / self.achieved_bw() + cache_bytes / (self.cache_bw * self.bw_efficiency);
        let t_compute = flops / self.achieved_flops();
        t_mem.max(t_compute) + self.kernel_overhead_s
    }

    /// The AI at which the device flips from memory- to compute-bound.
    pub fn ridge_point(&self) -> f64 {
        self.achieved_flops() / self.achieved_bw()
    }

    /// A report row in the paper's Table 1 format.
    pub fn report(&self, cost: &ModuleCost) -> RooflineReport {
        RooflineReport {
            flops: cost.flops,
            mops: cost.mops,
            arithmetic_intensity: cost.arithmetic_intensity(),
            latency_us: self.latency_us(cost),
            bound: if cost.mops / self.achieved_bw() >= cost.flops / self.achieved_flops() {
                Bound::Memory
            } else {
                Bound::Compute
            },
        }
    }
}

/// Whether a kernel sits under the memory or compute roof.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Memory,
    Compute,
}

/// One Table 1 row.
#[derive(Debug, Clone, Copy)]
pub struct RooflineReport {
    pub flops: f64,
    pub mops: f64,
    pub arithmetic_intensity: f64,
    pub latency_us: f64,
    pub bound: Bound,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    #[test]
    fn table1_latency_shape() {
        // Reproduce Table 1's orderings: at b=32, self-attention is the
        // slowest module despite the fewest FLOPs; QKV latency barely moves
        // from b=1 to b=32 while attention scales ~linearly.
        let hw = HardwareModel::a100_80g();
        let m = ModelConfig::llama2_7b();

        let attn_1 = hw.latency_us(&m.self_attention_cost(1, 2048));
        let attn_32 = hw.latency_us(&m.self_attention_cost(32, 2048));
        let qkv_1 = hw.latency_us(&m.qkv_projection_cost(1));
        let qkv_32 = hw.latency_us(&m.qkv_projection_cost(32));
        let mlp_32 = hw.latency_us(&m.mlp_cost(32));

        assert!(attn_32 > qkv_32, "attention dominates at b=32");
        assert!(attn_32 > mlp_32, "attention dominates MLP at b=32");
        assert!(attn_32 / attn_1 > 20.0, "attention scales with batch");
        assert!(qkv_32 / qkv_1 < 1.3, "projections are weight-bound");
        // Within a factor ~1.5 of the measured paper values.
        assert!((400.0..1100.0).contains(&attn_32), "paper: 687µs, got {attn_32}");
        assert!((50.0..140.0).contains(&qkv_1), "paper: 88µs, got {qkv_1}");
    }

    #[test]
    fn arithmetic_intensity_decides_bound() {
        let hw = HardwareModel::a100_80g();
        let m = ModelConfig::llama2_7b();
        assert_eq!(hw.report(&m.self_attention_cost(32, 2048)).bound, Bound::Memory);
        // b=64 QKV has AI ~63, still below the A100 ridge (~122 achieved).
        let ridge = hw.ridge_point();
        assert!(ridge > 60.0 && ridge < 200.0, "ridge {ridge}");
    }

    #[test]
    fn split_latency_is_cheaper_than_hbm_only() {
        let hw = HardwareModel::a100_80g();
        let flops = 1e9;
        let all_hbm = hw.latency_split_s(flops, 1e9, 0.0);
        let half_cached = hw.latency_split_s(flops, 0.5e9, 0.5e9);
        assert!(half_cached < all_hbm);
    }
}
