//! Analytical performance model: an A100 roofline (Williams et al., 2009)
//! plus per-kernel attention cost models for every Table 3 implementation.
//!
//! Two consumers:
//! - `benches/table1_roofline.rs` regenerates the paper's Table 1.
//! - the virtual-time end-to-end simulator (Fig. 5 / Table 4) prices each
//!   decode/prefill step of a Llama2-7B-scale server without needing the
//!   authors' A100 testbed (DESIGN.md §2 substitution table).

pub mod attention_cost;
pub mod roofline;

pub use attention_cost::{attention_step_cost, AttentionImpl, CacheSharingState};
pub use roofline::{HardwareModel, RooflineReport};
