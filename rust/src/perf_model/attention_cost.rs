//! Per-implementation attention cost models — what each Table 3 kernel
//! costs on the analytical A100, given how much of the KV cache is shared.
//!
//! The models encode the paper's §3/§4 reasoning:
//!
//! - **Naive / xformers / FlashAttn / PagedAttn** are prefix-agnostic: each
//!   of the `b` sequences streams its full `n`-token KV from HBM.
//!   FlashAttention additionally spills/reloads per-tile partials (its
//!   decode-time handicap, visible as the slow column of Table 3).
//! - **PagedAttn\***: the kernel still issues `b × n` reads, but the shared
//!   `n_s` tokens hit the same physical pages, so re-reads are served from
//!   L2 (`HardwareModel::cache_bw`).
//! - **ChunkAttn (TPP)**: the chunk-first phase reads shared chunks from
//!   HBM *once* and batches the `b` query rows over them (higher AI, MXU
//!   friendly); only private tails are per-sequence.

use super::roofline::HardwareModel;
use crate::model::{ModelConfig, DTYPE_BYTES};

/// Which Table 3 column to price.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AttentionImpl {
    Naive,
    Xformers,
    FlashAttn,
    PagedAttn,
    PagedAttnShared,
    ChunkAttn,
}

impl AttentionImpl {
    pub const ALL: [AttentionImpl; 6] = [
        AttentionImpl::Naive,
        AttentionImpl::Xformers,
        AttentionImpl::FlashAttn,
        AttentionImpl::PagedAttn,
        AttentionImpl::PagedAttnShared,
        AttentionImpl::ChunkAttn,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            AttentionImpl::Naive => "Naive",
            AttentionImpl::Xformers => "xformers",
            AttentionImpl::FlashAttn => "FlashAttn",
            AttentionImpl::PagedAttn => "PagedAttn",
            AttentionImpl::PagedAttnShared => "PagedAttn*",
            AttentionImpl::ChunkAttn => "ChunkAttn",
        }
    }

    /// Whether the implementation benefits from prefix sharing at all.
    pub fn prefix_aware(&self) -> bool {
        matches!(self, AttentionImpl::PagedAttnShared | AttentionImpl::ChunkAttn)
    }
}

/// Sharing state of the batch at one decode step.
#[derive(Debug, Clone, Copy)]
pub struct CacheSharingState {
    /// Sequences in the decode batch.
    pub batch: usize,
    /// Context tokens per sequence (prompt + generated so far).
    pub context: usize,
    /// Leading tokens shared across the whole batch.
    pub shared: usize,
}

/// Query rows the TPP chunk-first kernel processes per streaming pass over
/// a shared KV tile (register/SMEM tile height). Calibrated so the model's
/// ChunkAttn column lands on Table 3 within ~10% (e.g. 56µs at
/// n_p=n_s=1024, b=32 — the paper reports 56.00µs).
const TPP_QUERY_TILE: f64 = 4.0;

/// Decode-step self-attention latency (seconds) for one layer.
///
/// The sharing-dependent kernels follow a two-level memory model: unique
/// bytes stream from HBM once; re-reads of physically shared KV hit L2. A
/// kernel that batches `G` query rows per KV pass re-reads shared KV
/// `b/G - 1` times (PagedAttn\*: G = 1; ChunkAttn: G = [`TPP_QUERY_TILE`]).
pub fn attention_step_cost(
    hw: &HardwareModel,
    model: &ModelConfig,
    imp: AttentionImpl,
    state: &CacheSharingState,
) -> f64 {
    let b = state.batch as f64;
    let n = state.context as f64;
    let ns = (state.shared.min(state.context)) as f64;
    let (h, d) = (model.heads as f64, model.head_dim as f64);
    let row_bytes = 2.0 * h * d * DTYPE_BYTES; // K+V for one token
    let flops = b * h * 4.0 * n * d;
    let qo_bytes = 2.0 * b * h * d * DTYPE_BYTES;

    match imp {
        AttentionImpl::Naive | AttentionImpl::Xformers | AttentionImpl::PagedAttn => {
            // Full per-sequence KV streamed from HBM; the three kernels
            // differ only in constant factors on the A100 (Table 3 shows
            // them within ~25% of each other). Structural overheads:
            let overhead = match imp {
                AttentionImpl::Xformers => 1.15, // extra rescale traffic
                AttentionImpl::PagedAttn => 1.02, // page-table indirection
                _ => 1.0,
            };
            let hbm = b * n * row_bytes * overhead + qo_bytes;
            hw.latency_split_s(flops, hbm, 0.0)
        }
        AttentionImpl::FlashAttn => {
            // Training-oriented kernel: for q_len = 1 the tile is mostly
            // empty query rows, wasting ~4.4× effective K/V bandwidth, plus
            // per-tile partial (O, m, n) spill/reload. This reproduces the
            // paper's 4.3–4.6× FlashAttn/Naive decode gap.
            let tile = 128.0;
            let tiles = (n / tile).ceil().max(1.0);
            let spill = b * h * tiles * (d + 2.0) * DTYPE_BYTES * 2.0; // write+read
            let waste = 4.4;
            let hbm = b * n * row_bytes * waste + spill + qo_bytes;
            hw.latency_split_s(flops, hbm, 0.0)
        }
        AttentionImpl::PagedAttnShared => {
            // Shared pages: streamed from HBM once, re-read from L2 by each
            // of the remaining b-1 sequences (one query row per pass).
            let hbm = (ns + b * (n - ns)) * row_bytes + qo_bytes;
            let cache = (b - 1.0).max(0.0) * ns * row_bytes;
            hw.latency_split_s(flops, hbm, cache)
        }
        AttentionImpl::ChunkAttn => {
            // TPP chunk-first: query rows are batched TPP_QUERY_TILE at a
            // time over each shared chunk, cutting L2 re-reads by that
            // factor; private tails stream per sequence as usual. Partial
            // (O, m, n) merge traffic is negligible but included.
            let hbm = (ns + b * (n - ns)) * row_bytes + qo_bytes;
            let passes = (b / TPP_QUERY_TILE).ceil();
            let cache = (passes - 1.0).max(0.0) * ns * row_bytes;
            let merge = b * h * (d + 2.0) * DTYPE_BYTES * 2.0;
            hw.latency_split_s(flops, hbm + merge, cache)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(batch: usize, context: usize, shared: usize) -> CacheSharingState {
        CacheSharingState { batch, context, shared }
    }

    fn us(
        hw: &HardwareModel,
        m: &ModelConfig,
        imp: AttentionImpl,
        s: &CacheSharingState,
    ) -> f64 {
        attention_step_cost(hw, m, imp, s) * 1e6
    }

    #[test]
    fn table3_shape_full_sharing() {
        // n_p = n_s = 4096, b = 32: ChunkAttn ≈ 206µs, PagedAttn* ≈ 664µs,
        // Naive ≈ 1370µs in the paper. Require the ordering and rough
        // factors (3–8× Naive/Chunk, 2–4× Paged*/Chunk).
        let hw = HardwareModel::a100_80g();
        let m = ModelConfig::llama2_7b();
        let s = state(32, 4096, 4096);
        let naive = us(&hw, &m, AttentionImpl::Naive, &s);
        let paged = us(&hw, &m, AttentionImpl::PagedAttn, &s);
        let paged_star = us(&hw, &m, AttentionImpl::PagedAttnShared, &s);
        let chunk = us(&hw, &m, AttentionImpl::ChunkAttn, &s);
        let flash = us(&hw, &m, AttentionImpl::FlashAttn, &s);
        assert!(chunk < paged_star && paged_star < paged && paged <= flash);
        let speedup = naive / chunk;
        assert!((3.0..10.0).contains(&speedup), "naive/chunk {speedup}");
        let vs_star = paged_star / chunk;
        assert!((1.5..5.0).contains(&vs_star), "paged*/chunk {vs_star}");
    }

    #[test]
    fn no_sharing_no_regression() {
        // n_s = 0: ChunkAttn within a few percent of PagedAttn (Table 3
        // rows with n_s=0).
        let hw = HardwareModel::a100_80g();
        let m = ModelConfig::llama2_7b();
        let s = state(32, 2048, 0);
        let chunk = us(&hw, &m, AttentionImpl::ChunkAttn, &s);
        let paged = us(&hw, &m, AttentionImpl::PagedAttn, &s);
        assert!((chunk / paged - 1.0).abs() < 0.1, "chunk {chunk} vs paged {paged}");
    }

    #[test]
    fn latency_decreases_with_sharing_only_for_aware_kernels() {
        let hw = HardwareModel::a100_80g();
        let m = ModelConfig::llama2_7b();
        for imp in AttentionImpl::ALL {
            let t0 = us(&hw, &m, imp, &state(32, 2048, 0));
            let t1 = us(&hw, &m, imp, &state(32, 2048, 2048));
            if imp.prefix_aware() {
                assert!(t1 < t0 * 0.7, "{imp:?} should speed up: {t0} -> {t1}");
            } else {
                assert!((t1 / t0 - 1.0).abs() < 0.02, "{imp:?} is prefix-agnostic");
            }
        }
    }

    #[test]
    fn flash_is_slowest_for_decode() {
        let hw = HardwareModel::a100_80g();
        let m = ModelConfig::llama2_7b();
        let s = state(32, 2048, 0);
        let flash = us(&hw, &m, AttentionImpl::FlashAttn, &s);
        let naive = us(&hw, &m, AttentionImpl::Naive, &s);
        // Paper: 3175µs vs 686µs (~4.6×).
        let ratio = flash / naive;
        assert!((2.0..7.0).contains(&ratio), "flash/naive {ratio}");
    }

    #[test]
    fn speedup_decays_with_completion_tokens() {
        // Fig 3: as n_c grows past the shared prefix, speedup shrinks.
        let hw = HardwareModel::a100_80g();
        let m = ModelConfig::llama2_7b();
        let speedup_at = |nc: usize| {
            let s = state(32, 2048 + nc, 2048);
            us(&hw, &m, AttentionImpl::PagedAttn, &s) / us(&hw, &m, AttentionImpl::ChunkAttn, &s)
        };
        let early = speedup_at(64);
        let late = speedup_at(2048);
        assert!(early > late, "speedup decays: {early} -> {late}");
        assert!(late > 1.2, "still a win at n_c=2048");
    }
}
