//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime: model config, weight-parameter order/shapes, and
//! the artifact inventory. Everything is cross-checked at load time so a
//! stale artifact directory fails loudly instead of mis-executing.

use std::path::{Path, PathBuf};

use crate::model::ModelConfig;
use crate::util::json::Json;

/// Kind of compiled computation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    Decode,
    Prefill,
    KernelTest,
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub kind: ArtifactKind,
    /// decode: batch capacity.
    pub batch: usize,
    /// decode: chunk-slot capacity.
    pub max_chunks: usize,
    /// decode: tokens per chunk.
    pub chunk_size: usize,
    /// prefill: max suffix / prefix lengths.
    pub max_suffix: usize,
    pub max_prefix: usize,
}

/// One weight tensor in flattened-pytree order.
#[derive(Debug, Clone)]
pub struct WeightSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl WeightSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelConfig,
    pub heads_total: usize,
    pub weights_file: String,
    pub weights: Vec<WeightSpec>,
    pub artifacts: Vec<ArtifactEntry>,
}

fn get_usize(j: &Json, key: &str) -> anyhow::Result<usize> {
    j.get(key)
        .and_then(Json::as_f64)
        .map(|x| x as usize)
        .ok_or_else(|| anyhow::anyhow!("manifest missing numeric field {key:?}"))
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("{}: {e}; run `make artifacts` first", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;

        let m = j.get("model").ok_or_else(|| anyhow::anyhow!("manifest missing model"))?;
        let model = ModelConfig {
            name: "mini",
            n_layers: get_usize(m, "n_layers")?,
            d_model: get_usize(m, "d_model")?,
            heads: get_usize(m, "heads")?,
            head_dim: get_usize(m, "head_dim")?,
            ffn_dim: get_usize(m, "ffn_dim")?,
            vocab: get_usize(m, "vocab")?,
        };
        let heads_total = get_usize(m, "heads_total")?;
        anyhow::ensure!(
            heads_total == model.n_layers * model.heads,
            "manifest heads_total inconsistent"
        );
        // The compiled model must match the Rust-side preset the serving
        // examples assume.
        let expect = ModelConfig::mini();
        anyhow::ensure!(
            model == expect,
            "artifact model {model:?} != ModelConfig::mini() {expect:?}; re-run make artifacts"
        );

        let weights = j
            .get("weights")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing weights"))?
            .iter()
            .map(|w| {
                let name = w.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
                let shape = w
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_f64).map(|x| x as usize).collect())
                    .unwrap_or_default();
                WeightSpec { name, shape }
            })
            .collect();

        let mut artifacts = Vec::new();
        for a in j.get("artifacts").and_then(Json::as_arr).unwrap_or(&[]) {
            let kind = match a.get("kind").and_then(Json::as_str) {
                Some("decode") => ArtifactKind::Decode,
                Some("prefill") => ArtifactKind::Prefill,
                Some("kernel_test") => ArtifactKind::KernelTest,
                other => anyhow::bail!("unknown artifact kind {other:?}"),
            };
            artifacts.push(ArtifactEntry {
                file: a.get("file").and_then(Json::as_str).unwrap_or("?").to_string(),
                kind,
                batch: get_usize(a, "batch").unwrap_or(0),
                max_chunks: get_usize(a, "max_chunks").unwrap_or(0),
                chunk_size: get_usize(a, "chunk_size").unwrap_or(0),
                max_suffix: get_usize(a, "max_suffix").unwrap_or(0),
                max_prefix: get_usize(a, "max_prefix").unwrap_or(0),
            });
        }

        let manifest = Manifest {
            dir: dir.to_path_buf(),
            model,
            heads_total,
            weights_file: j
                .get("weights_file")
                .and_then(Json::as_str)
                .unwrap_or("mini_weights.bin")
                .to_string(),
            weights,
            artifacts,
        };
        Ok(manifest)
    }

    /// Load the raw f32 weights blob and split it per the manifest specs.
    pub fn load_weights(&self) -> anyhow::Result<Vec<Vec<f32>>> {
        let path = self.dir.join(&self.weights_file);
        let bytes = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let total: usize = self.weights.iter().map(WeightSpec::elems).sum();
        anyhow::ensure!(
            bytes.len() == total * 4,
            "weights blob {} bytes, manifest wants {}",
            bytes.len(),
            total * 4
        );
        let mut out = Vec::with_capacity(self.weights.len());
        let mut off = 0usize;
        for spec in &self.weights {
            let n = spec.elems();
            let mut buf = vec![0.0f32; n];
            for (i, x) in buf.iter_mut().enumerate() {
                let b = &bytes[(off + i) * 4..(off + i) * 4 + 4];
                *x = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            }
            off += n;
            out.push(buf);
        }
        Ok(out)
    }

    /// The decode artifact with the smallest capacity ≥ `batch`.
    pub fn decode_artifact(&self, batch: usize) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Decode && a.batch >= batch)
            .min_by_key(|a| a.batch)
    }

    pub fn prefill_artifact(&self) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.kind == ArtifactKind::Prefill)
    }

    pub fn kernel_test_artifact(&self) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.kind == ArtifactKind::KernelTest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need `make artifacts` to have run; they are skipped (not
    /// failed) otherwise so `cargo test` works on a fresh checkout.
    fn manifest() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn manifest_loads_and_crosschecks() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(m.model, ModelConfig::mini());
        assert!(m.decode_artifact(3).is_some());
        assert!(m.decode_artifact(4).unwrap().batch == 4);
        assert!(m.prefill_artifact().is_some());
        assert_eq!(m.weights.len(), 20);
    }

    #[test]
    fn weights_blob_splits() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let w = m.load_weights().unwrap();
        assert_eq!(w.len(), m.weights.len());
        // Embedding is vocab × d_model.
        let embed_idx = m.weights.iter().position(|s| s.name.contains("embed")).unwrap();
        assert_eq!(w[embed_idx].len(), m.model.vocab * m.model.d_model);
        assert!(w[embed_idx].iter().any(|&x| x != 0.0));
    }
}
