//! Runtime: load the AOT HLO-text artifacts through PJRT and serve the
//! compiled executables from the decode path. Python never runs here.
//!
//! The manifest (artifact inventory + model config) is always available;
//! the PJRT client and model runner need the `xla` crate and are gated
//! behind the `pjrt` cargo feature so the default build stays offline.

pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub mod pjrt_model;

pub use manifest::{ArtifactKind, Manifest};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtRuntime;
#[cfg(feature = "pjrt")]
pub use pjrt_model::PjrtModel;
