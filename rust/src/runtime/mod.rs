//! Runtime: load the AOT HLO-text artifacts through PJRT and serve the
//! compiled executables from the decode path. Python never runs here.

pub mod manifest;
pub mod pjrt;
pub mod pjrt_model;

pub use manifest::{ArtifactKind, Manifest};
pub use pjrt::PjrtRuntime;
pub use pjrt_model::PjrtModel;
