//! Thin wrapper over the `xla` crate's PJRT client: load HLO-text
//! artifacts, compile once, execute many times with typed literal helpers.

use std::path::Path;

use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// A PJRT client plus artifact loading. One per process.
pub struct PjrtRuntime {
    client: PjRtClient,
}

impl PjrtRuntime {
    /// CPU PJRT client (the only backend in this environment; the same
    /// code path takes `PjRtClient::gpu`/`tpu` upstream).
    pub fn cpu() -> anyhow::Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        log::info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(PjrtRuntime { client })
    }

    /// Load an HLO **text** artifact and compile it.
    ///
    /// Text, not serialized proto: jax ≥ 0.5 emits 64-bit instruction ids
    /// which this XLA rejects; the text parser reassigns ids.
    pub fn load_hlo_text(&self, path: &Path) -> anyhow::Result<PjRtLoadedExecutable> {
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow::anyhow!("non-utf8 path {}", path.display()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow::anyhow!("{}: parse failed: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("{}: compile failed: {e:?}", path.display()))?;
        log::info!("compiled {}", path.display());
        Ok(exe)
    }

    /// Execute and unpack the single-replica tuple output into literals.
    pub fn execute(
        &self,
        exe: &PjRtLoadedExecutable,
        args: &[&Literal],
    ) -> anyhow::Result<Vec<Literal>> {
        let out = exe.execute::<&Literal>(args).map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn f32_literal(data: &[f32], dims: &[i64]) -> anyhow::Result<Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "literal shape {dims:?} != {} elems", data.len());
    Literal::vec1(data).reshape(dims).map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn i32_literal(data: &[i32], dims: &[i64]) -> anyhow::Result<Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "literal shape {dims:?} != {} elems", data.len());
    Literal::vec1(data).reshape(dims).map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

/// Scalar i32 literal.
pub fn i32_scalar(x: i32) -> Literal {
    Literal::scalar(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_helpers_shape_check() {
        let l = f32_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        assert!(f32_literal(&[1.0], &[2, 2]).is_err());
        let i = i32_literal(&[7, 8], &[2]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, 8]);
    }
}
