//! [`PjrtModel`]: the [`ModelRunner`] that serves the AOT-compiled mini
//! model through PJRT — the production wiring of the three-layer stack.
//! The engine owns the prefix tree; this runner packs the tree context into
//! the fixed-shape chunk tensors the HLO expects (§3.3's "context copy"),
//! executes `mini_decode_b*.hlo.txt` / `mini_prefill.hlo.txt`, and returns
//! fresh K/V rows for the coordinator to append.

use std::path::Path;

use xla::{Literal, PjRtLoadedExecutable};

use super::manifest::Manifest;
use super::pjrt::{f32_literal, i32_literal, i32_scalar, PjrtRuntime};
use crate::coordinator::engine::{DecodeOutput, ModelRunner, PrefillOutput};
use crate::kvcache::{PrefixTree, TreeContext};

/// PJRT-backed model runner.
pub struct PjrtModel {
    runtime: PjrtRuntime,
    manifest: Manifest,
    weights: Vec<Literal>,
    /// (batch capacity, executable) sorted ascending.
    decode_exes: Vec<(usize, PjRtLoadedExecutable)>,
    prefill_exe: PjRtLoadedExecutable,
    max_chunks: usize,
    chunk_size: usize,
    max_suffix: usize,
    max_prefix: usize,
    /// Reused staging buffers for the chunk tensors (no per-step alloc).
    stage_k: Vec<f32>,
    stage_v: Vec<f32>,
}

impl PjrtModel {
    /// Load everything from an artifact directory (`make artifacts`).
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let runtime = PjrtRuntime::cpu()?;
        let manifest = Manifest::load(dir)?;
        let raw = manifest.load_weights()?;
        let mut weights = Vec::with_capacity(raw.len());
        for (spec, data) in manifest.weights.iter().zip(&raw) {
            let dims: Vec<i64> = spec.shape.iter().map(|&x| x as i64).collect();
            weights.push(f32_literal(data, &dims)?);
        }
        let mut decode_exes = Vec::new();
        let mut max_chunks = 0;
        let mut chunk_size = 0;
        for a in &manifest.artifacts {
            if a.kind == super::manifest::ArtifactKind::Decode {
                let exe = runtime.load_hlo_text(&dir.join(&a.file))?;
                decode_exes.push((a.batch, exe));
                max_chunks = a.max_chunks;
                chunk_size = a.chunk_size;
            }
        }
        decode_exes.sort_by_key(|(b, _)| *b);
        anyhow::ensure!(!decode_exes.is_empty(), "no decode artifacts in manifest");
        let pf = manifest
            .prefill_artifact()
            .ok_or_else(|| anyhow::anyhow!("no prefill artifact"))?
            .clone();
        let prefill_exe = runtime.load_hlo_text(&dir.join(&pf.file))?;
        let h_total = manifest.heads_total;
        let d = manifest.model.head_dim;
        let stage = max_chunks * h_total * chunk_size * d;
        Ok(PjrtModel {
            runtime,
            manifest,
            weights,
            decode_exes,
            prefill_exe,
            max_chunks,
            chunk_size,
            max_suffix: pf.max_suffix,
            max_prefix: pf.max_prefix,
            stage_k: vec![0.0; stage],
            stage_v: vec![0.0; stage],
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The chunk size the engine must be configured with.
    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    /// Largest decode batch the artifacts support.
    pub fn max_batch(&self) -> usize {
        self.decode_exes.last().map(|(b, _)| *b).unwrap_or(0)
    }

    fn weight_refs(&self) -> Vec<&Literal> {
        self.weights.iter().collect()
    }

    fn argmax(logits: &[f32]) -> u32 {
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        best as u32
    }

    /// Pack the tree context into the fixed chunk tensors. Returns the
    /// metadata arrays (padded to `max_chunks`).
    fn pack_context(
        &mut self,
        tree: &PrefixTree,
        ctx: &TreeContext,
    ) -> anyhow::Result<(Vec<i32>, Vec<i32>, Vec<i32>)> {
        let shape = tree.shape();
        anyhow::ensure!(
            shape.chunk_size == self.chunk_size && shape.heads == self.manifest.heads_total,
            "tree shape {shape:?} incompatible with artifacts (c={}, H={})",
            self.chunk_size,
            self.manifest.heads_total
        );
        anyhow::ensure!(
            ctx.entries.len() <= self.max_chunks,
            "live context has {} chunks; artifacts support {} — lower max_batch or prompt \
             lengths, or re-export with a larger MAX_CHUNKS",
            ctx.entries.len(),
            self.max_chunks
        );
        let per_chunk = shape.heads * shape.chunk_size * shape.head_dim;
        self.stage_k.fill(0.0); // padding chunks must be deterministic
        self.stage_v.fill(0.0);
        let (mut starts, mut ends, mut lens) =
            (vec![0i32; self.max_chunks], vec![0i32; self.max_chunks], vec![0i32; self.max_chunks]);
        for (i, e) in ctx.entries.iter().enumerate() {
            let chunk = tree.chunk(e.chunk);
            // Widen from the tree's storage dtype into the f32 device
            // staging tensors.
            chunk.k_slab().read_f32(0, &mut self.stage_k[i * per_chunk..(i + 1) * per_chunk]);
            chunk.v_slab().read_f32(0, &mut self.stage_v[i * per_chunk..(i + 1) * per_chunk]);
            starts[i] = e.start as i32;
            ends[i] = e.end as i32;
            lens[i] = chunk.len() as i32;
        }
        Ok((starts, ends, lens))
    }
}

impl ModelRunner for PjrtModel {
    fn heads_total(&self) -> usize {
        self.manifest.heads_total
    }

    fn head_dim(&self) -> usize {
        self.manifest.model.head_dim
    }

    fn prefill(
        &mut self,
        suffix_tokens: &[u32],
        pos_offset: usize,
        prefix_k: &[f32],
        prefix_v: &[f32],
        prefix_len: usize,
        is_final: bool,
    ) -> anyhow::Result<PrefillOutput> {
        let (p, n) = (self.max_suffix, self.max_prefix);
        let (h_total, d) = (self.manifest.heads_total, self.manifest.model.head_dim);
        anyhow::ensure!(
            suffix_tokens.len() <= p,
            "prompt suffix {} exceeds artifact capacity {p}",
            suffix_tokens.len()
        );
        anyhow::ensure!(prefix_len <= n, "prefix {prefix_len} exceeds artifact capacity {n}");

        let mut tokens = vec![0i32; p];
        for (i, &t) in suffix_tokens.iter().enumerate() {
            tokens[i] = t as i32;
        }
        // Pad the dense prefix KV ([H, prefix_len, d] → [H, n, d]).
        let mut pk = vec![0.0f32; h_total * n * d];
        let mut pv = vec![0.0f32; h_total * n * d];
        for h in 0..h_total {
            let src = h * prefix_len * d;
            let dst = h * n * d;
            pk[dst..dst + prefix_len * d].copy_from_slice(&prefix_k[src..src + prefix_len * d]);
            pv[dst..dst + prefix_len * d].copy_from_slice(&prefix_v[src..src + prefix_len * d]);
        }

        let tokens_l = i32_literal(&tokens, &[p as i64])?;
        let slen_l = i32_scalar(suffix_tokens.len() as i32);
        let pk_l = f32_literal(&pk, &[h_total as i64, n as i64, d as i64])?;
        let pv_l = f32_literal(&pv, &[h_total as i64, n as i64, d as i64])?;
        let plen_l = i32_scalar(prefix_len as i32);
        anyhow::ensure!(pos_offset == prefix_len, "positions start at the cached prefix length");

        let mut args = self.weight_refs();
        args.extend([&tokens_l, &slen_l, &pk_l, &pv_l, &plen_l]);
        let out = self.runtime.execute(&self.prefill_exe, &args)?;
        anyhow::ensure!(out.len() == 3, "prefill returns (logits, k, v), got {}", out.len());
        let logits = out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let k_flat = out[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let v_flat = out[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        // k_flat is [P, H, d]; keep only the valid suffix rows.
        let row = h_total * d;
        let k_rows: Vec<Vec<f32>> =
            (0..suffix_tokens.len()).map(|i| k_flat[i * row..(i + 1) * row].to_vec()).collect();
        let v_rows: Vec<Vec<f32>> =
            (0..suffix_tokens.len()).map(|i| v_flat[i * row..(i + 1) * row].to_vec()).collect();
        // The AOT prefill artifact always computes last-position logits;
        // the argmax is only meaningful (and only consumed) on the slice
        // that contains the true last prompt position.
        let next_token = is_final.then(|| Self::argmax(&logits));
        Ok(PrefillOutput { k_rows, v_rows, next_token })
    }

    fn decode(
        &mut self,
        tree: &PrefixTree,
        ctx: &TreeContext,
        last_tokens: &[u32],
        positions: &[usize],
    ) -> anyhow::Result<DecodeOutput> {
        let b = ctx.seq_order.len();
        let cap = self
            .decode_exes
            .iter()
            .map(|(c, _)| *c)
            .find(|&c| c >= b)
            .ok_or_else(|| anyhow::anyhow!("batch {b} exceeds artifact capacity"))?;
        let (h_total, d) = (self.manifest.heads_total, self.manifest.model.head_dim);
        let (starts, ends, lens) = self.pack_context(tree, ctx)?;

        let mut tokens = vec![0i32; cap];
        let mut pos = vec![0i32; cap];
        for i in 0..b {
            tokens[i] = last_tokens[i] as i32;
            pos[i] = positions[i] as i32;
        }
        let m = self.max_chunks as i64;
        let tokens_l = i32_literal(&tokens, &[cap as i64])?;
        let pos_l = i32_literal(&pos, &[cap as i64])?;
        let kc_l = f32_literal(&self.stage_k, &[m, h_total as i64, self.chunk_size as i64, d as i64])?;
        let vc_l = f32_literal(&self.stage_v, &[m, h_total as i64, self.chunk_size as i64, d as i64])?;
        let st_l = i32_literal(&starts, &[m])?;
        let en_l = i32_literal(&ends, &[m])?;
        let ln_l = i32_literal(&lens, &[m])?;

        let exe = &self.decode_exes.iter().find(|(c, _)| *c == cap).unwrap().1;
        let mut args = self.weight_refs();
        args.extend([&tokens_l, &pos_l, &kc_l, &vc_l, &st_l, &en_l, &ln_l]);
        let out = self.runtime.execute(exe, &args)?;
        anyhow::ensure!(out.len() == 3, "decode returns (logits, k, v), got {}", out.len());
        let logits = out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let k_flat = out[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let v_flat = out[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;

        let vocab = self.manifest.model.vocab;
        let row = h_total * d;
        let mut result = DecodeOutput {
            next_tokens: Vec::with_capacity(b),
            k_rows: Vec::with_capacity(b),
            v_rows: Vec::with_capacity(b),
        };
        for i in 0..b {
            result.next_tokens.push(Self::argmax(&logits[i * vocab..(i + 1) * vocab]));
            result.k_rows.push(k_flat[i * row..(i + 1) * row].to_vec());
            result.v_rows.push(v_flat[i * row..(i + 1) * row].to_vec());
        }
        Ok(result)
    }
}
