//! Monolithic dense KV cache — the layout the Naive / xformers / FlashAttn
//! baselines in Table 3 operate on.
//!
//! Every sequence owns a contiguous `[heads, capacity, head_dim]` K and V
//! slab stored at the shape's dtype. There is no sharing: two sequences
//! with identical prompts store two physical copies, exactly like stock
//! `past_key_values` serving. Using the same [`KvSlab`] storage as the
//! prefix tree keeps the Table 3/4 layout comparison fair at every dtype.

use std::collections::BTreeMap;

use super::chunk::KvShape;
use super::dtype::{KvElem, KvSlab};
use super::tree::SeqId;

/// One sequence's dense K/V slabs.
#[derive(Debug)]
pub struct DenseSeq {
    /// `[heads, capacity, head_dim]` elements.
    pub k: KvSlab,
    pub v: KvSlab,
    pub len: usize,
    pub capacity: usize,
}

impl DenseSeq {
    /// K rows for one head: typed `[len, head_dim]` slice (`E` must match
    /// the cache dtype; kernels dispatch once per call).
    #[inline]
    pub fn k_head<E: KvElem>(&self, shape: &KvShape, head: usize) -> &[E] {
        let stride = self.capacity * shape.head_dim;
        &self.k.as_slice::<E>()[head * stride..head * stride + self.len * shape.head_dim]
    }

    #[inline]
    pub fn v_head<E: KvElem>(&self, shape: &KvShape, head: usize) -> &[E] {
        let stride = self.capacity * shape.head_dim;
        &self.v.as_slice::<E>()[head * stride..head * stride + self.len * shape.head_dim]
    }

    /// Dequant scale of head `head`'s K rows (1.0 for float dtypes; the
    /// slabs are grouped one scale per head, so the group index is the
    /// head index).
    #[inline]
    pub fn k_head_scale(&self, _shape: &KvShape, head: usize) -> f32 {
        self.k.group_scale(head)
    }

    #[inline]
    pub fn v_head_scale(&self, _shape: &KvShape, head: usize) -> f32 {
        self.v.group_scale(head)
    }
}

/// Dense per-sequence KV cache manager.
pub struct MonolithicKvCache {
    shape: KvShape,
    seqs: BTreeMap<SeqId, DenseSeq>,
    peak_tokens: usize,
}

impl MonolithicKvCache {
    pub fn new(shape: KvShape) -> Self {
        MonolithicKvCache { shape, seqs: BTreeMap::new(), peak_tokens: 0 }
    }

    pub fn shape(&self) -> KvShape {
        self.shape
    }

    /// Admit a sequence with room for `capacity` tokens; fill the first
    /// `tokens.len()` positions via `fill(pos, token, k_row, v_row)`.
    pub fn insert_sequence(
        &mut self,
        seq: SeqId,
        tokens: &[u32],
        capacity: usize,
        fill: &mut dyn FnMut(usize, u32, &mut [f32], &mut [f32]),
    ) {
        assert!(!self.seqs.contains_key(&seq));
        assert!(tokens.len() <= capacity);
        let hd = self.shape.heads * self.shape.head_dim;
        let elems = self.shape.heads * capacity * self.shape.head_dim;
        // One int8 scale group per head (the per-head stride), matching the
        // chunk layout's grouping so head slices share a dequant scale.
        let mut k = KvSlab::zeroed_grouped(self.shape.dtype, elems, capacity * self.shape.head_dim);
        let mut v = KvSlab::zeroed_grouped(self.shape.dtype, elems, capacity * self.shape.head_dim);
        let mut k_row = vec![0.0f32; hd];
        let mut v_row = vec![0.0f32; hd];
        for (pos, &t) in tokens.iter().enumerate() {
            fill(pos, t, &mut k_row, &mut v_row);
            scatter_row(&self.shape, &mut k, &mut v, capacity, pos, &k_row, &v_row);
        }
        self.seqs.insert(seq, DenseSeq { k, v, len: tokens.len(), capacity });
        self.update_peak();
    }

    pub fn append_token(&mut self, seq: SeqId, k_rows: &[f32], v_rows: &[f32]) {
        let shape = self.shape;
        let s = self.seqs.get_mut(&seq).expect("unknown sequence");
        assert!(s.len < s.capacity, "sequence over capacity");
        let pos = s.len;
        let cap = s.capacity;
        scatter_row(&shape, &mut s.k, &mut s.v, cap, pos, k_rows, v_rows);
        s.len += 1;
        self.update_peak();
    }

    pub fn remove_sequence(&mut self, seq: SeqId) {
        self.seqs.remove(&seq).expect("unknown sequence");
    }

    pub fn get(&self, seq: SeqId) -> Option<&DenseSeq> {
        self.seqs.get(&seq)
    }

    pub fn num_sequences(&self) -> usize {
        self.seqs.len()
    }

    pub fn seq_ids(&self) -> impl Iterator<Item = SeqId> + '_ {
        self.seqs.keys().copied()
    }

    fn update_peak(&mut self) {
        // Monolithic allocates capacity up front; count capacity like real
        // dense serving does (this is vLLM's motivating waste).
        let total: usize = self.seqs.values().map(|s| s.capacity).sum();
        self.peak_tokens = self.peak_tokens.max(total);
    }

    /// Bytes for `tokens` tokens at the cache's dtype (2 tensors).
    fn token_bytes(&self, tokens: usize) -> u64 {
        (tokens * self.shape.heads * self.shape.head_dim * 2 * self.shape.dtype.bytes()) as u64
    }

    /// Peak KV bytes as actually allocated at the cache's dtype.
    pub fn peak_bytes(&self) -> u64 {
        self.token_bytes(self.peak_tokens)
    }

    pub fn in_use_bytes(&self) -> u64 {
        let total: usize = self.seqs.values().map(|s| s.capacity).sum();
        self.token_bytes(total)
    }
}

#[inline]
fn scatter_row(
    shape: &KvShape,
    k: &mut KvSlab,
    v: &mut KvSlab,
    capacity: usize,
    pos: usize,
    k_rows: &[f32],
    v_rows: &[f32],
) {
    for h in 0..shape.heads {
        let dst = (h * capacity + pos) * shape.head_dim;
        let src = h * shape.head_dim;
        k.write_f32(dst, &k_rows[src..src + shape.head_dim]);
        v.write_f32(dst, &v_rows[src..src + shape.head_dim]);
    }
}

#[cfg(test)]
mod tests {
    use super::super::dtype::KvDtype;
    use super::*;

    fn fill(pos: usize, token: u32, k: &mut [f32], v: &mut [f32]) {
        k.fill(pos as f32 + token as f32 * 0.5);
        v.fill(-(pos as f32));
    }

    #[test]
    fn insert_and_read_back() {
        let shape = KvShape::new(2, 4, 8);
        let mut cache = MonolithicKvCache::new(shape);
        cache.insert_sequence(SeqId(1), &[10, 20, 30], 8, &mut fill);
        let s = cache.get(SeqId(1)).unwrap();
        assert_eq!(s.len, 3);
        let k0 = s.k_head::<f32>(&shape, 0);
        assert_eq!(k0.len(), 3 * 4);
        assert_eq!(k0[0], 0.0 + 10.0 * 0.5);
        assert_eq!(k0[4], 1.0 + 20.0 * 0.5);
    }

    #[test]
    fn append_extends() {
        let shape = KvShape::new(1, 2, 8);
        let mut cache = MonolithicKvCache::new(shape);
        cache.insert_sequence(SeqId(1), &[1], 4, &mut fill);
        cache.append_token(SeqId(1), &[9.0, 9.0], &[8.0, 8.0]);
        let s = cache.get(SeqId(1)).unwrap();
        assert_eq!(s.len, 2);
        assert_eq!(s.k_head::<f32>(&shape, 0)[2..4], [9.0, 9.0]);
    }

    #[test]
    #[should_panic(expected = "over capacity")]
    fn capacity_is_enforced() {
        let shape = KvShape::new(1, 2, 8);
        let mut cache = MonolithicKvCache::new(shape);
        cache.insert_sequence(SeqId(1), &[1], 1, &mut fill);
        cache.append_token(SeqId(1), &[0.0, 0.0], &[0.0, 0.0]);
    }

    #[test]
    fn no_sharing_between_identical_prompts() {
        let shape = KvShape::new(1, 2, 8);
        let mut cache = MonolithicKvCache::new(shape);
        cache.insert_sequence(SeqId(1), &[1, 2, 3], 4, &mut fill);
        cache.insert_sequence(SeqId(2), &[1, 2, 3], 4, &mut fill);
        // 2 sequences * 4 capacity * 1 head * 2 dim * 2 tensors * 4 bytes
        assert_eq!(cache.in_use_bytes(), 2 * 4 * 2 * 2 * 4);
    }

    #[test]
    fn half_precision_halves_the_accounting() {
        let shape = KvShape::new(1, 2, 8).with_dtype(KvDtype::F16);
        let mut cache = MonolithicKvCache::new(shape);
        cache.insert_sequence(SeqId(1), &[1, 2, 3], 4, &mut fill);
        assert_eq!(cache.in_use_bytes(), 4 * 2 * 2 * 2);
        // Values read back within f16 rounding.
        let s = cache.get(SeqId(1)).unwrap();
        let mut row = vec![0.0f32; 2];
        s.k.read_f32(0, &mut row);
        assert!((row[0] - 0.5).abs() < 1e-3);
    }

    #[test]
    fn peak_survives_removal() {
        let shape = KvShape::new(1, 2, 8);
        let mut cache = MonolithicKvCache::new(shape);
        cache.insert_sequence(SeqId(1), &[1], 16, &mut fill);
        cache.insert_sequence(SeqId(2), &[1], 16, &mut fill);
        let peak = cache.peak_bytes();
        cache.remove_sequence(SeqId(1));
        cache.remove_sequence(SeqId(2));
        assert_eq!(cache.peak_bytes(), peak);
        assert_eq!(cache.in_use_bytes(), 0);
    }
}
