//! Prefix retention with LRU eviction — the multi-tenant extension the
//! paper's §5 points at ("discover redundancy ... at runtime
//! automatically") taken one step further: keep *hot tenants'* system
//! prompt KV resident even when no live request references it, so the next
//! request of that tenant skips prefill entirely; evict the least recently
//! used retained prefix when the chunk budget is exceeded.
//!
//! Implemented without modifying the tree: a retained prefix is pinned by a
//! *pin sequence* (ids from a reserved high range) inserted over an
//! already-cached prefix. Evicting = removing the pin sequence; the tree's
//! normal refcounting then frees exactly the chunks nothing else uses.

use std::collections::BTreeMap;

use super::tree::{PrefixTree, SeqId};

/// Pin sequence ids live at the top of the id space; real request ids must
/// stay below this.
pub const PIN_ID_BASE: u64 = u64::MAX - (1 << 20);

#[derive(Debug, Clone)]
struct Pin {
    seq: SeqId,
    tokens: usize,
    last_used: u64,
}

/// LRU-retained prefixes over a [`PrefixTree`], bounded by a chunk budget.
pub struct PrefixRetainer {
    /// key: the pinned prefix tokens (exact match).
    pins: BTreeMap<Vec<u32>, Pin>,
    next_pin_id: u64,
    clock: u64,
    /// Max chunks the whole tree may keep in use before pins are evicted.
    budget_chunks: usize,
    /// Accumulated eviction-token credit (amortized eviction): the step
    /// planner grants an allowance per step while the tree is over
    /// budget; a pin is evicted only once the credit covers its token
    /// count, so per-step eviction work is bounded instead of bursting.
    evict_credit: u64,
    /// Tokens charged for pin eviction (granted allowances under a step
    /// budget; actual pin tokens when unbounded).
    eviction_tokens_total: u64,
    /// Chunks returned to the pool by pin eviction.
    evicted_chunks_total: u64,
    /// Pins evicted.
    evicted_pins_total: u64,
}

impl PrefixRetainer {
    pub fn new(budget_chunks: usize) -> Self {
        PrefixRetainer {
            pins: BTreeMap::new(),
            next_pin_id: PIN_ID_BASE,
            clock: 0,
            budget_chunks,
            evict_credit: 0,
            eviction_tokens_total: 0,
            evicted_chunks_total: 0,
            evicted_pins_total: 0,
        }
    }

    /// Cheap resident fast path: whether eviction work is needed at all.
    /// O(1) — a pool-counter compare — so callers can skip eviction (and
    /// any budget reservation for it) on the overwhelmingly common
    /// under-budget step.
    pub fn over_budget(&self, tree: &PrefixTree) -> bool {
        !self.pins.is_empty() && tree.pool().in_use() > self.budget_chunks
    }

    /// Tokens charged for pin eviction so far (`eviction_tokens_total`).
    pub fn eviction_tokens_total(&self) -> u64 {
        self.eviction_tokens_total
    }

    /// Chunks freed by pin eviction so far (`evicted_chunks_total`).
    pub fn evicted_chunks_total(&self) -> u64 {
        self.evicted_chunks_total
    }

    /// Pins evicted so far.
    pub fn evicted_pins_total(&self) -> u64 {
        self.evicted_pins_total
    }

    /// Configured chunk budget (crash recovery rebuilds the retainer with
    /// the same budget after a hard reset).
    pub fn budget_chunks(&self) -> usize {
        self.budget_chunks
    }

    pub fn pinned_count(&self) -> usize {
        self.pins.len()
    }

    /// Pin `prefix` so its KV survives its sequences. The prefix must be
    /// fully cached already (call right after inserting a request that
    /// carries it). Touches LRU state if already pinned. Returns whether a
    /// new pin was created.
    ///
    /// Pinning never evicts inline: a pin that pushes the tree over
    /// budget is paid off by the *caller's* next
    /// [`Self::enforce_budget_amortized`] call (the engine spends an
    /// eviction allowance every step), so activation cannot stall on a
    /// burst of tree work.
    pub fn pin(&mut self, tree: &mut PrefixTree, prefix: &[u32]) -> bool {
        self.clock += 1;
        if prefix.is_empty() {
            return false;
        }
        if let Some(pin) = self.pins.get_mut(prefix) {
            pin.last_used = self.clock;
            return false;
        }
        // Only pin prefixes whose KV is fully present; the pin's fill
        // callback must never run.
        if tree.match_prefix(prefix) < prefix.len() {
            return false;
        }
        let seq = SeqId(self.next_pin_id);
        self.next_pin_id += 1;
        tree.insert_sequence(seq, prefix, &mut |_, _, _, _| {
            unreachable!("pin over fully cached prefix never computes KV")
        });
        self.pins.insert(
            prefix.to_vec(),
            Pin { seq, tokens: prefix.len(), last_used: self.clock },
        );
        true
    }

    /// Record a cache hit on a pinned prefix (any request whose prompt
    /// starts with it), refreshing its LRU position.
    pub fn touch(&mut self, prompt: &[u32]) {
        self.clock += 1;
        let clock = self.clock;
        for (prefix, pin) in self.pins.iter_mut() {
            if prompt.len() >= prefix.len() && &prompt[..prefix.len()] == prefix.as_slice() {
                pin.last_used = clock;
            }
        }
    }

    /// Evict least-recently-used pins until the tree fits the budget —
    /// the unbounded (between-step burst) form, kept for [`Self::pin`]
    /// and offline callers. Returns how many pins were evicted.
    pub fn enforce_budget(&mut self, tree: &mut PrefixTree) -> usize {
        self.enforce_budget_amortized(tree, usize::MAX)
    }

    /// Amortized eviction: spend at most `grant_tokens` of eviction work
    /// this call. The grant accumulates as credit while the tree stays
    /// over budget, and an LRU pin is evicted once the credit covers its
    /// token count — so a large pinned prefix is paid off over several
    /// steps instead of stalling one (`usize::MAX` = unbounded, the
    /// historical burst). Starts with the cheap [`Self::over_budget`]
    /// fast path, so an under-budget step costs one counter compare.
    /// Returns how many pins were evicted.
    pub fn enforce_budget_amortized(&mut self, tree: &mut PrefixTree, grant_tokens: usize) -> usize {
        if !self.over_budget(tree) {
            // Balanced: drop any leftover credit so a later overload pays
            // its own way instead of drawing on stale grants.
            self.evict_credit = 0;
            return 0;
        }
        let bounded = grant_tokens != usize::MAX;
        if bounded {
            self.evict_credit = self.evict_credit.saturating_add(grant_tokens as u64);
            // Charged against the step budget whether or not a pin falls
            // this very step — the credit is the spend.
            self.eviction_tokens_total += grant_tokens as u64;
        }
        let mut evicted = 0;
        while tree.pool().in_use() > self.budget_chunks && !self.pins.is_empty() {
            let (lru_key, tokens) = self
                .pins
                .iter()
                .min_by_key(|(_, p)| p.last_used)
                .map(|(k, p)| (k.clone(), p.tokens as u64))
                .expect("non-empty");
            if bounded && self.evict_credit < tokens {
                break; // keep accruing credit next step
            }
            let before = tree.pool().in_use();
            let pin = self.pins.remove(&lru_key).expect("key just observed");
            tree.remove_sequence(pin.seq);
            if bounded {
                self.evict_credit -= tokens;
            } else {
                self.eviction_tokens_total += tokens;
            }
            self.evicted_chunks_total += before.saturating_sub(tree.pool().in_use()) as u64;
            self.evicted_pins_total += 1;
            evicted += 1;
        }
        if tree.pool().in_use() <= self.budget_chunks {
            self.evict_credit = 0;
        }
        evicted
    }

    /// Drop every pin (shutdown / tests).
    pub fn unpin_all(&mut self, tree: &mut PrefixTree) {
        for (_, pin) in std::mem::take(&mut self.pins) {
            tree.remove_sequence(pin.seq);
        }
    }

    /// Total tokens currently kept alive by pins.
    pub fn pinned_tokens(&self) -> usize {
        self.pins.values().map(|p| p.tokens).sum()
    }

    /// Per-pin residency for debug endpoints: `(prefix_tokens, tokens,
    /// lru_age)` per pin, LRU-hottest first. `lru_age` counts retainer
    /// clock ticks since the pin was last used (0 = touched most
    /// recently); the pin with the largest age falls first under budget
    /// pressure.
    pub fn pin_residency(&self) -> Vec<(usize, usize, u64)> {
        let mut rows: Vec<(usize, usize, u64)> = self
            .pins
            .iter()
            .map(|(prefix, p)| (prefix.len(), p.tokens, self.clock.saturating_sub(p.last_used)))
            .collect();
        rows.sort_by_key(|&(_, _, age)| age);
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvShape;

    fn fill(_p: usize, t: u32, k: &mut [f32], v: &mut [f32]) {
        k.fill(t as f32);
        v.fill(-(t as f32));
    }

    fn tree() -> PrefixTree {
        PrefixTree::new(KvShape::new(1, 2, 4))
    }

    #[test]
    fn retained_prefix_survives_sequence_departure() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(1000);
        let sys: Vec<u32> = (0..8).collect();
        let mut prompt = sys.clone();
        prompt.extend([100, 101]);
        t.insert_sequence(SeqId(1), &prompt, &mut fill);
        assert!(r.pin(&mut t, &sys));
        t.remove_sequence(SeqId(1));
        // The system prompt chunks are still resident...
        assert_eq!(t.match_prefix(&prompt), 8);
        assert_eq!(t.pool().in_use(), 2);
        // ...so a new request reuses them without recompute.
        let out = t.insert_sequence(SeqId(2), &prompt, &mut fill);
        assert_eq!(out.matched_tokens, 8);
        t.check_invariants().unwrap();
    }

    #[test]
    fn pin_requires_fully_cached_prefix() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(1000);
        assert!(!r.pin(&mut t, &[1, 2, 3]), "nothing cached yet");
        t.insert_sequence(SeqId(1), &[1, 2], &mut fill);
        assert!(!r.pin(&mut t, &[1, 2, 3]), "only a shorter prefix is cached");
        assert!(r.pin(&mut t, &[1, 2]));
    }

    #[test]
    fn lru_eviction_under_budget() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(4); // 4 chunks of 4 tokens
        // Three tenants, 8 tokens (2 chunks) each.
        for tenant in 0..3u32 {
            let sys: Vec<u32> = (0..8).map(|i| tenant * 100 + i).collect();
            t.insert_sequence(SeqId(tenant as u64), &sys, &mut fill);
            r.pin(&mut t, &sys);
            t.remove_sequence(SeqId(tenant as u64));
            r.enforce_budget(&mut t);
        }
        // Budget 4 chunks = 2 tenants; tenant 0 (LRU) must be gone.
        assert_eq!(r.pinned_count(), 2);
        assert!(t.pool().in_use() <= 4);
        assert_eq!(t.match_prefix(&(0..8).collect::<Vec<_>>()), 0, "tenant 0 evicted");
        assert_eq!(t.match_prefix(&(200..208).collect::<Vec<_>>()), 8, "tenant 2 retained");
        t.check_invariants().unwrap();
    }

    #[test]
    fn touch_refreshes_lru_order() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(4);
        let sys_a: Vec<u32> = (0..8).collect();
        let sys_b: Vec<u32> = (100..108).collect();
        t.insert_sequence(SeqId(1), &sys_a, &mut fill);
        r.pin(&mut t, &sys_a);
        t.remove_sequence(SeqId(1));
        t.insert_sequence(SeqId(2), &sys_b, &mut fill);
        r.pin(&mut t, &sys_b);
        t.remove_sequence(SeqId(2));
        // A is older, but a request touches it — B becomes LRU.
        let mut prompt_a = sys_a.clone();
        prompt_a.push(999);
        r.touch(&prompt_a);
        // Third tenant forces one eviction.
        let sys_c: Vec<u32> = (200..208).collect();
        t.insert_sequence(SeqId(3), &sys_c, &mut fill);
        r.pin(&mut t, &sys_c);
        t.remove_sequence(SeqId(3));
        r.enforce_budget(&mut t);
        assert_eq!(t.match_prefix(&sys_a), 8, "A retained (recently touched)");
        assert_eq!(t.match_prefix(&sys_b), 0, "B evicted");
    }

    #[test]
    fn amortized_eviction_pays_a_pin_off_over_several_grants() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(1); // over budget once anything pins
        let sys: Vec<u32> = (0..12).collect(); // 12-token pin, 3 chunks
        t.insert_sequence(SeqId(1), &sys, &mut fill);
        r.pin(&mut t, &sys);
        t.remove_sequence(SeqId(1));
        assert!(r.over_budget(&t));
        // 5-token grants: the 12-token pin needs ceil(12/5)=3 steps of
        // credit before it falls; each step is bounded work.
        assert_eq!(r.enforce_budget_amortized(&mut t, 5), 0, "credit 5 < 12");
        assert_eq!(r.enforce_budget_amortized(&mut t, 5), 0, "credit 10 < 12");
        assert_eq!(r.enforce_budget_amortized(&mut t, 5), 1, "credit 15 >= 12: evicted");
        assert_eq!(t.pool().in_use(), 0);
        assert_eq!(r.eviction_tokens_total(), 15, "every grant while over budget is charged");
        assert_eq!(r.evicted_chunks_total(), 3);
        assert_eq!(r.evicted_pins_total(), 1);
        // Balanced again: further calls are the O(1) fast path and charge
        // nothing.
        assert_eq!(r.enforce_budget_amortized(&mut t, 5), 0);
        assert_eq!(r.eviction_tokens_total(), 15);
    }

    #[test]
    fn under_budget_fast_path_charges_nothing() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(1000);
        let sys: Vec<u32> = (0..8).collect();
        t.insert_sequence(SeqId(1), &sys, &mut fill);
        r.pin(&mut t, &sys);
        assert!(!r.over_budget(&t));
        for _ in 0..10 {
            assert_eq!(r.enforce_budget_amortized(&mut t, 100), 0);
        }
        assert_eq!(r.eviction_tokens_total(), 0, "under-budget steps must not be charged");
        assert_eq!(r.evicted_chunks_total(), 0);
    }

    #[test]
    fn unpin_all_releases_everything() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(100);
        let sys: Vec<u32> = (0..12).collect();
        t.insert_sequence(SeqId(1), &sys, &mut fill);
        r.pin(&mut t, &sys);
        t.remove_sequence(SeqId(1));
        assert!(t.pool().in_use() > 0);
        r.unpin_all(&mut t);
        assert_eq!(t.pool().in_use(), 0);
        assert_eq!(r.pinned_tokens(), 0);
    }

    #[test]
    fn live_sequences_are_never_evicted() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(1); // absurdly small budget
        let sys: Vec<u32> = (0..8).collect();
        let mut prompt = sys.clone();
        prompt.extend([55, 56]);
        t.insert_sequence(SeqId(1), &prompt, &mut fill);
        r.pin(&mut t, &sys);
        // Budget enforcement may drop the pin, but the live sequence keeps
        // its chunks.
        r.enforce_budget(&mut t);
        let (_, _, tokens) = t.gather_dense(SeqId(1)).unwrap();
        assert_eq!(tokens, prompt);
        t.check_invariants().unwrap();
    }
}
