//! Prefix retention with LRU eviction and tiered cold storage — the
//! multi-tenant extension the paper's §5 points at ("discover redundancy
//! ... at runtime automatically") taken one step further: keep *hot
//! tenants'* system prompt KV resident even when no live request
//! references it, so the next request of that tenant skips prefill
//! entirely; evict the least recently used retained prefix when the chunk
//! budget is exceeded.
//!
//! Implemented without modifying the tree: a retained prefix is pinned by a
//! *pin sequence* (ids from a reserved high range) inserted over an
//! already-cached prefix. Evicting = removing the pin sequence; the tree's
//! normal refcounting then frees exactly the chunks nothing else uses.
//!
//! # Tiered retention
//!
//! Between "resident at full width" and "evicted" there are two cheaper
//! tiers. A pin cold past [`TieringConfig::demote_after`] LRU ticks
//! *demotes*: its K/V are snapshotted through the tree's f32 read path,
//! re-narrowed to int8 (one symmetric scale per head, the same layout
//! [`super::dtype::KvSlab`] uses), and the pin sequence is removed so the
//! tree chunks return to the pool. Past [`TieringConfig::spill_after`]
//! ticks the int8 copy moves to a spill file under
//! [`TieringConfig::spill_dir`] and leaves RSS entirely. On the next
//! prompt hit the engine calls [`PrefixRetainer::promote_for_prompt`]
//! *before* prefix matching, which re-inserts the dequantized rows, so the
//! kernel only ever sees hot, tree-resident chunks.
//!
//! Spill files are crash-safe by *recreation*, not by durability: a file
//! is written to a temp name and renamed into place (a torn write never
//! yields a parsable file), and a missing or corrupt file just means the
//! promotion fails and prefill recomputes the prefix — the same outcome as
//! an eviction.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use super::dtype::quantize_i8;
use super::tree::{PrefixTree, SeqId};
use crate::util::stats::LogHistogram;

/// Pin sequence ids live at the top of the id space; real request ids must
/// stay below this.
pub const PIN_ID_BASE: u64 = u64::MAX - (1 << 20);

/// Cold-prefix tiering thresholds. Ages are measured in retainer LRU
/// clock ticks (one tick per pin/touch, i.e. per admitted request that
/// interacts with the retainer); `0` disables that transition.
#[derive(Debug, Clone, Default)]
pub struct TieringConfig {
    /// Hot → int8-in-memory after this many ticks without a hit.
    pub demote_after: u64,
    /// Int8-in-memory → spill file after this many ticks without a hit.
    /// Requires `spill_dir`; ignored otherwise.
    pub spill_after: u64,
    /// Directory for spill files (`pin-<id>.kvq`). Created on first spill.
    pub spill_dir: Option<PathBuf>,
}

impl TieringConfig {
    pub fn enabled(&self) -> bool {
        self.demote_after > 0 || (self.spill_after > 0 && self.spill_dir.is_some())
    }
}

/// A demoted prefix's quantized KV snapshot: `[heads, tokens, head_dim]`
/// i8 codes with one symmetric dequant scale per head per tensor —
/// deliberately the same grouping the int8 [`super::dtype::KvSlab`] uses,
/// so demoting an int8 tree re-quantizes losslessly up to one rounding
/// step and demoting a float tree costs exactly one quantization.
#[derive(Debug, Clone)]
struct DemotedPrefix {
    heads: usize,
    head_dim: usize,
    k_q: Vec<i8>,
    v_q: Vec<i8>,
    k_scales: Vec<f32>,
    v_scales: Vec<f32>,
}

impl DemotedPrefix {
    fn bytes(&self) -> u64 {
        (self.k_q.len() + self.v_q.len() + 4 * (self.k_scales.len() + self.v_scales.len())) as u64
    }
}

#[derive(Debug, Clone)]
enum TierState {
    /// Full-width chunks resident in the tree, held by a pin sequence.
    Hot(SeqId),
    /// Int8 snapshot in memory; no tree chunks held.
    Int8Mem(DemotedPrefix),
    /// Int8 snapshot on disk; nothing resident.
    Spilled { path: PathBuf, bytes: u64 },
}

#[derive(Debug, Clone)]
struct Pin {
    /// Stable id for spill-file naming (survives re-promotion).
    id: u64,
    tokens: usize,
    last_used: u64,
    state: TierState,
}

/// LRU-retained prefixes over a [`PrefixTree`], bounded by a chunk budget,
/// with optional cold-prefix tiering (see module docs).
pub struct PrefixRetainer {
    /// key: the pinned prefix tokens (exact match).
    pins: BTreeMap<Vec<u32>, Pin>,
    next_pin_id: u64,
    clock: u64,
    /// Max chunks the whole tree may keep in use before pins are evicted.
    budget_chunks: usize,
    /// Accumulated eviction-token credit (amortized eviction): the step
    /// planner grants an allowance per step while the tree is over
    /// budget; a pin is evicted only once the credit covers its token
    /// count, so per-step eviction work is bounded instead of bursting.
    evict_credit: u64,
    /// Tokens charged for pin eviction (granted allowances under a step
    /// budget; actual pin tokens when unbounded).
    eviction_tokens_total: u64,
    /// Chunks returned to the pool by pin eviction.
    evicted_chunks_total: u64,
    /// Pins evicted.
    evicted_pins_total: u64,
    /// Tiering thresholds (disabled by default).
    tiering: TieringConfig,
    /// Pins currently in [`TierState::Hot`] — kept as a counter so
    /// [`Self::over_budget`] stays O(1).
    hot_pins: usize,
    promotions_total: u64,
    demotions_total: u64,
    spills_total: u64,
    spill_load_failures_total: u64,
    promote_s: LogHistogram,
    demote_s: LogHistogram,
}

impl PrefixRetainer {
    pub fn new(budget_chunks: usize) -> Self {
        PrefixRetainer {
            pins: BTreeMap::new(),
            next_pin_id: PIN_ID_BASE,
            clock: 0,
            budget_chunks,
            evict_credit: 0,
            eviction_tokens_total: 0,
            evicted_chunks_total: 0,
            evicted_pins_total: 0,
            tiering: TieringConfig::default(),
            hot_pins: 0,
            promotions_total: 0,
            demotions_total: 0,
            spills_total: 0,
            spill_load_failures_total: 0,
            promote_s: LogHistogram::time_seconds(),
            demote_s: LogHistogram::time_seconds(),
        }
    }

    /// Install tiering thresholds (crash recovery re-applies the same
    /// config after a hard reset).
    pub fn set_tiering(&mut self, cfg: TieringConfig) {
        self.tiering = cfg;
    }

    pub fn tiering(&self) -> &TieringConfig {
        &self.tiering
    }

    /// Cheap resident fast path: whether eviction work is needed at all.
    /// O(1) — a pool-counter compare — so callers can skip eviction (and
    /// any budget reservation for it) on the overwhelmingly common
    /// under-budget step. Only hot pins hold tree chunks, so only they
    /// count.
    pub fn over_budget(&self, tree: &PrefixTree) -> bool {
        self.hot_pins > 0 && tree.pool().in_use() > self.budget_chunks
    }

    /// Whether any pin is cold enough that [`Self::run_tiering`] would do
    /// work (ignores the in-flight guard, which needs the active prompt
    /// set). O(pins).
    pub fn tiering_pending(&self) -> bool {
        if !self.tiering.enabled() {
            return false;
        }
        let demote_after = self.tiering.demote_after;
        let spill_after = self.tiering.spill_after;
        let spill_ready = spill_after > 0 && self.tiering.spill_dir.is_some();
        self.pins.values().any(|p| {
            let age = self.clock.saturating_sub(p.last_used);
            match p.state {
                TierState::Hot(_) => demote_after > 0 && age >= demote_after,
                TierState::Int8Mem(_) => spill_ready && age >= spill_after,
                TierState::Spilled { .. } => false,
            }
        })
    }

    /// Tokens charged for pin eviction so far (`eviction_tokens_total`).
    pub fn eviction_tokens_total(&self) -> u64 {
        self.eviction_tokens_total
    }

    /// Chunks freed by pin eviction so far (`evicted_chunks_total`).
    pub fn evicted_chunks_total(&self) -> u64 {
        self.evicted_chunks_total
    }

    /// Pins evicted so far.
    pub fn evicted_pins_total(&self) -> u64 {
        self.evicted_pins_total
    }

    pub fn promotions_total(&self) -> u64 {
        self.promotions_total
    }

    pub fn demotions_total(&self) -> u64 {
        self.demotions_total
    }

    pub fn spills_total(&self) -> u64 {
        self.spills_total
    }

    pub fn spill_load_failures_total(&self) -> u64 {
        self.spill_load_failures_total
    }

    /// Promotion latency (seconds domain; includes spill-file load).
    pub fn promote_hist(&self) -> &LogHistogram {
        &self.promote_s
    }

    /// Demotion latency (seconds domain; includes quantize + spill write).
    pub fn demote_hist(&self) -> &LogHistogram {
        &self.demote_s
    }

    /// Pins per tier: `(hot, int8_mem, spilled)`.
    pub fn tier_counts(&self) -> (usize, usize, usize) {
        let mut int8 = 0;
        let mut spilled = 0;
        for p in self.pins.values() {
            match p.state {
                TierState::Hot(_) => {}
                TierState::Int8Mem(_) => int8 += 1,
                TierState::Spilled { .. } => spilled += 1,
            }
        }
        (self.hot_pins, int8, spilled)
    }

    /// Bytes retained per tier, labelled for the `/metrics` gauge family.
    /// Hot bytes are the pin-held tokens priced at the tree's storage
    /// dtype (chunk-granularity rounding and sharing with live sequences
    /// make the exact number a property of the tree, not the retainer).
    pub fn tier_bytes(&self, tree: &PrefixTree) -> [(&'static str, u64); 3] {
        let shape = tree.shape();
        let per_tok = (2 * shape.heads * shape.head_dim * shape.dtype.bytes()) as u64;
        let mut hot = 0u64;
        let mut int8 = 0u64;
        let mut spilled = 0u64;
        for p in self.pins.values() {
            match &p.state {
                TierState::Hot(_) => hot += p.tokens as u64 * per_tok,
                TierState::Int8Mem(dp) => int8 += dp.bytes(),
                TierState::Spilled { bytes, .. } => spilled += *bytes,
            }
        }
        [("hot", hot), ("int8", int8), ("spilled", spilled)]
    }

    /// Configured chunk budget (crash recovery rebuilds the retainer with
    /// the same budget after a hard reset).
    pub fn budget_chunks(&self) -> usize {
        self.budget_chunks
    }

    pub fn pinned_count(&self) -> usize {
        self.pins.len()
    }

    /// Pin `prefix` so its KV survives its sequences. The prefix must be
    /// fully cached already (call right after inserting a request that
    /// carries it). Touches LRU state if already pinned. Returns whether a
    /// new pin was created.
    ///
    /// Pinning never evicts inline: a pin that pushes the tree over
    /// budget is paid off by the *caller's* next
    /// [`Self::enforce_budget_amortized`] call (the engine spends an
    /// eviction allowance every step), so activation cannot stall on a
    /// burst of tree work.
    pub fn pin(&mut self, tree: &mut PrefixTree, prefix: &[u32]) -> bool {
        self.clock += 1;
        if prefix.is_empty() {
            return false;
        }
        if let Some(pin) = self.pins.get_mut(prefix) {
            pin.last_used = self.clock;
            // A demoted pin whose prefix the calling request just
            // recomputed can re-hot for free: the chunks are already in
            // the tree, so the pin sequence re-attaches without touching
            // the quantized copy's dequant path.
            let demoted = !matches!(pin.state, TierState::Hot(_));
            if demoted && tree.match_prefix(prefix) >= prefix.len() {
                let seq = SeqId(self.next_pin_id);
                self.next_pin_id += 1;
                tree.insert_sequence(seq, prefix, &mut |_, _, _, _| {
                    unreachable!("pin over fully cached prefix never computes KV")
                });
                let old = std::mem::replace(&mut pin.state, TierState::Hot(seq));
                if let TierState::Spilled { path, .. } = old {
                    let _ = std::fs::remove_file(path);
                }
                self.hot_pins += 1;
                self.promotions_total += 1;
            }
            return false;
        }
        // Only pin prefixes whose KV is fully present; the pin's fill
        // callback must never run.
        if tree.match_prefix(prefix) < prefix.len() {
            return false;
        }
        let seq = SeqId(self.next_pin_id);
        self.next_pin_id += 1;
        tree.insert_sequence(seq, prefix, &mut |_, _, _, _| {
            unreachable!("pin over fully cached prefix never computes KV")
        });
        self.pins.insert(
            prefix.to_vec(),
            Pin {
                id: seq.0,
                tokens: prefix.len(),
                last_used: self.clock,
                state: TierState::Hot(seq),
            },
        );
        self.hot_pins += 1;
        true
    }

    /// Record a cache hit on a pinned prefix (any request whose prompt
    /// starts with it), refreshing its LRU position.
    pub fn touch(&mut self, prompt: &[u32]) {
        self.clock += 1;
        let clock = self.clock;
        for (prefix, pin) in self.pins.iter_mut() {
            if prompt.len() >= prefix.len() && &prompt[..prefix.len()] == prefix.as_slice() {
                pin.last_used = clock;
            }
        }
    }

    /// Promote the longest demoted/spilled pinned prefix of `prompt` back
    /// into the tree, so the subsequent `match_prefix` at admission sees
    /// it and the kernel never touches a quantized-at-rest copy. Returns
    /// the number of tokens promoted (0 if nothing matched or the load
    /// failed — the caller's prefill then recomputes, same as a miss).
    ///
    /// Must run *before* prefix matching for the prompt: promotion is an
    /// `insert_sequence`, and insertion over an already-matched prefix is
    /// how the dequantized rows become visible to the matcher.
    pub fn promote_for_prompt(&mut self, tree: &mut PrefixTree, prompt: &[u32]) -> usize {
        if self.pins.len() == self.hot_pins {
            return 0; // everything hot — the common fast path
        }
        let key: Option<Vec<u32>> = self
            .pins
            .iter()
            .filter(|(prefix, pin)| {
                !matches!(pin.state, TierState::Hot(_))
                    && prompt.len() >= prefix.len()
                    && prompt[..prefix.len()] == prefix[..]
            })
            .max_by_key(|(prefix, _)| prefix.len())
            .map(|(prefix, _)| prefix.clone());
        let Some(key) = key else { return 0 };
        let start = Instant::now();
        let seq = SeqId(self.next_pin_id);
        self.next_pin_id += 1;
        let pin = self.pins.get_mut(&key).expect("key just observed");
        let dp = match std::mem::replace(&mut pin.state, TierState::Hot(seq)) {
            TierState::Hot(_) => unreachable!("filtered to non-hot above"),
            TierState::Int8Mem(dp) => dp,
            TierState::Spilled { path, .. } => match read_spill(&path, key.len()) {
                Some(dp) => {
                    let _ = std::fs::remove_file(&path);
                    dp
                }
                None => {
                    // Lost or corrupt spill file: the prefix is simply
                    // gone; drop the pin and let prefill recompute.
                    self.spill_load_failures_total += 1;
                    self.pins.remove(&key);
                    return 0;
                }
            },
        };
        let heads = dp.heads;
        let d = dp.head_dim;
        let n = key.len();
        tree.insert_sequence(seq, &key, &mut |pos, _t, k_out, v_out| {
            for h in 0..heads {
                let ks = dp.k_scales[h];
                let vs = dp.v_scales[h];
                let base = (h * n + pos) * d;
                for i in 0..d {
                    k_out[h * d + i] = dp.k_q[base + i] as f32 * ks;
                    v_out[h * d + i] = dp.v_q[base + i] as f32 * vs;
                }
            }
        });
        self.clock += 1;
        let clock = self.clock;
        let pin = self.pins.get_mut(&key).expect("still present");
        pin.last_used = clock;
        self.hot_pins += 1;
        self.promotions_total += 1;
        self.promote_s.record(start.elapsed().as_secs_f64());
        n
    }

    /// One tiering pass: demote hot pins cold past `demote_after`, spill
    /// int8 pins cold past `spill_after`. A pin whose prefix is a prefix
    /// of any prompt in `active_prompts` is skipped — its chunks are (or
    /// are about to be) referenced by a live sequence's tree context, and
    /// demotion mid-step would force a structural epoch bump under that
    /// sequence. Returns `(demoted, spilled)` counts.
    pub fn run_tiering(
        &mut self,
        tree: &mut PrefixTree,
        active_prompts: &[Vec<u32>],
    ) -> (usize, usize) {
        if !self.tiering.enabled() {
            return (0, 0);
        }
        let clock = self.clock;
        let spill_ready = self.tiering.spill_after > 0 && self.tiering.spill_dir.is_some();
        let mut demoted = 0;
        let mut spilled = 0;
        let keys: Vec<Vec<u32>> = self.pins.keys().cloned().collect();
        for key in keys {
            if active_prompts
                .iter()
                .any(|p| p.len() >= key.len() && p[..key.len()] == key[..])
            {
                continue; // in-flight guard: never demote under a live sequence
            }
            let Some(pin) = self.pins.get(&key) else { continue };
            let age = clock.saturating_sub(pin.last_used);
            if matches!(pin.state, TierState::Hot(_))
                && self.tiering.demote_after > 0
                && age >= self.tiering.demote_after
                && self.demote_to_int8(tree, &key)
            {
                demoted += 1;
            }
            let Some(pin) = self.pins.get(&key) else { continue };
            if matches!(pin.state, TierState::Int8Mem(_))
                && spill_ready
                && age >= self.tiering.spill_after
                && self.spill_to_disk(&key)
            {
                spilled += 1;
            }
        }
        (demoted, spilled)
    }

    /// Hot → int8-in-memory: snapshot the pin's KV through the tree's f32
    /// read path, quantize per head, and release the tree chunks.
    fn demote_to_int8(&mut self, tree: &mut PrefixTree, key: &[u32]) -> bool {
        let pin = self.pins.get(key).expect("caller checked");
        let TierState::Hot(seq) = pin.state else { return false };
        let start = Instant::now();
        let Some((k, v, _tokens)) = tree.gather_dense(seq) else { return false };
        let shape = tree.shape();
        let per_head = pin.tokens * shape.head_dim;
        let (k_q, k_scales) = quantize_head_major(&k, shape.heads, per_head);
        let (v_q, v_scales) = quantize_head_major(&v, shape.heads, per_head);
        tree.remove_sequence(seq);
        let pin = self.pins.get_mut(key).expect("still present");
        pin.state = TierState::Int8Mem(DemotedPrefix {
            heads: shape.heads,
            head_dim: shape.head_dim,
            k_q,
            v_q,
            k_scales,
            v_scales,
        });
        self.hot_pins -= 1;
        self.demotions_total += 1;
        self.demote_s.record(start.elapsed().as_secs_f64());
        true
    }

    /// Int8-in-memory → spill file. On any I/O failure the in-memory copy
    /// is kept (spilling is an optimization, never a correctness step).
    fn spill_to_disk(&mut self, key: &[u32]) -> bool {
        let Some(dir) = self.tiering.spill_dir.clone() else { return false };
        let pin = self.pins.get_mut(key).expect("caller checked");
        let TierState::Int8Mem(dp) = &pin.state else { return false };
        let start = Instant::now();
        let path = dir.join(format!("pin-{}.kvq", pin.id));
        match write_spill(&dir, &path, key.len(), dp) {
            Ok(bytes) => {
                pin.state = TierState::Spilled { path, bytes };
                self.spills_total += 1;
                self.demote_s.record(start.elapsed().as_secs_f64());
                true
            }
            Err(_) => false,
        }
    }

    /// Evict least-recently-used pins until the tree fits the budget —
    /// the unbounded (between-step burst) form, kept for [`Self::pin`]
    /// and offline callers. Returns how many pins were evicted.
    pub fn enforce_budget(&mut self, tree: &mut PrefixTree) -> usize {
        self.enforce_budget_amortized(tree, usize::MAX)
    }

    /// Amortized eviction: spend at most `grant_tokens` of eviction work
    /// this call. The grant accumulates as credit while the tree stays
    /// over budget, and an LRU pin is evicted once the credit covers its
    /// token count — so a large pinned prefix is paid off over several
    /// steps instead of stalling one (`usize::MAX` = unbounded, the
    /// historical burst). Starts with the cheap [`Self::over_budget`]
    /// fast path, so an under-budget step costs one counter compare.
    /// Demoted pins hold no tree chunks and are never budget-evicted.
    /// Returns how many pins were evicted.
    pub fn enforce_budget_amortized(&mut self, tree: &mut PrefixTree, grant_tokens: usize) -> usize {
        if !self.over_budget(tree) {
            // Balanced: drop any leftover credit so a later overload pays
            // its own way instead of drawing on stale grants.
            self.evict_credit = 0;
            return 0;
        }
        let bounded = grant_tokens != usize::MAX;
        if bounded {
            self.evict_credit = self.evict_credit.saturating_add(grant_tokens as u64);
            // Charged against the step budget whether or not a pin falls
            // this very step — the credit is the spend.
            self.eviction_tokens_total += grant_tokens as u64;
        }
        let mut evicted = 0;
        while tree.pool().in_use() > self.budget_chunks {
            let lru = self
                .pins
                .iter()
                .filter(|(_, p)| matches!(p.state, TierState::Hot(_)))
                .min_by_key(|(_, p)| p.last_used)
                .map(|(k, p)| (k.clone(), p.tokens as u64));
            let Some((lru_key, tokens)) = lru else { break };
            if bounded && self.evict_credit < tokens {
                break; // keep accruing credit next step
            }
            let before = tree.pool().in_use();
            let pin = self.pins.remove(&lru_key).expect("key just observed");
            if let TierState::Hot(seq) = pin.state {
                tree.remove_sequence(seq);
                self.hot_pins -= 1;
            }
            if bounded {
                self.evict_credit -= tokens;
            } else {
                self.eviction_tokens_total += tokens;
            }
            self.evicted_chunks_total += before.saturating_sub(tree.pool().in_use()) as u64;
            self.evicted_pins_total += 1;
            evicted += 1;
        }
        if tree.pool().in_use() <= self.budget_chunks {
            self.evict_credit = 0;
        }
        evicted
    }

    /// Drop every pin (shutdown / tests). Spill files are deleted.
    pub fn unpin_all(&mut self, tree: &mut PrefixTree) {
        for (_, pin) in std::mem::take(&mut self.pins) {
            match pin.state {
                TierState::Hot(seq) => tree.remove_sequence(seq),
                TierState::Int8Mem(_) => {}
                TierState::Spilled { path, .. } => {
                    let _ = std::fs::remove_file(path);
                }
            }
        }
        self.hot_pins = 0;
    }

    /// Total tokens currently kept alive by pins (all tiers).
    pub fn pinned_tokens(&self) -> usize {
        self.pins.values().map(|p| p.tokens).sum()
    }

    /// Per-pin residency for debug endpoints: `(prefix_tokens, tokens,
    /// lru_age, tier)` per pin, LRU-hottest first. `lru_age` counts
    /// retainer clock ticks since the pin was last used (0 = touched most
    /// recently); the pin with the largest age falls first under budget
    /// pressure.
    pub fn pin_residency(&self) -> Vec<(usize, usize, u64, &'static str)> {
        let mut rows: Vec<(usize, usize, u64, &'static str)> = self
            .pins
            .iter()
            .map(|(prefix, p)| {
                let tier = match p.state {
                    TierState::Hot(_) => "hot",
                    TierState::Int8Mem(_) => "int8",
                    TierState::Spilled { .. } => "spilled",
                };
                (prefix.len(), p.tokens, self.clock.saturating_sub(p.last_used), tier)
            })
            .collect();
        rows.sort_by_key(|&(_, _, age, _)| age);
        rows
    }
}

/// Quantize a `[heads, per_head]` f32 buffer to i8 with one symmetric
/// scale per head (`scale = max_abs / 127`, 0.0 for all-zero heads — the
/// same convention as [`super::dtype::KvSlab`]).
fn quantize_head_major(x: &[f32], heads: usize, per_head: usize) -> (Vec<i8>, Vec<f32>) {
    let mut q = vec![0i8; x.len()];
    let mut scales = vec![0.0f32; heads];
    for h in 0..heads {
        let sl = &x[h * per_head..(h + 1) * per_head];
        let max = sl.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = if max == 0.0 { 0.0 } else { max / 127.0 };
        scales[h] = scale;
        for (dst, &v) in q[h * per_head..(h + 1) * per_head].iter_mut().zip(sl) {
            *dst = quantize_i8(v, scale);
        }
    }
    (q, scales)
}

const SPILL_MAGIC: &[u8; 4] = b"KVQ1";

/// Write a spill file: temp-name + rename so a torn write never yields a
/// file that parses. Returns the file size in bytes.
fn write_spill(
    dir: &Path,
    path: &Path,
    tokens: usize,
    dp: &DemotedPrefix,
) -> std::io::Result<u64> {
    std::fs::create_dir_all(dir)?;
    let mut buf: Vec<u8> = Vec::with_capacity(dp.bytes() as usize + 16);
    buf.extend_from_slice(SPILL_MAGIC);
    buf.extend_from_slice(&(dp.heads as u32).to_le_bytes());
    buf.extend_from_slice(&(dp.head_dim as u32).to_le_bytes());
    buf.extend_from_slice(&(tokens as u32).to_le_bytes());
    for &s in dp.k_scales.iter().chain(dp.v_scales.iter()) {
        buf.extend_from_slice(&s.to_le_bytes());
    }
    buf.extend(dp.k_q.iter().map(|&b| b as u8));
    buf.extend(dp.v_q.iter().map(|&b| b as u8));
    let tmp = path.with_extension("kvq.tmp");
    std::fs::write(&tmp, &buf)?;
    std::fs::rename(&tmp, path)?;
    Ok(buf.len() as u64)
}

/// Read a spill file back; `None` on any shape/size mismatch or I/O error
/// (the caller treats that as a cache miss).
fn read_spill(path: &Path, tokens: usize) -> Option<DemotedPrefix> {
    let buf = std::fs::read(path).ok()?;
    if buf.len() < 16 || &buf[..4] != SPILL_MAGIC {
        return None;
    }
    let heads = u32::from_le_bytes(buf[4..8].try_into().ok()?) as usize;
    let head_dim = u32::from_le_bytes(buf[8..12].try_into().ok()?) as usize;
    let n = u32::from_le_bytes(buf[12..16].try_into().ok()?) as usize;
    if n != tokens {
        return None;
    }
    let elems = heads * n * head_dim;
    let scales_bytes = 2 * heads * 4;
    if buf.len() != 16 + scales_bytes + 2 * elems {
        return None;
    }
    let mut off = 16;
    let mut read_scales = |off: &mut usize| -> Vec<f32> {
        (0..heads)
            .map(|_| {
                let s = f32::from_le_bytes(buf[*off..*off + 4].try_into().unwrap());
                *off += 4;
                s
            })
            .collect()
    };
    let k_scales = read_scales(&mut off);
    let v_scales = read_scales(&mut off);
    let k_q: Vec<i8> = buf[off..off + elems].iter().map(|&b| b as i8).collect();
    let v_q: Vec<i8> = buf[off + elems..off + 2 * elems].iter().map(|&b| b as i8).collect();
    Some(DemotedPrefix { heads, head_dim, k_q, v_q, k_scales, v_scales })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvShape;

    fn fill(_p: usize, t: u32, k: &mut [f32], v: &mut [f32]) {
        k.fill(t as f32);
        v.fill(-(t as f32));
    }

    fn tree() -> PrefixTree {
        PrefixTree::new(KvShape::new(1, 2, 4))
    }

    #[test]
    fn retained_prefix_survives_sequence_departure() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(1000);
        let sys: Vec<u32> = (0..8).collect();
        let mut prompt = sys.clone();
        prompt.extend([100, 101]);
        t.insert_sequence(SeqId(1), &prompt, &mut fill);
        assert!(r.pin(&mut t, &sys));
        t.remove_sequence(SeqId(1));
        // The system prompt chunks are still resident...
        assert_eq!(t.match_prefix(&prompt), 8);
        assert_eq!(t.pool().in_use(), 2);
        // ...so a new request reuses them without recompute.
        let out = t.insert_sequence(SeqId(2), &prompt, &mut fill);
        assert_eq!(out.matched_tokens, 8);
        t.check_invariants().unwrap();
    }

    #[test]
    fn pin_requires_fully_cached_prefix() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(1000);
        assert!(!r.pin(&mut t, &[1, 2, 3]), "nothing cached yet");
        t.insert_sequence(SeqId(1), &[1, 2], &mut fill);
        assert!(!r.pin(&mut t, &[1, 2, 3]), "only a shorter prefix is cached");
        assert!(r.pin(&mut t, &[1, 2]));
    }

    #[test]
    fn lru_eviction_under_budget() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(4); // 4 chunks of 4 tokens
        // Three tenants, 8 tokens (2 chunks) each.
        for tenant in 0..3u32 {
            let sys: Vec<u32> = (0..8).map(|i| tenant * 100 + i).collect();
            t.insert_sequence(SeqId(tenant as u64), &sys, &mut fill);
            r.pin(&mut t, &sys);
            t.remove_sequence(SeqId(tenant as u64));
            r.enforce_budget(&mut t);
        }
        // Budget 4 chunks = 2 tenants; tenant 0 (LRU) must be gone.
        assert_eq!(r.pinned_count(), 2);
        assert!(t.pool().in_use() <= 4);
        assert_eq!(t.match_prefix(&(0..8).collect::<Vec<_>>()), 0, "tenant 0 evicted");
        assert_eq!(t.match_prefix(&(200..208).collect::<Vec<_>>()), 8, "tenant 2 retained");
        t.check_invariants().unwrap();
    }

    #[test]
    fn touch_refreshes_lru_order() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(4);
        let sys_a: Vec<u32> = (0..8).collect();
        let sys_b: Vec<u32> = (100..108).collect();
        t.insert_sequence(SeqId(1), &sys_a, &mut fill);
        r.pin(&mut t, &sys_a);
        t.remove_sequence(SeqId(1));
        t.insert_sequence(SeqId(2), &sys_b, &mut fill);
        r.pin(&mut t, &sys_b);
        t.remove_sequence(SeqId(2));
        // A is older, but a request touches it — B becomes LRU.
        let mut prompt_a = sys_a.clone();
        prompt_a.push(999);
        r.touch(&prompt_a);
        // Third tenant forces one eviction.
        let sys_c: Vec<u32> = (200..208).collect();
        t.insert_sequence(SeqId(3), &sys_c, &mut fill);
        r.pin(&mut t, &sys_c);
        t.remove_sequence(SeqId(3));
        r.enforce_budget(&mut t);
        assert_eq!(t.match_prefix(&sys_a), 8, "A retained (recently touched)");
        assert_eq!(t.match_prefix(&sys_b), 0, "B evicted");
    }

    #[test]
    fn amortized_eviction_pays_a_pin_off_over_several_grants() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(1); // over budget once anything pins
        let sys: Vec<u32> = (0..12).collect(); // 12-token pin, 3 chunks
        t.insert_sequence(SeqId(1), &sys, &mut fill);
        r.pin(&mut t, &sys);
        t.remove_sequence(SeqId(1));
        assert!(r.over_budget(&t));
        // 5-token grants: the 12-token pin needs ceil(12/5)=3 steps of
        // credit before it falls; each step is bounded work.
        assert_eq!(r.enforce_budget_amortized(&mut t, 5), 0, "credit 5 < 12");
        assert_eq!(r.enforce_budget_amortized(&mut t, 5), 0, "credit 10 < 12");
        assert_eq!(r.enforce_budget_amortized(&mut t, 5), 1, "credit 15 >= 12: evicted");
        assert_eq!(t.pool().in_use(), 0);
        assert_eq!(r.eviction_tokens_total(), 15, "every grant while over budget is charged");
        assert_eq!(r.evicted_chunks_total(), 3);
        assert_eq!(r.evicted_pins_total(), 1);
        // Balanced again: further calls are the O(1) fast path and charge
        // nothing.
        assert_eq!(r.enforce_budget_amortized(&mut t, 5), 0);
        assert_eq!(r.eviction_tokens_total(), 15);
    }

    #[test]
    fn under_budget_fast_path_charges_nothing() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(1000);
        let sys: Vec<u32> = (0..8).collect();
        t.insert_sequence(SeqId(1), &sys, &mut fill);
        r.pin(&mut t, &sys);
        assert!(!r.over_budget(&t));
        for _ in 0..10 {
            assert_eq!(r.enforce_budget_amortized(&mut t, 100), 0);
        }
        assert_eq!(r.eviction_tokens_total(), 0, "under-budget steps must not be charged");
        assert_eq!(r.evicted_chunks_total(), 0);
    }

    #[test]
    fn unpin_all_releases_everything() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(100);
        let sys: Vec<u32> = (0..12).collect();
        t.insert_sequence(SeqId(1), &sys, &mut fill);
        r.pin(&mut t, &sys);
        t.remove_sequence(SeqId(1));
        assert!(t.pool().in_use() > 0);
        r.unpin_all(&mut t);
        assert_eq!(t.pool().in_use(), 0);
        assert_eq!(r.pinned_tokens(), 0);
    }

    #[test]
    fn live_sequences_are_never_evicted() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(1); // absurdly small budget
        let sys: Vec<u32> = (0..8).collect();
        let mut prompt = sys.clone();
        prompt.extend([55, 56]);
        t.insert_sequence(SeqId(1), &prompt, &mut fill);
        r.pin(&mut t, &sys);
        // Budget enforcement may drop the pin, but the live sequence keeps
        // its chunks.
        r.enforce_budget(&mut t);
        let (_, _, tokens) = t.gather_dense(SeqId(1)).unwrap();
        assert_eq!(tokens, prompt);
        t.check_invariants().unwrap();
    }

    #[test]
    fn cold_pin_demotes_to_int8_and_promotes_on_hit() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(1000);
        r.set_tiering(TieringConfig { demote_after: 2, spill_after: 0, spill_dir: None });
        let sys: Vec<u32> = (0..8).collect();
        t.insert_sequence(SeqId(1), &sys, &mut fill);
        r.pin(&mut t, &sys);
        t.remove_sequence(SeqId(1));
        assert_eq!(t.pool().in_use(), 2);
        // Two unrelated requests age the pin past the threshold.
        r.touch(&[999]);
        r.touch(&[999]);
        assert!(r.tiering_pending());
        assert_eq!(r.run_tiering(&mut t, &[]), (1, 0));
        assert_eq!(t.pool().in_use(), 0, "demotion releases the tree chunks");
        assert_eq!(r.demotions_total(), 1);
        assert_eq!(r.tier_counts(), (0, 1, 0));
        assert!(r.tier_bytes(&t)[1].1 > 0, "int8 tier bytes are accounted");
        assert_eq!(t.match_prefix(&sys), 0, "nothing resident until promoted");
        // A prompt carrying the prefix promotes it back before matching.
        let mut prompt = sys.clone();
        prompt.push(100);
        assert_eq!(r.promote_for_prompt(&mut t, &prompt), 8);
        assert_eq!(r.promotions_total(), 1);
        assert_eq!(r.tier_counts(), (1, 0, 0));
        assert_eq!(t.match_prefix(&prompt), 8);
        assert!(r.promote_hist().total() >= 1);
        assert!(r.demote_hist().total() >= 1);
        // The restored values are the originals up to one int8 step per
        // head (scale = max_abs / 127).
        let out = t.insert_sequence(SeqId(2), &sys, &mut |_, _, _, _| {
            unreachable!("fully cached after promotion")
        });
        assert_eq!(out.matched_tokens, 8);
        let (k, v, toks) = t.gather_dense(SeqId(2)).unwrap();
        assert_eq!(toks, sys);
        let step = 7.0 / 127.0; // max |k| over the prefix is 7
        for (i, &x) in k.iter().enumerate() {
            let want = (i / 2) as f32; // head_dim = 2, k row = token value
            assert!((x - want).abs() <= 0.5 * step + 1e-6, "k[{i}] = {x}, want ~{want}");
        }
        for (i, &x) in v.iter().enumerate() {
            let want = -((i / 2) as f32);
            assert!((x - want).abs() <= 0.5 * step + 1e-6, "v[{i}] = {x}, want ~{want}");
        }
        t.check_invariants().unwrap();
    }

    #[test]
    fn very_cold_pin_spills_to_disk_and_promotes_back() {
        let dir = std::env::temp_dir().join(format!("kvspill-retain-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut t = tree();
        let mut r = PrefixRetainer::new(1000);
        r.set_tiering(TieringConfig {
            demote_after: 1,
            spill_after: 2,
            spill_dir: Some(dir.clone()),
        });
        let sys: Vec<u32> = (0..8).collect();
        t.insert_sequence(SeqId(1), &sys, &mut fill);
        r.pin(&mut t, &sys);
        t.remove_sequence(SeqId(1));
        r.touch(&[999]);
        r.touch(&[999]);
        // Age 2 clears both thresholds: one pass demotes and spills.
        assert_eq!(r.run_tiering(&mut t, &[]), (1, 1));
        assert_eq!(r.tier_counts(), (0, 0, 1));
        assert_eq!(r.spills_total(), 1);
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert_eq!(files.len(), 1, "one spill file per pin");
        assert!(r.tier_bytes(&t)[2].1 > 0, "spilled tier bytes are accounted");
        // Promotion loads the file, restores the tree, and removes it.
        assert_eq!(r.promote_for_prompt(&mut t, &sys), 8);
        assert_eq!(t.match_prefix(&sys), 8);
        assert_eq!(r.tier_counts(), (1, 0, 0));
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0, "spill file consumed");
        t.check_invariants().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lost_spill_file_degrades_to_a_cache_miss() {
        let dir = std::env::temp_dir().join(format!("kvspill-lost-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut t = tree();
        let mut r = PrefixRetainer::new(1000);
        r.set_tiering(TieringConfig {
            demote_after: 1,
            spill_after: 1,
            spill_dir: Some(dir.clone()),
        });
        let sys: Vec<u32> = (0..8).collect();
        t.insert_sequence(SeqId(1), &sys, &mut fill);
        r.pin(&mut t, &sys);
        t.remove_sequence(SeqId(1));
        r.touch(&[999]);
        assert_eq!(r.run_tiering(&mut t, &[]), (1, 1));
        // Crash-safety by recreation: losing the file loses only the
        // cached KV, never correctness.
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(r.promote_for_prompt(&mut t, &sys), 0);
        assert_eq!(r.spill_load_failures_total(), 1);
        assert_eq!(r.pinned_count(), 0, "unloadable pin is dropped");
        assert_eq!(t.match_prefix(&sys), 0, "prefill recomputes from scratch");
    }

    #[test]
    fn demotion_skips_prefixes_of_in_flight_prompts() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(1000);
        r.set_tiering(TieringConfig { demote_after: 1, spill_after: 0, spill_dir: None });
        let sys: Vec<u32> = (0..8).collect();
        let mut prompt = sys.clone();
        prompt.extend([55, 56]);
        t.insert_sequence(SeqId(1), &prompt, &mut fill);
        r.pin(&mut t, &sys);
        r.touch(&[999]);
        r.touch(&[999]);
        // The pin is cold, but its prefix is under a live sequence: the
        // guard must keep it hot so the in-flight tree context is never
        // invalidated by a demotion.
        assert_eq!(r.run_tiering(&mut t, &[prompt.clone()]), (0, 0));
        assert_eq!(r.tier_counts(), (1, 0, 0));
        // Once the sequence departs, the same pass demotes it.
        t.remove_sequence(SeqId(1));
        assert_eq!(r.run_tiering(&mut t, &[]), (1, 0));
        assert_eq!(r.tier_counts(), (0, 1, 0));
    }

    #[test]
    fn budget_eviction_ignores_demoted_pins() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(1);
        r.set_tiering(TieringConfig { demote_after: 1, spill_after: 0, spill_dir: None });
        let sys: Vec<u32> = (0..8).collect();
        t.insert_sequence(SeqId(1), &sys, &mut fill);
        r.pin(&mut t, &sys);
        t.remove_sequence(SeqId(1));
        r.touch(&[999]);
        assert_eq!(r.run_tiering(&mut t, &[]), (1, 0));
        // A live sequence pushes the pool over budget, but no hot pin
        // exists: the fast path must not charge grants it can never spend.
        t.insert_sequence(SeqId(2), &(100..112).collect::<Vec<_>>(), &mut fill);
        assert!(t.pool().in_use() > 1);
        assert!(!r.over_budget(&t));
        assert_eq!(r.enforce_budget_amortized(&mut t, 100), 0);
        assert_eq!(r.eviction_tokens_total(), 0);
        assert_eq!(r.pinned_count(), 1, "the demoted pin survives");
    }

    #[test]
    fn repin_of_a_demoted_prefix_rehots_in_place() {
        let mut t = tree();
        let mut r = PrefixRetainer::new(1000);
        r.set_tiering(TieringConfig { demote_after: 1, spill_after: 0, spill_dir: None });
        let sys: Vec<u32> = (0..8).collect();
        t.insert_sequence(SeqId(1), &sys, &mut fill);
        r.pin(&mut t, &sys);
        t.remove_sequence(SeqId(1));
        r.touch(&[999]);
        assert_eq!(r.run_tiering(&mut t, &[]), (1, 0));
        // A request recomputes the prefix (promotion was skipped, e.g.
        // tiering raced admission); pinning again re-attaches over the
        // freshly cached chunks and drops the stale int8 copy.
        t.insert_sequence(SeqId(2), &sys, &mut fill);
        assert!(!r.pin(&mut t, &sys), "existing pin, not a new one");
        assert_eq!(r.tier_counts(), (1, 0, 0));
        t.remove_sequence(SeqId(2));
        assert_eq!(t.match_prefix(&sys), 8, "pin holds the chunks again");
        t.check_invariants().unwrap();
    }
}
