//! The prefix tree over KV chunks — the paper's PAKV contribution (§3.1).
//!
//! Each node owns one [`Chunk`]; each root-to-leaf path spells a sequence's
//! token prefix. Sequences whose prompts share a prefix share the nodes (and
//! therefore the physical K/V memory) of that prefix. The tree supports the
//! three runtime events of §3.1 — sequence join, sequence leave, and
//! decode-append — plus mid-chunk *splitting* so that prompts diverging in
//! the middle of a chunk still share the common part.
//!
//! The kernel-facing view is a [`TreeContext`] (§3.3 "context"): a
//! topologically ordered list of `(chunk, start_seq, end_seq)` entries where
//! the covered sequences of every chunk form a contiguous interval of the
//! DFS sequence order — the key property that lets the chunk-first kernel
//! slice the query tensor. Context generation is *lazy*: it is cached and
//! only rebuilt when the tree structure changes (chunk filled, join, leave),
//! mirroring the paper's lazy context copy.

use std::collections::BTreeMap;

use super::chunk::{Chunk, ChunkId, ChunkPool, KvShape};

/// Sequence identifier assigned by the caller (request id).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SeqId(pub u64);

/// Handle to a tree node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NodeId(u32);

#[derive(Debug)]
struct Node {
    chunk: ChunkId,
    parent: Option<NodeId>,
    children: Vec<NodeId>,
    /// Number of live sequences whose path passes through this node.
    nseqs: usize,
    /// Number of live sequences terminating exactly here.
    nterm: usize,
}

#[derive(Debug)]
enum Slot {
    Used(Node),
    Free,
}

#[derive(Debug, Clone)]
struct SeqInfo {
    leaf: NodeId,
    /// Total logical tokens of the sequence.
    len: usize,
}

/// Kernel-facing context entry: one chunk and the contiguous interval
/// `[start, end)` of sequence rows it covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CtxEntry {
    pub node: NodeId,
    pub chunk: ChunkId,
    pub start: usize,
    pub end: usize,
}

impl CtxEntry {
    /// Shared chunks (covering >1 sequence) go to the chunk-first phase.
    pub fn is_shared(&self) -> bool {
        self.end - self.start > 1
    }
}

/// Cached, topologically ordered tree context (§3.3).
#[derive(Debug, Clone, Default)]
pub struct TreeContext {
    /// DFS order of live sequences; row `r` of the query matrix belongs to
    /// `seq_order[r]`.
    pub seq_order: Vec<SeqId>,
    /// All chunks in parent-before-child order with covered intervals.
    pub entries: Vec<CtxEntry>,
}

impl TreeContext {
    /// Entries shared by more than one sequence (chunk-first phase input).
    pub fn shared(&self) -> impl Iterator<Item = &CtxEntry> {
        self.entries.iter().filter(|e| e.is_shared())
    }

    /// Entries private to exactly one sequence (sequence-first phase input).
    pub fn private(&self) -> impl Iterator<Item = &CtxEntry> {
        self.entries.iter().filter(|e| !e.is_shared())
    }

    /// Row index of a sequence in the query matrix.
    pub fn row_of(&self, seq: SeqId) -> Option<usize> {
        self.seq_order.iter().position(|&s| s == seq)
    }
}

/// Outcome of inserting a sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InsertOutcome {
    /// Tokens whose K/V were found in the tree (no recomputation needed).
    pub matched_tokens: usize,
    /// Total tokens inserted (== prompt length).
    pub total_tokens: usize,
}

/// Callback that produces the K/V rows for one token position.
/// Arguments: `(position_in_sequence, token, k_out, v_out)` where the output
/// slices are `[heads * head_dim]`.
pub type KvFill<'a> = &'a mut dyn FnMut(usize, u32, &mut [f32], &mut [f32]);

/// Prefix tree KV cache (a forest: one root per distinct first chunk).
pub struct PrefixTree {
    pool: ChunkPool,
    slots: Vec<Slot>,
    free_slots: Vec<NodeId>,
    roots: Vec<NodeId>,
    seqs: BTreeMap<SeqId, SeqInfo>,
    /// Bumped on every structural change; invalidates the cached context.
    epoch: u64,
    ctx_cache: Option<(u64, TreeContext)>,
    /// Lazy-context statistics for the ablation bench.
    ctx_rebuilds: u64,
    ctx_hits: u64,
    /// When false, the context is rebuilt on every call (ablation baseline).
    pub lazy_context: bool,
}

impl PrefixTree {
    pub fn new(shape: KvShape) -> Self {
        PrefixTree {
            pool: ChunkPool::new(shape),
            slots: Vec::new(),
            free_slots: Vec::new(),
            roots: Vec::new(),
            seqs: BTreeMap::new(),
            epoch: 0,
            ctx_cache: None,
            ctx_rebuilds: 0,
            ctx_hits: 0,
            lazy_context: true,
        }
    }

    pub fn shape(&self) -> KvShape {
        self.pool.shape()
    }

    pub fn pool(&self) -> &ChunkPool {
        &self.pool
    }

    pub fn chunk(&self, id: ChunkId) -> &Chunk {
        self.pool.get(id)
    }

    pub fn num_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// Ids of every resident sequence (including retention pins). Crash
    /// recovery diffs this against the scheduler's view to find residency
    /// orphaned by a panic that unwound out of a partial step.
    pub fn sequence_ids(&self) -> Vec<SeqId> {
        self.seqs.keys().copied().collect()
    }

    pub fn sequence_len(&self, seq: SeqId) -> Option<usize> {
        self.seqs.get(&seq).map(|s| s.len)
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Topology generation counter: bumped on every structural change
    /// (join, leave, chunk fill/fork, split) and *not* on in-place tail
    /// appends. A caller holding a [`TreeContext`] built at generation `g`
    /// may keep using it — without calling [`PrefixTree::context`] at all —
    /// for as long as `generation()` still returns `g`; the engine uses
    /// this to skip the per-step context fetch on the decode hot loop.
    pub fn generation(&self) -> u64 {
        self.epoch
    }

    pub fn context_stats(&self) -> (u64, u64) {
        (self.ctx_rebuilds, self.ctx_hits)
    }

    fn node(&self, id: NodeId) -> &Node {
        match &self.slots[id.0 as usize] {
            Slot::Used(n) => n,
            Slot::Free => panic!("dangling node {id:?}"),
        }
    }

    fn node_mut(&mut self, id: NodeId) -> &mut Node {
        match &mut self.slots[id.0 as usize] {
            Slot::Used(n) => n,
            Slot::Free => panic!("dangling node {id:?}"),
        }
    }

    fn new_node(&mut self, parent: Option<NodeId>) -> NodeId {
        let chunk = self.pool.acquire();
        let node = Node { chunk, parent, children: Vec::new(), nseqs: 0, nterm: 0 };
        match self.free_slots.pop() {
            Some(id) => {
                self.slots[id.0 as usize] = Slot::Used(node);
                id
            }
            None => {
                let id = NodeId(self.slots.len() as u32);
                self.slots.push(Slot::Used(node));
                id
            }
        }
    }

    fn free_node(&mut self, id: NodeId) {
        let chunk = self.node(id).chunk;
        self.pool.release(chunk);
        self.slots[id.0 as usize] = Slot::Free;
        self.free_slots.push(id);
    }

    fn bump_epoch(&mut self) {
        self.epoch += 1;
    }

    /// How many leading tokens of `tokens` are already cached (read-only).
    /// The engine uses this to know which suffix needs prefill compute.
    pub fn match_prefix(&self, tokens: &[u32]) -> usize {
        let mut matched = 0;
        let mut cursor: Option<&[NodeId]> = Some(&self.roots);
        while matched < tokens.len() {
            let candidates = match cursor {
                Some(c) => c,
                None => break,
            };
            let mut advanced = false;
            for &child in candidates {
                let chunk = self.pool.get(self.node(child).chunk);
                let m = common_prefix(chunk.tokens(), &tokens[matched..]);
                if m > 0 {
                    matched += m;
                    if m == chunk.len() {
                        cursor = Some(&self.node(child).children);
                    } else {
                        cursor = None; // diverged mid-chunk; stop
                    }
                    advanced = true;
                    break;
                }
            }
            if !advanced {
                break;
            }
        }
        matched
    }

    /// Insert a new sequence with the given prompt tokens. K/V rows for the
    /// unmatched suffix are produced by `fill` (position, token, k, v).
    ///
    /// Matched prefix chunks are shared: their K/V are *not* recomputed
    /// (§3.2 prefilling: "perform a prefix lookup to avoid repeated
    /// computation of KV projection ... for matched prompt prefixes").
    pub fn insert_sequence(&mut self, seq: SeqId, tokens: &[u32], fill: KvFill) -> InsertOutcome {
        assert!(!self.seqs.contains_key(&seq), "sequence {seq:?} already present");
        assert!(!tokens.is_empty(), "empty prompt");
        let shape = self.pool.shape();
        let mut pos = 0usize;

        // Phase 1: walk matching whole or partial chunks.
        let mut parent: Option<NodeId> = None;
        let mut matched_tokens = 0usize;
        loop {
            let candidates: Vec<NodeId> = match parent {
                None => self.roots.clone(),
                Some(p) => self.node(p).children.clone(),
            };
            let mut next: Option<(NodeId, usize)> = None;
            for child in candidates {
                let chunk = self.pool.get(self.node(child).chunk);
                let m = common_prefix(chunk.tokens(), &tokens[pos..]);
                if m > 0 {
                    next = Some((child, m));
                    break;
                }
            }
            let Some((child, m)) = next else { break };
            let chunk_len = self.pool.get(self.node(child).chunk).len();
            if m < chunk_len {
                // Diverged (or exhausted) mid-chunk: split `child` at `m` so
                // the common part stays shared.
                self.split_node(child, m);
            }
            self.node_mut(child).nseqs += 1;
            pos += m;
            matched_tokens += m;
            parent = Some(child);
            if pos == tokens.len() {
                break;
            }
            // If we diverged mid-chunk the split already happened and no
            // child can match; the loop exits naturally on the next probe.
        }

        // Phase 2: append the unmatched suffix into fresh chunks.
        // A shared, partially-filled chunk is never extended in place — that
        // would mutate another sequence's prefix — so the suffix always goes
        // into new nodes ("some memory is unused due to alignment", §3.1).
        let mut k_row = vec![0.0f32; shape.heads * shape.head_dim];
        let mut v_row = vec![0.0f32; shape.heads * shape.head_dim];
        let mut leaf = parent;
        while pos < tokens.len() {
            let node = self.new_node(leaf);
            match leaf {
                None => self.roots.push(node),
                Some(p) => self.node_mut(p).children.push(node),
            }
            self.node_mut(node).nseqs += 1;
            let take = (tokens.len() - pos).min(shape.chunk_size);
            for i in 0..take {
                let t = tokens[pos + i];
                fill(pos + i, t, &mut k_row, &mut v_row);
                let chunk_id = self.node(node).chunk;
                self.pool.get_mut(chunk_id).append(&shape, t, &k_row, &v_row);
            }
            pos += take;
            leaf = Some(node);
        }

        let leaf = leaf.expect("non-empty prompt yields a leaf");
        self.node_mut(leaf).nterm += 1;
        self.seqs.insert(seq, SeqInfo { leaf, len: tokens.len() });
        self.bump_epoch();
        InsertOutcome { matched_tokens, total_tokens: tokens.len() }
    }

    /// Split `node`'s chunk at offset `at` (> 0): the first `at` tokens stay
    /// in `node`; the remainder moves into a new child that inherits the old
    /// children and terminating sequences.
    fn split_node(&mut self, node: NodeId, at: usize) {
        let shape = self.pool.shape();
        let chunk_len = self.pool.get(self.node(node).chunk).len();
        assert!(at > 0 && at < chunk_len, "split at {at} of {chunk_len}");
        let tail = self.new_node(Some(node));
        // Move the K/V suffix rows into the tail chunk.
        let (node_chunk, tail_chunk) = (self.node(node).chunk, self.node(tail).chunk);
        let (src, dst) = self.pool.get2_mut(node_chunk, tail_chunk);
        dst.take_suffix_from(&shape, src, at);
        // Rewire children: old children hang off the tail now.
        let old_children = std::mem::take(&mut self.node_mut(node).children);
        for &c in &old_children {
            self.node_mut(c).parent = Some(tail);
        }
        let (nseqs, nterm) = {
            let n = self.node(node);
            (n.nseqs, n.nterm)
        };
        {
            let t = self.node_mut(tail);
            t.children = old_children;
            t.nseqs = nseqs;
            t.nterm = nterm;
        }
        self.node_mut(node).children = vec![tail];
        self.node_mut(node).nterm = 0;
        // Sequences that terminated at `node` now terminate at `tail`.
        for info in self.seqs.values_mut() {
            if info.leaf == node {
                info.leaf = tail;
            }
        }
    }

    /// Remove a completed sequence, releasing chunks that no live sequence
    /// references (they return to the pool's free list).
    pub fn remove_sequence(&mut self, seq: SeqId) {
        let info = self.seqs.remove(&seq).unwrap_or_else(|| panic!("unknown {seq:?}"));
        self.node_mut(info.leaf).nterm -= 1;
        let mut cur = Some(info.leaf);
        while let Some(id) = cur {
            let parent = self.node(id).parent;
            let n = self.node_mut(id);
            n.nseqs -= 1;
            if n.nseqs == 0 {
                debug_assert!(n.children.is_empty(), "orphaned children under dead node");
                match parent {
                    Some(p) => {
                        let siblings = &mut self.node_mut(p).children;
                        siblings.retain(|&c| c != id);
                    }
                    None => self.roots.retain(|&r| r != id),
                }
                self.free_node(id);
            }
            cur = parent;
        }
        self.bump_epoch();
    }

    /// Extend a resident sequence with `tokens` (K/V rows produced by
    /// `fill`; positions continue from the current length). This is the
    /// chunked-prefill growth path: a partially prefilled prompt is a
    /// first-class resident, so between two slices other sequences may
    /// have matched onto its tail chunk — in that case (or when the tail
    /// is full) growth forks fresh private chunks, exactly like a decode
    /// append on a shared leaf. In-place tail extension of a private,
    /// partially filled chunk does not bump the generation counter.
    pub fn extend_sequence(&mut self, seq: SeqId, tokens: &[u32], fill: KvFill) {
        if tokens.is_empty() {
            return;
        }
        let shape = self.pool.shape();
        let info = self.seqs.get(&seq).unwrap_or_else(|| panic!("unknown {seq:?}")).clone();
        let mut leaf = info.leaf;
        let base = info.len;
        let mut structural = false;
        let mut k_row = vec![0.0f32; shape.heads * shape.head_dim];
        let mut v_row = vec![0.0f32; shape.heads * shape.head_dim];
        let mut idx = 0usize;
        while idx < tokens.len() {
            let leaf_private = self.node(leaf).nseqs == 1;
            let leaf_len = self.pool.get(self.node(leaf).chunk).len();
            let (target, avail) = if leaf_private && leaf_len < shape.chunk_size {
                // Fast path: room left in the private tail chunk.
                (leaf, shape.chunk_size - leaf_len)
            } else {
                // Shared or full tail: grow a fresh private chunk below it.
                // The old leaf stops terminating this sequence.
                let node = self.new_node(Some(leaf));
                self.node_mut(leaf).children.push(node);
                self.node_mut(leaf).nterm -= 1;
                {
                    let n = self.node_mut(node);
                    n.nseqs = 1;
                    n.nterm = 1;
                }
                structural = true;
                leaf = node;
                (node, shape.chunk_size)
            };
            let take = avail.min(tokens.len() - idx);
            let chunk_id = self.node(target).chunk;
            for i in 0..take {
                let t = tokens[idx + i];
                fill(base + idx + i, t, &mut k_row, &mut v_row);
                self.pool.get_mut(chunk_id).append(&shape, t, &k_row, &v_row);
            }
            idx += take;
        }
        let info = self.seqs.get_mut(&seq).expect("checked above");
        info.leaf = leaf;
        info.len += tokens.len();
        if structural {
            self.bump_epoch();
        }
    }

    /// Decode-append one token for a sequence. Only triggers a structural
    /// change (and context rebuild) when the leaf chunk is full or shared.
    pub fn append_token(&mut self, seq: SeqId, token: u32, k_rows: &[f32], v_rows: &[f32]) {
        let shape = self.pool.shape();
        let info = self.seqs.get(&seq).unwrap_or_else(|| panic!("unknown {seq:?}")).clone();
        let leaf = info.leaf;
        let leaf_private = self.node(leaf).nseqs == 1;
        let leaf_full = self.pool.get(self.node(leaf).chunk).len() >= shape.chunk_size;
        if leaf_private && !leaf_full {
            // Fast path: extend the private tail chunk in place. The tree
            // structure is unchanged, so the cached context stays valid.
            let chunk_id = self.node(leaf).chunk;
            self.pool.get_mut(chunk_id).append(&shape, token, k_rows, v_rows);
        } else {
            // Grow a fresh private chunk under the current leaf.
            let node = self.new_node(Some(leaf));
            self.node_mut(leaf).children.push(node);
            self.node_mut(leaf).nterm -= 1;
            self.node_mut(node).nseqs = 1;
            self.node_mut(node).nterm = 1;
            let chunk_id = self.node(node).chunk;
            self.pool.get_mut(chunk_id).append(&shape, token, k_rows, v_rows);
            self.seqs.get_mut(&seq).unwrap().leaf = node;
            self.bump_epoch();
        }
        self.seqs.get_mut(&seq).unwrap().len += 1;
    }

    /// Build a context without touching the lazy cache or its statistics.
    /// For callers that maintain their own [`PrefixTree::generation`]-keyed
    /// cache (the serving engine): avoids retaining a second copy of every
    /// context inside the tree.
    pub fn context_fresh(&self) -> TreeContext {
        self.build_context()
    }

    /// The kernel context (§3.3), cached across decode iterations and
    /// rebuilt only when the structure changed (lazy context copy).
    pub fn context(&mut self) -> TreeContext {
        if self.lazy_context {
            if let Some((epoch, ctx)) = &self.ctx_cache {
                if *epoch == self.epoch {
                    self.ctx_hits += 1;
                    return ctx.clone();
                }
            }
        }
        let ctx = self.build_context();
        self.ctx_rebuilds += 1;
        self.ctx_cache = Some((self.epoch, ctx.clone()));
        ctx
    }

    fn build_context(&self) -> TreeContext {
        let mut ctx = TreeContext::default();
        // Leaf-to-seq mapping: collect sequences terminating at each node.
        let mut term: BTreeMap<u32, Vec<SeqId>> = BTreeMap::new();
        for (&seq, info) in &self.seqs {
            term.entry(info.leaf.0).or_default().push(seq);
        }
        // Explicit-stack DFS assigning contiguous sequence intervals. Tree
        // depth is tokens/chunk_size along a path, so a single long
        // sequence (64k tokens at a small chunk size) produces a path far
        // deeper than any thread stack tolerates — per-node recursion is
        // not an option here. `Enter` emits a node's entry and schedules
        // its children; the matching `Exit` patches the interval end once
        // the whole subtree has been emitted, which reproduces the
        // recursive post-order exactly.
        enum Frame {
            Enter(NodeId),
            Exit(usize),
        }
        let mut stack: Vec<Frame> = self.roots.iter().rev().map(|&r| Frame::Enter(r)).collect();
        while let Some(frame) = stack.pop() {
            match frame {
                Frame::Enter(node) => {
                    let start = ctx.seq_order.len();
                    // Sequences ending exactly here come first in the
                    // interval.
                    if let Some(seqs) = term.get(&node.0) {
                        ctx.seq_order.extend_from_slice(seqs);
                    }
                    let entry_idx = ctx.entries.len();
                    ctx.entries.push(CtxEntry {
                        node,
                        chunk: self.node(node).chunk,
                        start,
                        end: 0,
                    });
                    stack.push(Frame::Exit(entry_idx));
                    for &child in self.node(node).children.iter().rev() {
                        stack.push(Frame::Enter(child));
                    }
                }
                Frame::Exit(entry_idx) => ctx.entries[entry_idx].end = ctx.seq_order.len(),
            }
        }
        ctx
    }

    /// Gather a sequence's full K/V into dense `[heads, len, head_dim]`
    /// f32 buffers, widening from the storage dtype (used by prefill, the
    /// f64 oracle, baselines, and tests).
    pub fn gather_dense(&self, seq: SeqId) -> Option<(Vec<f32>, Vec<f32>, Vec<u32>)> {
        let info = self.seqs.get(&seq)?;
        let shape = self.pool.shape();
        // Collect path root..leaf.
        let mut path = Vec::new();
        let mut cur = Some(info.leaf);
        while let Some(id) = cur {
            path.push(id);
            cur = self.node(id).parent;
        }
        path.reverse();
        let n = info.len;
        let mut k = vec![0.0f32; shape.heads * n * shape.head_dim];
        let mut v = vec![0.0f32; shape.heads * n * shape.head_dim];
        let mut tokens = Vec::with_capacity(n);
        let mut pos = 0usize;
        for id in path {
            let chunk = self.pool.get(self.node(id).chunk);
            for h in 0..shape.heads {
                for p in 0..chunk.len() {
                    let src = shape.row_offset(h, p);
                    let dst = (h * n + pos + p) * shape.head_dim;
                    chunk.k_slab().read_f32(src, &mut k[dst..dst + shape.head_dim]);
                    chunk.v_slab().read_f32(src, &mut v[dst..dst + shape.head_dim]);
                }
            }
            tokens.extend_from_slice(chunk.tokens());
            pos += chunk.len();
        }
        debug_assert_eq!(pos, n);
        Some((k, v, tokens))
    }

    /// Locate the chunk whose tokens begin at offset `pos` along the path
    /// matching `tokens`. Returns `(usable_len, chunk)` where `usable_len`
    /// is how many of the chunk's tokens match from `pos` on; callers read
    /// rows through the chunk's slab adapters or typed head views.
    /// Used by prefill to gather a matched prefix without owning a SeqId.
    pub fn find_chunk_at(&self, tokens: &[u32], pos: usize) -> Option<(usize, &Chunk)> {
        let mut offset = 0usize;
        let mut candidates: &[NodeId] = &self.roots;
        loop {
            let mut found = None;
            for &c in candidates {
                let chunk = self.pool.get(self.node(c).chunk);
                let m = common_prefix(chunk.tokens(), &tokens[offset..]);
                if m > 0 {
                    found = Some((c, m));
                    break;
                }
            }
            let (node_id, m) = found?;
            let chunk = self.pool.get(self.node(node_id).chunk);
            if offset == pos {
                return Some((m, chunk));
            }
            if m < chunk.len() {
                return None; // diverged before reaching pos
            }
            offset += m;
            if offset > pos {
                return None; // pos falls inside this chunk, not at its start
            }
            candidates = &self.node(node_id).children;
        }
    }

    /// Logical tokens currently represented (sum over sequences) vs physical
    /// tokens stored (sum over chunks) — the sharing ratio of §3.1.
    pub fn sharing_stats(&self) -> SharingStats {
        let logical: usize = self.seqs.values().map(|s| s.len).sum();
        let mut physical = 0usize;
        let mut chunks = 0usize;
        for slot in &self.slots {
            if let Slot::Used(n) = slot {
                physical += self.pool.get(n.chunk).len();
                chunks += 1;
            }
        }
        SharingStats { logical_tokens: logical, physical_tokens: physical, chunks }
    }

    /// Integrity check used by tests and property tests: verifies refcounts,
    /// parent/child symmetry, interval contiguity and token round-trips.
    pub fn check_invariants(&self) -> Result<(), String> {
        // nseqs consistency: recompute by walking every sequence's path.
        let mut counted: BTreeMap<u32, usize> = BTreeMap::new();
        for info in self.seqs.values() {
            let mut cur = Some(info.leaf);
            while let Some(id) = cur {
                *counted.entry(id.0).or_default() += 1;
                cur = self.node(id).parent;
            }
        }
        let mut used_nodes = 0;
        for (i, slot) in self.slots.iter().enumerate() {
            if let Slot::Used(n) = slot {
                used_nodes += 1;
                let expect = counted.get(&(i as u32)).copied().unwrap_or(0);
                if n.nseqs != expect {
                    return Err(format!("node {i}: nseqs {} != walked {expect}", n.nseqs));
                }
                if n.nseqs == 0 {
                    return Err(format!("node {i}: zero-ref node not freed"));
                }
                for &c in &n.children {
                    if self.node(c).parent != Some(NodeId(i as u32)) {
                        return Err(format!("node {i}: child {c:?} parent mismatch"));
                    }
                }
                let chunk_len = self.pool.get(n.chunk).len();
                if chunk_len == 0 {
                    return Err(format!("node {i}: empty chunk"));
                }
            }
        }
        if used_nodes != self.pool.in_use() {
            return Err(format!("{used_nodes} nodes vs {} chunks in use", self.pool.in_use()));
        }
        // Context invariants.
        let ctx = self.build_context();
        if ctx.seq_order.len() != self.seqs.len() {
            return Err("context misses sequences".into());
        }
        for e in &ctx.entries {
            if e.start >= e.end {
                return Err(format!("empty interval {e:?}"));
            }
            let node = self.node(e.node);
            if e.end - e.start != node.nseqs {
                return Err(format!("interval width {} != nseqs {}", e.end - e.start, node.nseqs));
            }
            if let Some(p) = node.parent {
                let pe = ctx.entries.iter().find(|x| x.node == p).unwrap();
                if pe.start > e.start || pe.end < e.end {
                    return Err(format!("child interval {e:?} escapes parent {pe:?}"));
                }
            }
        }
        // Token round-trip per sequence.
        for (&seq, info) in &self.seqs {
            let (_, _, tokens) = self.gather_dense(seq).unwrap();
            if tokens.len() != info.len {
                return Err(format!("{seq:?}: dense len {} != {}", tokens.len(), info.len));
            }
        }
        Ok(())
    }
}

/// Sharing statistics (§3.1): capacity gain is `logical/physical`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SharingStats {
    pub logical_tokens: usize,
    pub physical_tokens: usize,
    pub chunks: usize,
}

impl SharingStats {
    /// Fraction of logical tokens that are deduplicated away.
    pub fn sharing_ratio(&self) -> f64 {
        if self.logical_tokens == 0 {
            0.0
        } else {
            1.0 - self.physical_tokens as f64 / self.logical_tokens as f64
        }
    }
}

/// Length of the longest common prefix of two token slices. Shared by the
/// tree walks and the scheduler's prefix-aware admission scoring.
pub fn common_prefix(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> KvShape {
        KvShape::new(2, 4, 4) // tiny chunks: splits and growth exercise easily
    }

    /// Deterministic fake KV: row value encodes (pos, token) so shared rows
    /// are verifiable.
    fn fill_fn(pos: usize, token: u32, k: &mut [f32], v: &mut [f32]) {
        for (i, x) in k.iter_mut().enumerate() {
            *x = pos as f32 * 1000.0 + token as f32 + i as f32 * 0.001;
        }
        for (i, x) in v.iter_mut().enumerate() {
            *x = -(pos as f32 * 1000.0 + token as f32) - i as f32 * 0.001;
        }
    }

    fn insert(tree: &mut PrefixTree, seq: u64, tokens: &[u32]) -> InsertOutcome {
        tree.insert_sequence(SeqId(seq), tokens, &mut fill_fn)
    }

    #[test]
    fn first_sequence_matches_nothing() {
        let mut tree = PrefixTree::new(shape());
        let out = insert(&mut tree, 1, &[1, 2, 3, 4, 5, 6]);
        assert_eq!(out.matched_tokens, 0);
        assert_eq!(out.total_tokens, 6);
        assert_eq!(tree.pool().in_use(), 2); // 4 + 2 tokens
        tree.check_invariants().unwrap();
    }

    #[test]
    fn identical_prompts_share_all_chunks() {
        let mut tree = PrefixTree::new(shape());
        insert(&mut tree, 1, &[1, 2, 3, 4, 5, 6, 7, 8]);
        let out = insert(&mut tree, 2, &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(out.matched_tokens, 8);
        assert_eq!(tree.pool().in_use(), 2, "no new chunks for identical prompt");
        let stats = tree.sharing_stats();
        assert_eq!(stats.logical_tokens, 16);
        assert_eq!(stats.physical_tokens, 8);
        assert!((stats.sharing_ratio() - 0.5).abs() < 1e-12);
        tree.check_invariants().unwrap();
    }

    #[test]
    fn divergence_at_chunk_boundary() {
        let mut tree = PrefixTree::new(shape());
        insert(&mut tree, 1, &[1, 2, 3, 4, 10, 11]);
        let out = insert(&mut tree, 2, &[1, 2, 3, 4, 20, 21]);
        assert_eq!(out.matched_tokens, 4);
        // Shared root chunk + two private tails.
        assert_eq!(tree.pool().in_use(), 3);
        tree.check_invariants().unwrap();
    }

    #[test]
    fn divergence_mid_chunk_splits() {
        let mut tree = PrefixTree::new(shape());
        insert(&mut tree, 1, &[1, 2, 3, 4]);
        let out = insert(&mut tree, 2, &[1, 2, 9, 9]);
        assert_eq!(out.matched_tokens, 2);
        // Split: [1,2] shared, [3,4] private to s1, [9,9] private to s2.
        assert_eq!(tree.pool().in_use(), 3);
        tree.check_invariants().unwrap();
        // K/V rows must have moved with the split.
        let (_, _, t1) = tree.gather_dense(SeqId(1)).unwrap();
        assert_eq!(t1, vec![1, 2, 3, 4]);
        let (_, _, t2) = tree.gather_dense(SeqId(2)).unwrap();
        assert_eq!(t2, vec![1, 2, 9, 9]);
    }

    #[test]
    fn prefix_of_existing_sequence() {
        let mut tree = PrefixTree::new(shape());
        insert(&mut tree, 1, &[1, 2, 3, 4, 5, 6]);
        let out = insert(&mut tree, 2, &[1, 2, 3]);
        assert_eq!(out.matched_tokens, 3);
        tree.check_invariants().unwrap();
        let ctx = tree.context();
        assert_eq!(ctx.seq_order.len(), 2);
    }

    #[test]
    fn match_prefix_agrees_with_insert() {
        let mut tree = PrefixTree::new(shape());
        insert(&mut tree, 1, &[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        for tokens in [&[1u32, 2, 3, 4, 5][..], &[1, 2][..], &[9, 9][..], &[1, 2, 3, 4, 5, 6, 7, 8, 9, 10][..]] {
            let expect = tree.match_prefix(tokens);
            let mut probe = PrefixTree::new(shape());
            insert(&mut probe, 1, &[1, 2, 3, 4, 5, 6, 7, 8, 9]);
            let got = insert(&mut probe, 2, tokens).matched_tokens;
            assert_eq!(expect, got, "tokens {tokens:?}");
        }
    }

    #[test]
    fn remove_frees_private_chunks_only() {
        let mut tree = PrefixTree::new(shape());
        insert(&mut tree, 1, &[1, 2, 3, 4, 10, 11]);
        insert(&mut tree, 2, &[1, 2, 3, 4, 20, 21]);
        assert_eq!(tree.pool().in_use(), 3);
        tree.remove_sequence(SeqId(2));
        assert_eq!(tree.pool().in_use(), 2, "shared chunk stays, private tail freed");
        tree.check_invariants().unwrap();
        tree.remove_sequence(SeqId(1));
        assert_eq!(tree.pool().in_use(), 0);
        assert_eq!(tree.num_sequences(), 0);
    }

    #[test]
    fn append_fast_path_keeps_context_valid() {
        let mut tree = PrefixTree::new(shape());
        insert(&mut tree, 1, &[1, 2]); // private chunk with room
        let _ = tree.context();
        let epoch = tree.epoch();
        let k = vec![1.0; 8];
        let v = vec![2.0; 8];
        tree.append_token(SeqId(1), 3, &k, &v);
        assert_eq!(tree.epoch(), epoch, "in-place append must not invalidate");
        let _ = tree.context();
        let (rebuilds, hits) = tree.context_stats();
        assert_eq!(rebuilds, 1);
        assert_eq!(hits, 1);
        assert_eq!(tree.sequence_len(SeqId(1)), Some(3));
        tree.check_invariants().unwrap();
    }

    #[test]
    fn append_to_shared_leaf_forks() {
        let mut tree = PrefixTree::new(shape());
        insert(&mut tree, 1, &[1, 2, 3]);
        insert(&mut tree, 2, &[1, 2, 3]); // both end on the same (partial) chunk
        let k = vec![1.0; 8];
        let v = vec![2.0; 8];
        tree.append_token(SeqId(1), 100, &k, &v);
        tree.append_token(SeqId(2), 200, &k, &v);
        tree.check_invariants().unwrap();
        let (_, _, t1) = tree.gather_dense(SeqId(1)).unwrap();
        let (_, _, t2) = tree.gather_dense(SeqId(2)).unwrap();
        assert_eq!(t1, vec![1, 2, 3, 100]);
        assert_eq!(t2, vec![1, 2, 3, 200]);
        // Shared [1,2,3] chunk + two private tails.
        assert_eq!(tree.pool().in_use(), 3);
    }

    #[test]
    fn append_grows_chunk_when_full() {
        let mut tree = PrefixTree::new(shape());
        insert(&mut tree, 1, &[1, 2, 3, 4]); // exactly one full chunk
        let k = vec![0.5; 8];
        let v = vec![0.25; 8];
        for t in 5..=9 {
            tree.append_token(SeqId(1), t, &k, &v);
        }
        assert_eq!(tree.sequence_len(SeqId(1)), Some(9));
        assert_eq!(tree.pool().in_use(), 3); // 4 + 4 + 1
        tree.check_invariants().unwrap();
    }

    #[test]
    fn context_intervals_are_contiguous_dfs() {
        let mut tree = PrefixTree::new(shape());
        insert(&mut tree, 1, &[1, 2, 3, 4, 10, 11, 12, 13]);
        insert(&mut tree, 2, &[1, 2, 3, 4, 20, 21, 22, 23]);
        insert(&mut tree, 3, &[1, 2, 3, 4, 10, 11, 12, 13, 30, 31]);
        insert(&mut tree, 4, &[7, 7, 7, 7]);
        let ctx = tree.context();
        assert_eq!(ctx.seq_order.len(), 4);
        // Root chunk [1,2,3,4] covers exactly the three sharing sequences.
        let root_entry = ctx.entries.iter().find(|e| e.end - e.start == 3).expect("shared root");
        assert!(root_entry.is_shared());
        // Sequence 4 is alone in its own tree.
        let solo = ctx.entries.iter().filter(|e| !e.is_shared()).count();
        assert!(solo >= 2);
        tree.check_invariants().unwrap();
    }

    #[test]
    fn shared_kv_is_physically_identical() {
        let mut tree = PrefixTree::new(shape());
        insert(&mut tree, 1, &[5, 6, 7, 8, 1, 1]);
        insert(&mut tree, 2, &[5, 6, 7, 8, 2, 2]);
        let (k1, _, _) = tree.gather_dense(SeqId(1)).unwrap();
        let (k2, _, _) = tree.gather_dense(SeqId(2)).unwrap();
        let s = shape();
        // First 4 tokens of head 0 identical.
        assert_eq!(&k1[0..4 * s.head_dim], &k2[0..4 * s.head_dim]);
    }

    #[test]
    fn forest_multiple_roots() {
        let mut tree = PrefixTree::new(shape());
        insert(&mut tree, 1, &[1, 1, 1, 1]);
        insert(&mut tree, 2, &[2, 2, 2, 2]);
        insert(&mut tree, 3, &[3, 3, 3, 3]);
        let ctx = tree.context();
        assert_eq!(ctx.entries.len(), 3);
        assert!(ctx.entries.iter().all(|e| !e.is_shared()));
        tree.check_invariants().unwrap();
    }

    #[test]
    fn extend_sequence_grows_in_place_then_across_chunks() {
        let mut tree = PrefixTree::new(shape()); // chunk_size 4
        insert(&mut tree, 1, &[1, 2]); // partial private chunk
        let epoch = tree.epoch();
        tree.extend_sequence(SeqId(1), &[3, 4], &mut fill_fn);
        assert_eq!(tree.epoch(), epoch, "in-place tail extension is non-structural");
        tree.extend_sequence(SeqId(1), &[5, 6, 7, 8, 9], &mut fill_fn);
        assert!(tree.epoch() > epoch, "chunk overflow forks new nodes");
        assert_eq!(tree.sequence_len(SeqId(1)), Some(9));
        assert_eq!(tree.pool().in_use(), 3); // 4 + 4 + 1
        let (k, _, tokens) = tree.gather_dense(SeqId(1)).unwrap();
        assert_eq!(tokens, vec![1, 2, 3, 4, 5, 6, 7, 8, 9]);
        // Rows carry the positions the fill callback saw (continuing from
        // the existing length), so slices are indistinguishable from a
        // monolithic insert.
        let mut whole = PrefixTree::new(shape());
        insert(&mut whole, 7, &[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        let (kw, _, _) = whole.gather_dense(SeqId(7)).unwrap();
        assert_eq!(k, kw, "extended K rows bit-identical to a one-shot insert");
        tree.check_invariants().unwrap();
    }

    #[test]
    fn extend_forks_when_a_follower_matched_the_partial_tail() {
        // Chunked prefill interleaving: sequence 1 is mid-prefill when
        // sequence 2 joins and matches its partial tail chunk. Growing 1
        // must fork (the tail is now shared) instead of mutating 2's
        // prefix in place.
        let mut tree = PrefixTree::new(shape());
        insert(&mut tree, 1, &[1, 2, 3]); // partial resident (3 of 4)
        insert(&mut tree, 2, &[1, 2, 3]); // follower matches everything
        tree.extend_sequence(SeqId(1), &[4, 5], &mut fill_fn);
        tree.check_invariants().unwrap();
        let (_, _, t1) = tree.gather_dense(SeqId(1)).unwrap();
        let (_, _, t2) = tree.gather_dense(SeqId(2)).unwrap();
        assert_eq!(t1, vec![1, 2, 3, 4, 5]);
        assert_eq!(t2, vec![1, 2, 3], "follower's prefix untouched by the leader's growth");
        // Shared [1,2,3] + private [4,5].
        assert_eq!(tree.pool().in_use(), 2);
        // And a follower arriving later still matches the extended content.
        let m = tree.match_prefix(&[1, 2, 3, 4, 5, 9]);
        assert_eq!(m, 5);
    }

    #[test]
    fn deep_tree_context_does_not_overflow_the_stack() {
        // Regression for the recursive build_context: one 64k-token
        // sequence at chunk_size 1 is a 64k-deep path — per-node recursion
        // blows the (2 MiB default) test-thread stack; the explicit-stack
        // traversal must handle it and agree with the recursive reference
        // on every field.
        let s = KvShape::new(1, 1, 1);
        let mut tree = PrefixTree::new(s);
        let n = 65_536usize;
        let tokens: Vec<u32> = (0..n as u32).collect();
        tree.insert_sequence(SeqId(1), &tokens, &mut fill_fn);
        // A second, shorter sequence sharing the prefix exercises interval
        // nesting at depth.
        let tokens2: Vec<u32> = (0..1000).collect();
        tree.insert_sequence(SeqId(2), &tokens2, &mut fill_fn);
        let ctx = tree.context_fresh();
        assert_eq!(ctx.seq_order.len(), 2);
        assert_eq!(ctx.entries.len(), n);
        // The first 1000 chunks cover both sequences, the rest only one.
        assert_eq!(ctx.entries[0].end - ctx.entries[0].start, 2);
        assert_eq!(ctx.entries[999].end - ctx.entries[999].start, 2);
        assert_eq!(ctx.entries[1000].end - ctx.entries[1000].start, 1);
        assert_eq!(ctx.entries[n - 1].end - ctx.entries[n - 1].start, 1);
    }

    /// Recursive reference implementation of the context build, kept only
    /// to pin the explicit-stack traversal's output.
    fn build_context_recursive(tree: &PrefixTree) -> TreeContext {
        let mut ctx = TreeContext::default();
        let mut term: BTreeMap<u32, Vec<SeqId>> = BTreeMap::new();
        for (&seq, info) in &tree.seqs {
            term.entry(info.leaf.0).or_default().push(seq);
        }
        fn dfs(
            tree: &PrefixTree,
            node: NodeId,
            term: &BTreeMap<u32, Vec<SeqId>>,
            ctx: &mut TreeContext,
        ) {
            let start = ctx.seq_order.len();
            if let Some(seqs) = term.get(&node.0) {
                ctx.seq_order.extend_from_slice(seqs);
            }
            let entry_idx = ctx.entries.len();
            ctx.entries.push(CtxEntry { node, chunk: tree.node(node).chunk, start, end: 0 });
            for &child in &tree.node(node).children {
                dfs(tree, child, term, ctx);
            }
            ctx.entries[entry_idx].end = ctx.seq_order.len();
        }
        for &root in &tree.roots {
            dfs(tree, root, &term, &mut ctx);
        }
        ctx
    }

    #[test]
    fn iterative_context_is_identical_to_the_recursive_reference() {
        let mut tree = PrefixTree::new(shape());
        insert(&mut tree, 1, &[1, 2, 3, 4, 10, 11, 12, 13]);
        insert(&mut tree, 2, &[1, 2, 3, 4, 20, 21, 22, 23]);
        insert(&mut tree, 3, &[1, 2, 3, 4, 10, 11, 12, 13, 30, 31]);
        insert(&mut tree, 4, &[7, 7, 7, 7, 8, 8]);
        insert(&mut tree, 5, &[1, 2, 9]); // mid-chunk split
        tree.extend_sequence(SeqId(4), &[9, 9, 9], &mut fill_fn);
        let got = tree.build_context();
        let want = build_context_recursive(&tree);
        assert_eq!(got.seq_order, want.seq_order);
        assert_eq!(got.entries, want.entries);
    }

    #[test]
    fn memory_waste_bound_holds() {
        // §3.1: alignment loss per sequence is bounded by (c-1)/n.
        let s = KvShape::new(1, 2, 16);
        let mut tree = PrefixTree::new(s);
        for seq in 0..8u64 {
            let n = 16 * 3 + (seq as usize * 3 + 1) % 16;
            let tokens: Vec<u32> = (0..n as u32).map(|t| t + seq as u32 * 1000).collect();
            tree.insert_sequence(SeqId(seq), &tokens, &mut fill_fn);
            let stats = tree.sharing_stats();
            let allocated = stats.chunks * 16;
            let waste = allocated - stats.physical_tokens;
            assert!(waste <= 8 * (16 - 1), "waste {waste} over bound");
        }
        tree.check_invariants().unwrap();
    }
}
