//! Paged KV cache — a faithful small reimplementation of vLLM's
//! PagedAttention memory manager (Kwon et al., 2023), used as the strongest
//! baseline in Table 3 / Table 4.
//!
//! K/V live in fixed-size *pages* held in a global pool; each sequence owns
//! a *page table* mapping its logical token blocks to physical pages. Pages
//! store [`KvSlab`]s at the shape's dtype, so the baseline pays the same
//! bytes per token as the prefix tree and the layout comparison stays fair
//! at every precision. Two modes reproduce the paper's two baselines:
//!
//! - `PagedKvCache` (plain): every sequence gets private pages, even for a
//!   shared prompt — the released-vLLM behaviour ("PagedAttn" rows).
//! - `share_prefix_with`: maps the full pages of another sequence's prefix
//!   into a new sequence's page table with refcounting — the manually
//!   aliased page table the paper calls PagedAttn\*.

use std::collections::BTreeMap;

use super::chunk::KvShape;
use super::dtype::{KvElem, KvSlab};
use super::tree::SeqId;

/// Physical page handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PageId(pub u32);

#[derive(Debug)]
struct Page {
    /// `[heads, page_size, head_dim]` elements.
    k: KvSlab,
    v: KvSlab,
    refcount: u32,
}

#[derive(Debug, Clone)]
struct SeqEntry {
    table: Vec<PageId>,
    len: usize,
}

/// Paged KV cache with refcounted physical pages.
pub struct PagedKvCache {
    shape: KvShape,
    /// Tokens per page (vLLM block_size; the paper's chunk size c plays the
    /// same role, we default both to the same value in benches).
    page_size: usize,
    pages: Vec<Page>,
    free: Vec<PageId>,
    seqs: BTreeMap<SeqId, SeqEntry>,
    in_use_pages: usize,
    peak_pages: usize,
}

impl PagedKvCache {
    pub fn new(shape: KvShape, page_size: usize) -> Self {
        assert!(page_size > 0);
        PagedKvCache {
            shape,
            page_size,
            pages: Vec::new(),
            free: Vec::new(),
            seqs: BTreeMap::new(),
            in_use_pages: 0,
            peak_pages: 0,
        }
    }

    pub fn shape(&self) -> KvShape {
        self.shape
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    fn page_elems(&self) -> usize {
        self.shape.heads * self.page_size * self.shape.head_dim
    }

    /// Bytes of K+V per page at the cache's dtype (int8 includes the
    /// per-head f32 scales each tensor carries).
    fn page_bytes(&self) -> usize {
        let scale_bytes =
            if self.shape.dtype == super::KvDtype::Int8 { 2 * self.shape.heads * 4 } else { 0 };
        2 * self.page_elems() * self.shape.dtype.bytes() + scale_bytes
    }

    fn alloc_page(&mut self) -> PageId {
        let id = match self.free.pop() {
            Some(id) => {
                // Recycled page: forget the previous tenant's int8 scales so
                // fresh writes pick their own quantization scale.
                let p = &mut self.pages[id.0 as usize];
                p.k.reset_scales();
                p.v.reset_scales();
                id
            }
            None => {
                let id = PageId(self.pages.len() as u32);
                let n = self.page_elems();
                // One int8 scale group per head (the per-head stride).
                let group = self.page_size * self.shape.head_dim;
                self.pages.push(Page {
                    k: KvSlab::zeroed_grouped(self.shape.dtype, n, group),
                    v: KvSlab::zeroed_grouped(self.shape.dtype, n, group),
                    refcount: 0,
                });
                id
            }
        };
        self.pages[id.0 as usize].refcount = 1;
        self.in_use_pages += 1;
        self.peak_pages = self.peak_pages.max(self.in_use_pages);
        id
    }

    fn ref_page(&mut self, id: PageId) {
        self.pages[id.0 as usize].refcount += 1;
    }

    fn unref_page(&mut self, id: PageId) {
        let page = &mut self.pages[id.0 as usize];
        page.refcount -= 1;
        if page.refcount == 0 {
            self.free.push(id);
            self.in_use_pages -= 1;
        }
    }

    /// Admit a sequence with private pages for all `tokens`.
    pub fn insert_sequence(
        &mut self,
        seq: SeqId,
        tokens: &[u32],
        fill: &mut dyn FnMut(usize, u32, &mut [f32], &mut [f32]),
    ) {
        assert!(!self.seqs.contains_key(&seq));
        let mut entry = SeqEntry { table: Vec::new(), len: 0 };
        let hd = self.shape.heads * self.shape.head_dim;
        let mut k_row = vec![0.0f32; hd];
        let mut v_row = vec![0.0f32; hd];
        for (pos, &t) in tokens.iter().enumerate() {
            if entry.len % self.page_size == 0 {
                let pid = self.alloc_page();
                entry.table.push(pid);
            }
            fill(pos, t, &mut k_row, &mut v_row);
            self.write_row(&entry, pos, &k_row, &v_row);
            entry.len += 1;
        }
        self.seqs.insert(seq, entry);
    }

    /// Admit a sequence whose first `shared_tokens` tokens alias the pages of
    /// `donor` (PagedAttn\* simulation). `shared_tokens` is rounded *down* to
    /// a page boundary — partial pages cannot be aliased safely. Returns the
    /// number of tokens actually aliased; the caller fills the rest.
    pub fn insert_sequence_shared(
        &mut self,
        seq: SeqId,
        donor: SeqId,
        tokens: &[u32],
        shared_tokens: usize,
        fill: &mut dyn FnMut(usize, u32, &mut [f32], &mut [f32]),
    ) -> usize {
        assert!(!self.seqs.contains_key(&seq));
        let donor_entry = self.seqs.get(&donor).expect("unknown donor").clone();
        let shared_tokens = shared_tokens.min(tokens.len()).min(donor_entry.len);
        let shared_pages = shared_tokens / self.page_size;
        let aliased_tokens = shared_pages * self.page_size;
        let mut entry = SeqEntry { table: Vec::new(), len: aliased_tokens };
        for &pid in &donor_entry.table[..shared_pages] {
            self.ref_page(pid);
            entry.table.push(pid);
        }
        let hd = self.shape.heads * self.shape.head_dim;
        let mut k_row = vec![0.0f32; hd];
        let mut v_row = vec![0.0f32; hd];
        for pos in aliased_tokens..tokens.len() {
            if entry.len % self.page_size == 0 {
                let pid = self.alloc_page();
                entry.table.push(pid);
            }
            fill(pos, tokens[pos], &mut k_row, &mut v_row);
            self.write_row(&entry, pos, &k_row, &v_row);
            entry.len += 1;
        }
        self.seqs.insert(seq, entry);
        aliased_tokens
    }

    /// Decode-append one token. If the tail page is shared (refcount > 1),
    /// copy-on-write duplicates it first.
    pub fn append_token(&mut self, seq: SeqId, k_rows: &[f32], v_rows: &[f32]) {
        let mut entry = self.seqs.get(&seq).expect("unknown sequence").clone();
        if entry.len % self.page_size == 0 {
            let pid = self.alloc_page();
            entry.table.push(pid);
        } else {
            let tail = *entry.table.last().unwrap();
            if self.pages[tail.0 as usize].refcount > 1 {
                // Copy-on-write: private copy of the partially filled page
                // (a bit-exact slab clone — no re-rounding).
                let new = self.alloc_page();
                let (kcopy, vcopy) = {
                    let p = &self.pages[tail.0 as usize];
                    (p.k.clone(), p.v.clone())
                };
                self.pages[new.0 as usize].k = kcopy;
                self.pages[new.0 as usize].v = vcopy;
                self.unref_page(tail);
                *entry.table.last_mut().unwrap() = new;
            }
        }
        let pos = entry.len;
        self.write_row(&entry, pos, k_rows, v_rows);
        entry.len += 1;
        self.seqs.insert(seq, entry);
    }

    pub fn remove_sequence(&mut self, seq: SeqId) {
        let entry = self.seqs.remove(&seq).expect("unknown sequence");
        for pid in entry.table {
            self.unref_page(pid);
        }
    }

    fn write_row(&mut self, entry: &SeqEntry, pos: usize, k_rows: &[f32], v_rows: &[f32]) {
        let page = entry.table[pos / self.page_size];
        let slot = pos % self.page_size;
        let p = &mut self.pages[page.0 as usize];
        for h in 0..self.shape.heads {
            let dst = (h * self.page_size + slot) * self.shape.head_dim;
            let src = h * self.shape.head_dim;
            p.k.write_f32(dst, &k_rows[src..src + self.shape.head_dim]);
            p.v.write_f32(dst, &v_rows[src..src + self.shape.head_dim]);
        }
    }

    pub fn seq_len(&self, seq: SeqId) -> Option<usize> {
        self.seqs.get(&seq).map(|e| e.len)
    }

    pub fn page_table(&self, seq: SeqId) -> Option<&[PageId]> {
        self.seqs.get(&seq).map(|e| e.table.as_slice())
    }

    /// K rows of one (page, head): typed contiguous `[page_size, head_dim]`
    /// slice (`E` must match the cache dtype).
    #[inline]
    pub fn page_k_head<E: KvElem>(&self, page: PageId, head: usize) -> &[E] {
        let stride = self.page_size * self.shape.head_dim;
        &self.pages[page.0 as usize].k.as_slice::<E>()[head * stride..(head + 1) * stride]
    }

    #[inline]
    pub fn page_v_head<E: KvElem>(&self, page: PageId, head: usize) -> &[E] {
        let stride = self.page_size * self.shape.head_dim;
        &self.pages[page.0 as usize].v.as_slice::<E>()[head * stride..(head + 1) * stride]
    }

    /// Dequant scale of one (page, head)'s K rows (1.0 for float dtypes;
    /// pages group scales per head, so the group index is the head index).
    #[inline]
    pub fn page_k_head_scale(&self, page: PageId, head: usize) -> f32 {
        self.pages[page.0 as usize].k.group_scale(head)
    }

    #[inline]
    pub fn page_v_head_scale(&self, page: PageId, head: usize) -> f32 {
        self.pages[page.0 as usize].v.group_scale(head)
    }

    pub fn num_sequences(&self) -> usize {
        self.seqs.len()
    }

    pub fn in_use_pages(&self) -> usize {
        self.in_use_pages
    }

    /// In-use KV bytes as actually allocated at the cache's dtype.
    pub fn in_use_bytes(&self) -> u64 {
        (self.in_use_pages * self.page_bytes()) as u64
    }

    pub fn peak_bytes(&self) -> u64 {
        (self.peak_pages * self.page_bytes()) as u64
    }

    /// Integrity: refcounts match table references; lens match table sizes.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut counted: BTreeMap<u32, u32> = BTreeMap::new();
        for (seq, e) in &self.seqs {
            let want_pages = e.len.div_ceil(self.page_size);
            if e.table.len() != want_pages {
                return Err(format!(
                    "{seq:?}: table {} pages, len {} wants {want_pages}",
                    e.table.len(),
                    e.len
                ));
            }
            for pid in &e.table {
                *counted.entry(pid.0).or_default() += 1;
            }
        }
        for (i, p) in self.pages.iter().enumerate() {
            let expect = counted.get(&(i as u32)).copied().unwrap_or(0);
            if p.refcount != expect {
                return Err(format!("page {i}: refcount {} != references {expect}", p.refcount));
            }
        }
        let live = self.pages.iter().filter(|p| p.refcount > 0).count();
        if live != self.in_use_pages {
            return Err(format!("in_use_pages {} != live {live}", self.in_use_pages));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::dtype::KvDtype;
    use super::*;

    fn fill(pos: usize, token: u32, k: &mut [f32], v: &mut [f32]) {
        k.fill(pos as f32 + token as f32 * 0.01);
        v.fill(pos as f32 * -1.0);
    }

    fn shape() -> KvShape {
        KvShape::new(2, 4, 4)
    }

    #[test]
    fn private_pages_for_plain_insert() {
        let mut cache = PagedKvCache::new(shape(), 4);
        cache.insert_sequence(SeqId(1), &[1, 2, 3, 4, 5], &mut fill);
        cache.insert_sequence(SeqId(2), &[1, 2, 3, 4, 5], &mut fill);
        assert_eq!(cache.in_use_pages(), 4, "identical prompts still get private pages");
        cache.check_invariants().unwrap();
    }

    #[test]
    fn shared_insert_aliases_full_pages() {
        let mut cache = PagedKvCache::new(shape(), 4);
        cache.insert_sequence(SeqId(1), &[1, 2, 3, 4, 5, 6], &mut fill);
        let aliased =
            cache.insert_sequence_shared(SeqId(2), SeqId(1), &[1, 2, 3, 4, 9, 9], 4, &mut fill);
        assert_eq!(aliased, 4);
        // Seq1: 2 pages. Seq2: aliases page 0, private page for [9,9].
        assert_eq!(cache.in_use_pages(), 3);
        assert_eq!(cache.page_table(SeqId(1)).unwrap()[0], cache.page_table(SeqId(2)).unwrap()[0]);
        cache.check_invariants().unwrap();
    }

    #[test]
    fn shared_insert_rounds_down_to_page_boundary() {
        let mut cache = PagedKvCache::new(shape(), 4);
        cache.insert_sequence(SeqId(1), &[1, 2, 3, 4, 5, 6, 7], &mut fill);
        let aliased =
            cache.insert_sequence_shared(SeqId(2), SeqId(1), &[1, 2, 3, 4, 5, 6, 7], 6, &mut fill);
        assert_eq!(aliased, 4, "6 shared tokens -> 1 full page of 4");
        cache.check_invariants().unwrap();
    }

    #[test]
    fn append_cow_on_shared_tail() {
        let mut cache = PagedKvCache::new(shape(), 4);
        cache.insert_sequence(SeqId(1), &[1, 2], &mut fill);
        // Alias the partial page deliberately via full-page share of 0 tokens
        // then manual alias is impossible through the API; instead share a
        // full-page prefix and diverge inside the NEXT page.
        cache.insert_sequence(SeqId(3), &[1, 2, 3, 4, 5], &mut fill);
        let aliased =
            cache.insert_sequence_shared(SeqId(4), SeqId(3), &[1, 2, 3, 4, 5], 5, &mut fill);
        assert_eq!(aliased, 4);
        // Seq4's tail page (token 5) is private already; append must not COW.
        let pages_before = cache.in_use_pages();
        cache.append_token(SeqId(4), &[9.0; 8], &[9.0; 8]);
        assert_eq!(cache.in_use_pages(), pages_before);
        cache.check_invariants().unwrap();
    }

    #[test]
    fn remove_frees_unreferenced_pages_only() {
        let mut cache = PagedKvCache::new(shape(), 4);
        cache.insert_sequence(SeqId(1), &[1, 2, 3, 4, 5, 6, 7, 8], &mut fill);
        cache.insert_sequence_shared(SeqId(2), SeqId(1), &[1, 2, 3, 4, 9, 9], 4, &mut fill);
        cache.remove_sequence(SeqId(1));
        // Page 0 still referenced by seq 2; seq1's second page freed.
        assert_eq!(cache.in_use_pages(), 2);
        cache.remove_sequence(SeqId(2));
        assert_eq!(cache.in_use_pages(), 0);
        cache.check_invariants().unwrap();
    }

    #[test]
    fn page_reuse_from_free_list() {
        let mut cache = PagedKvCache::new(shape(), 4);
        cache.insert_sequence(SeqId(1), &[1, 2, 3, 4], &mut fill);
        cache.remove_sequence(SeqId(1));
        cache.insert_sequence(SeqId(2), &[5, 6], &mut fill);
        assert_eq!(cache.pages.len(), 1, "freed page must be reused");
        cache.check_invariants().unwrap();
    }

    #[test]
    fn peak_accounting_follows_dtype() {
        // f32: 1 page * (2 heads * 4 tokens * 4 dim) * 2 tensors * 4 bytes.
        let mut cache = PagedKvCache::new(shape(), 4);
        cache.insert_sequence(SeqId(1), &[1, 2, 3, 4, 5, 6, 7, 8], &mut fill);
        let peak = cache.peak_bytes();
        assert_eq!(peak, (2 * (2 * 4 * 4) * 2 * 4) as u64);
        cache.remove_sequence(SeqId(1));
        assert_eq!(cache.peak_bytes(), peak);

        // f16 pages cost exactly half.
        let mut half = PagedKvCache::new(shape().with_dtype(KvDtype::F16), 4);
        half.insert_sequence(SeqId(1), &[1, 2, 3, 4, 5, 6, 7, 8], &mut fill);
        assert_eq!(half.peak_bytes() * 2, peak);
    }

    #[test]
    fn rows_survive_page_indirection() {
        let s = shape();
        let mut cache = PagedKvCache::new(s, 4);
        cache.insert_sequence(SeqId(1), &[10, 20, 30, 40, 50], &mut fill);
        // Token at pos 4 lives in page 1 slot 0.
        let table = cache.page_table(SeqId(1)).unwrap().to_vec();
        let k = cache.page_k_head::<f32>(table[1], 1);
        assert_eq!(k[0], 4.0 + 50.0 * 0.01);
    }
}
