//! KV storage dtypes: the format seam between the cache managers and the
//! attention kernels.
//!
//! The paper reports every KV-cache memory number in FP16 (Table 4) and the
//! chunk-first phase of the TPP kernel is bandwidth-bound on the streamed
//! `c×d` K-blocks, so the storage format directly sets both resident bytes
//! and kernel traffic. This module provides:
//!
//! - [`KvDtype`] — the runtime tag (`f32`, `f16`, `bf16`), carried by
//!   [`super::KvShape`] so every cache layout and kernel agrees on one
//!   format;
//! - software `f32 ↔ f16 / bf16` conversions (round-to-nearest-even,
//!   subnormal- and NaN-correct; no external crates, validated bit-exact
//!   against IEEE-754 binary16 semantics);
//! - [`KvElem`] — the typed element view the monomorphized kernel load
//!   paths are generic over: rows are widened to f32 registers at load
//!   time, accumulation always stays f32;
//! - [`KvSlab`] — a dtype-erased, 8-byte-aligned storage slab with typed
//!   slice views and f32 read/write adapters, the unit every chunk, page
//!   and dense buffer is built from.
//!
//! Accumulation-precision policy: storage may be half precision, but all
//! dot products, softmax statistics and output accumulators are f32 (the
//! f64 oracle tolerance therefore only loosens by the storage rounding of
//! K/V, ~2⁻¹¹ relative for f16 and ~2⁻⁸ for bf16).

/// KV-cache storage format.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KvDtype {
    /// IEEE-754 binary32 — the numerics baseline.
    F32,
    /// IEEE-754 binary16 — the paper's serving format (Table 4 accounting).
    F16,
    /// bfloat16 — truncated-exponent-preserving half precision.
    Bf16,
    /// Symmetric int8 with a per-group f32 scale held by the slab (one
    /// group per head within a chunk/page/dense buffer): `x ≈ q · scale`,
    /// `q ∈ [-127, 127]`, `scale = group_max_abs / 127`. Quantization is
    /// GGML-style blockwise (scale chosen at narrow time), dequantization
    /// happens in the kernel's widening load.
    Int8,
}

impl KvDtype {
    /// Bytes per stored element (excluding per-group scale metadata; see
    /// [`KvSlab::payload_bytes`] for the all-in accounting).
    #[inline]
    pub fn bytes(self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::F16 | KvDtype::Bf16 => 2,
            KvDtype::Int8 => 1,
        }
    }

    /// Canonical lowercase label (CLI values, metrics labels, bench rows).
    pub fn label(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
            KvDtype::Bf16 => "bf16",
            KvDtype::Int8 => "int8",
        }
    }

    /// Parse a CLI/config value.
    pub fn parse(s: &str) -> Option<KvDtype> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(KvDtype::F32),
            "f16" | "fp16" | "float16" | "half" => Some(KvDtype::F16),
            "bf16" | "bfloat16" => Some(KvDtype::Bf16),
            "int8" | "i8" => Some(KvDtype::Int8),
            _ => None,
        }
    }

    /// All supported dtypes (bench sweeps, property-test grids).
    pub const ALL: [KvDtype; 4] = [KvDtype::F32, KvDtype::F16, KvDtype::Bf16, KvDtype::Int8];

    /// Unit roundoff of the storage format: the relative rounding error
    /// bound for values stored at this dtype (the principled half of the
    /// kernel-vs-reference error budget; see DESIGN.md).
    ///
    /// For int8 the bound is relative to the *scale group's* max-abs, not
    /// the element: a fresh quantization rounds to the nearest step
    /// (≤ half a step = `group_max / 254`), and one requant-on-grow (the
    /// whole group re-rounded when a later write raises the scale) adds at
    /// most another half step — so a full step, `group_max / 127`, is the
    /// per-element bound the budget tests use.
    pub fn unit_roundoff(self) -> f32 {
        match self {
            KvDtype::F32 => f32::EPSILON / 2.0, // 2^-24
            KvDtype::F16 => 1.0 / 2048.0,       // 2^-11
            KvDtype::Bf16 => 1.0 / 256.0,       // 2^-8
            KvDtype::Int8 => 1.0 / 127.0,       // one quantization step
        }
    }
}

// ---------------------------------------------------------------------------
// Bit-level conversions (round-to-nearest-even everywhere).
// ---------------------------------------------------------------------------

/// `f32 → f16` bits: RNE rounding, gradual underflow to subnormals,
/// overflow to ±inf, NaN to a canonical quiet NaN.
#[inline]
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let x = value.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let exp = (x >> 23) & 0xff;
    let man = x & 0x007f_ffff;
    if exp == 0xff {
        // Inf keeps its sign; any NaN becomes the canonical quiet NaN.
        return if man == 0 { sign | 0x7c00 } else { sign | 0x7e00 };
    }
    let unbiased = exp as i32 - 127;
    if unbiased >= 16 {
        return sign | 0x7c00; // too large for binary16: ±inf
    }
    if unbiased >= -14 {
        // Normal range: rebias the exponent, round 23→10 mantissa bits.
        let half_exp = (unbiased + 15) as u32;
        let mut out = (half_exp << 10) | (man >> 13);
        let round_bits = man & 0x1fff;
        // A mantissa carry propagates into the exponent, which is exactly
        // the right behaviour (…1111₂ rounds up to the next binade, and
        // 65520 rounds to +inf).
        if round_bits > 0x1000 || (round_bits == 0x1000 && (out & 1) != 0) {
            out += 1;
        }
        return sign | out as u16;
    }
    if unbiased >= -25 {
        // Subnormal range: shift the implicit-1 mantissa into place.
        let man = man | 0x0080_0000;
        let shift = ((-14 - unbiased) + 13) as u32; // 14..=24
        let mut out = man >> shift;
        let halfway = 1u32 << (shift - 1);
        let round_bits = man & ((1u32 << shift) - 1);
        if round_bits > halfway || (round_bits == halfway && (out & 1) != 0) {
            out += 1;
        }
        return sign | out as u16;
    }
    sign // underflows to ±0
}

/// `f16 bits → f32` (exact: every binary16 value is representable).
#[inline]
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    if exp == 0 {
        // Subnormal (or zero): value is man × 2⁻²⁴, exact in f32.
        let mag = man as f32 * (1.0 / (1u32 << 24) as f32);
        return if sign != 0 { -mag } else { mag };
    }
    // 127 - 15 = 112 exponent rebias.
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

/// `f32 → bf16` bits: RNE via the carry trick on the low 16 bits; NaN is
/// quieted so truncation can never produce an infinity from a NaN payload.
#[inline]
pub fn f32_to_bf16_bits(value: f32) -> u16 {
    let bits = value.to_bits();
    if value.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    // No overflow: the largest non-NaN bit pattern is 0xff80_0000 (-inf).
    (((bits + 0x7fff + lsb) >> 16) & 0xffff) as u16
}

/// `bf16 bits → f32` (exact: bf16 is a truncated f32).
#[inline]
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

// ---------------------------------------------------------------------------
// Typed elements.
// ---------------------------------------------------------------------------

/// A KV storage element the kernels can be monomorphized over. Loads widen
/// to f32 (`to_f32`), stores narrow from f32 (`from_f32`); all arithmetic
/// stays in f32.
pub trait KvElem: Copy + Send + Sync + 'static {
    const DTYPE: KvDtype;
    fn from_f32(x: f32) -> Self;
    fn to_f32(self) -> f32;

    /// Zero-copy f32 view when the element already *is* f32 (lets the
    /// SIMD kernel skip the widening copy entirely at full precision).
    #[inline]
    fn as_f32(slice: &[Self]) -> Option<&[f32]> {
        let _ = slice;
        None
    }

    /// Zero-copy i8 view when the element is the quantized container (the
    /// kernel's int8 branch feeds this to [`crate::util::simd::widen_i8`]
    /// together with the slab's per-group scale).
    #[inline]
    fn as_i8(slice: &[Self]) -> Option<&[i8]> {
        let _ = slice;
        None
    }

    /// Widen a whole slice to f32 through the SIMD seam (exact for every
    /// dtype: f16/bf16→f32 conversion never rounds). `dst` must be the
    /// same length as `src`.
    #[inline]
    fn widen_into(src: &[Self], dst: &mut [f32]) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = s.to_f32();
        }
    }
}

/// IEEE-754 binary16 element (bit container + conversions).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct F16(pub u16);

/// Symmetric-int8 element: the raw quantized container. The per-group
/// scale lives on the owning [`KvSlab`], so `to_f32`/`from_f32` here are
/// the *unscaled* integer conversions — the kernels never use them alone;
/// the int8 load path goes through `simd::widen_i8(…, scale, …)` with the
/// slab's group scale, and the store path through [`KvSlab::write_f32`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct I8(pub i8);

/// Quantize one value at a fixed symmetric scale: `round(x / scale)`
/// saturated to `[-127, 127]` (−128 is unused so the range is symmetric).
/// A zero scale means the group has only ever held zeros.
#[inline]
pub fn quantize_i8(x: f32, scale: f32) -> i8 {
    if scale == 0.0 {
        return 0;
    }
    (x / scale).round().clamp(-127.0, 127.0) as i8
}

/// bfloat16 element (bit container + conversions).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct Bf16(pub u16);

impl KvElem for f32 {
    const DTYPE: KvDtype = KvDtype::F32;
    #[inline]
    fn from_f32(x: f32) -> Self {
        x
    }
    #[inline]
    fn to_f32(self) -> f32 {
        self
    }
    #[inline]
    fn as_f32(slice: &[Self]) -> Option<&[f32]> {
        Some(slice)
    }
    #[inline]
    fn widen_into(src: &[Self], dst: &mut [f32]) {
        dst.copy_from_slice(src);
    }
}

impl KvElem for F16 {
    const DTYPE: KvDtype = KvDtype::F16;
    #[inline]
    fn from_f32(x: f32) -> Self {
        F16(f32_to_f16_bits(x))
    }
    #[inline]
    fn to_f32(self) -> f32 {
        f16_bits_to_f32(self.0)
    }
    #[inline]
    fn widen_into(src: &[Self], dst: &mut [f32]) {
        // Safety: F16 is repr(transparent) over u16, so the slice casts
        // losslessly to its bit patterns.
        let bits = unsafe { std::slice::from_raw_parts(src.as_ptr() as *const u16, src.len()) };
        crate::util::simd::widen_f16(crate::util::simd::active(), bits, dst);
    }
}

impl KvElem for I8 {
    const DTYPE: KvDtype = KvDtype::Int8;
    #[inline]
    fn from_f32(x: f32) -> Self {
        // Unscaled (scale = 1): only meaningful through the slab adapters,
        // which own the group scale. Kept total so the trait stays object-
        // safe over every dtype.
        I8(quantize_i8(x, 1.0))
    }
    #[inline]
    fn to_f32(self) -> f32 {
        self.0 as f32
    }
    #[inline]
    fn as_i8(slice: &[Self]) -> Option<&[i8]> {
        // Safety: I8 is repr(transparent) over i8.
        Some(unsafe { std::slice::from_raw_parts(slice.as_ptr() as *const i8, slice.len()) })
    }
}

impl KvElem for Bf16 {
    const DTYPE: KvDtype = KvDtype::Bf16;
    #[inline]
    fn from_f32(x: f32) -> Self {
        Bf16(f32_to_bf16_bits(x))
    }
    #[inline]
    fn to_f32(self) -> f32 {
        bf16_bits_to_f32(self.0)
    }
    #[inline]
    fn widen_into(src: &[Self], dst: &mut [f32]) {
        // Safety: Bf16 is repr(transparent) over u16 (see F16::widen_into).
        let bits = unsafe { std::slice::from_raw_parts(src.as_ptr() as *const u16, src.len()) };
        crate::util::simd::widen_bf16(crate::util::simd::active(), bits, dst);
    }
}

// ---------------------------------------------------------------------------
// Dtype-erased storage.
// ---------------------------------------------------------------------------

/// A dtype-erased element slab: the storage unit behind every KV chunk,
/// page and dense buffer. Backed by `u64` words so every supported element
/// type is alignment-safe; exposes typed slice views for the monomorphized
/// kernels and f32 read/write adapters for the dtype-agnostic managers.
#[derive(Clone)]
pub struct KvSlab {
    dtype: KvDtype,
    /// Length in elements (not bytes).
    len: usize,
    raw: Box<[u64]>,
    /// Int8 only: elements per scale group (the chunk layouts use one
    /// group per head, so a head's rows share one scale). Float dtypes
    /// keep a single degenerate group.
    group: usize,
    /// Int8 only: per-group symmetric scales (`x ≈ q · scale`); empty for
    /// float dtypes. A scale of 0.0 marks a group that has only ever held
    /// zeros.
    scales: Box<[f32]>,
}

impl std::fmt::Debug for KvSlab {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KvSlab({} x {})", self.len, self.dtype.label())
    }
}

impl KvSlab {
    /// Zero-initialised slab of `len` elements (one scale group for int8).
    pub fn zeroed(dtype: KvDtype, len: usize) -> Self {
        KvSlab::zeroed_grouped(dtype, len, len.max(1))
    }

    /// Zero-initialised slab with `group` elements per int8 scale group
    /// (`group` must divide `len`; ignored for float dtypes). The chunk,
    /// page and dense layouts pass one head's span so quantization error
    /// is bounded per head, not per tensor.
    pub fn zeroed_grouped(dtype: KvDtype, len: usize, group: usize) -> Self {
        let words = (len * dtype.bytes()).div_ceil(8);
        let group = group.max(1);
        let scales = if dtype == KvDtype::Int8 {
            assert!(len % group == 0, "scale group {group} must divide slab len {len}");
            vec![0.0f32; len / group]
        } else {
            Vec::new()
        };
        KvSlab { dtype, len, raw: vec![0u64; words].into_boxed_slice(), group, scales: scales.into() }
    }

    /// The symmetric scale of int8 group `g`; identity (1.0) for float
    /// dtypes so kernel call sites can pass it unconditionally.
    #[inline]
    pub fn group_scale(&self, g: usize) -> f32 {
        if self.dtype == KvDtype::Int8 {
            self.scales[g]
        } else {
            1.0
        }
    }

    /// Elements per int8 scale group (slab length for float dtypes).
    #[inline]
    pub fn group_len(&self) -> usize {
        self.group
    }

    /// Forget all int8 scales (no-op for float dtypes). Called when a
    /// pooled chunk is recycled: the stale scales would otherwise make
    /// fresh writes quantize at the previous tenant's (possibly much
    /// coarser) scale.
    #[inline]
    pub fn reset_scales(&mut self) {
        self.scales.fill(0.0);
    }

    #[inline]
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Elements stored (fixed at construction).
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes of element payload plus per-group scale metadata (what
    /// accounting reports — int8 carries 4 scale bytes per group).
    #[inline]
    pub fn payload_bytes(&self) -> usize {
        self.len * self.dtype.bytes() + self.scales.len() * 4
    }

    /// Typed element view. Panics if `E` does not match the slab's dtype —
    /// the kernels dispatch on [`KvDtype`] once per call, so a mismatch is
    /// a programming error, not a data error.
    #[inline]
    pub fn as_slice<E: KvElem>(&self) -> &[E] {
        assert!(E::DTYPE == self.dtype, "slab is {:?}, requested {:?}", self.dtype, E::DTYPE);
        // Safety: `raw` is 8-byte aligned (≥ align_of::<E>()), holds at
        // least `len * size_of::<E>()` bytes, and every bit pattern is a
        // valid `f32`/`F16`/`Bf16` (the `u16` wrappers are
        // repr(transparent)).
        unsafe { std::slice::from_raw_parts(self.raw.as_ptr() as *const E, self.len) }
    }

    /// Typed mutable element view (same contract as [`KvSlab::as_slice`]).
    #[inline]
    pub fn as_mut_slice<E: KvElem>(&mut self) -> &mut [E] {
        assert!(E::DTYPE == self.dtype, "slab is {:?}, requested {:?}", self.dtype, E::DTYPE);
        // Safety: as in `as_slice`, plus exclusive access via `&mut self`.
        unsafe { std::slice::from_raw_parts_mut(self.raw.as_mut_ptr() as *mut E, self.len) }
    }

    /// Store `src` (f32) at element offset `offset`, narrowing to the
    /// slab's dtype.
    pub fn write_f32(&mut self, offset: usize, src: &[f32]) {
        assert!(offset + src.len() <= self.len, "slab write out of range");
        match self.dtype {
            KvDtype::F32 => {
                self.as_mut_slice::<f32>()[offset..offset + src.len()].copy_from_slice(src);
            }
            KvDtype::F16 => {
                let dst = &mut self.as_mut_slice::<F16>()[offset..offset + src.len()];
                for (d, &x) in dst.iter_mut().zip(src) {
                    *d = F16::from_f32(x);
                }
            }
            KvDtype::Bf16 => {
                let dst = &mut self.as_mut_slice::<Bf16>()[offset..offset + src.len()];
                for (d, &x) in dst.iter_mut().zip(src) {
                    *d = Bf16::from_f32(x);
                }
            }
            KvDtype::Int8 => {
                // Writes must stay inside one scale group (the cache
                // layouts write per head, which is exactly one group).
                let group = self.group;
                let g = offset / group;
                assert!(
                    offset % group + src.len() <= group,
                    "int8 write spans scale groups (offset {offset}, len {}, group {group})",
                    src.len()
                );
                let mut max_abs = 0f32;
                for &x in src {
                    max_abs = max_abs.max(x.abs());
                }
                let needed = max_abs / 127.0;
                let old = self.scales[g];
                if needed > old {
                    // Requant-on-grow: the new value needs a coarser scale,
                    // so re-round the whole group at it (adds at most half
                    // a step on top of each element's original half step —
                    // the `unit_roundoff` budget covers exactly this).
                    if old > 0.0 {
                        let (start, end) = (g * group, (g + 1) * group);
                        let q = self.as_mut_slice::<I8>();
                        for e in &mut q[start..end] {
                            *e = I8(quantize_i8(e.0 as f32 * old, needed));
                        }
                    }
                    self.scales[g] = needed;
                }
                let scale = self.scales[g];
                let dst = &mut self.as_mut_slice::<I8>()[offset..offset + src.len()];
                for (d, &x) in dst.iter_mut().zip(src) {
                    *d = I8(quantize_i8(x, scale));
                }
            }
        }
    }

    /// Load `dst.len()` elements starting at `offset`, widening to f32.
    pub fn read_f32(&self, offset: usize, dst: &mut [f32]) {
        assert!(offset + dst.len() <= self.len, "slab read out of range");
        match self.dtype {
            KvDtype::F32 => {
                dst.copy_from_slice(&self.as_slice::<f32>()[offset..offset + dst.len()]);
            }
            KvDtype::F16 => {
                let src = &self.as_slice::<F16>()[offset..offset + dst.len()];
                for (d, &x) in dst.iter_mut().zip(src) {
                    *d = x.to_f32();
                }
            }
            KvDtype::Bf16 => {
                let src = &self.as_slice::<Bf16>()[offset..offset + dst.len()];
                for (d, &x) in dst.iter_mut().zip(src) {
                    *d = x.to_f32();
                }
            }
            KvDtype::Int8 => {
                // Reads may span groups: dequantize elementwise at each
                // element's own group scale (exact: i8→f32 convert is exact
                // and the multiply rounds once, same as the kernel's
                // widening load).
                let src = &self.as_slice::<I8>()[offset..offset + dst.len()];
                for (i, (d, &x)) in dst.iter_mut().zip(src).enumerate() {
                    *d = x.0 as f32 * self.scales[(offset + i) / self.group];
                }
            }
        }
    }

    /// Copy `n` elements from `src[src_off..]` into `self[dst_off..]`
    /// without widening (both slabs must share a dtype) — chunk splits move
    /// rows between slabs bit-exactly.
    pub fn copy_range_from(&mut self, src: &KvSlab, src_off: usize, dst_off: usize, n: usize) {
        assert!(self.dtype == src.dtype, "slab dtype mismatch in copy");
        assert!(src_off + n <= src.len && dst_off + n <= self.len, "slab copy out of range");
        match self.dtype {
            KvDtype::F32 => {
                let s = &src.as_slice::<f32>()[src_off..src_off + n];
                self.as_mut_slice::<f32>()[dst_off..dst_off + n].copy_from_slice(s);
            }
            KvDtype::F16 => {
                let s = &src.as_slice::<F16>()[src_off..src_off + n];
                self.as_mut_slice::<F16>()[dst_off..dst_off + n].copy_from_slice(s);
            }
            KvDtype::Bf16 => {
                let s = &src.as_slice::<Bf16>()[src_off..src_off + n];
                self.as_mut_slice::<Bf16>()[dst_off..dst_off + n].copy_from_slice(s);
            }
            KvDtype::Int8 => {
                // Walk runs that stay inside one (src group, dst group)
                // pair. When the destination group's scale matches (or the
                // group is still all-zero and can adopt the source scale)
                // the quantized bytes copy over bit-exactly — this is the
                // path chunk splits and page COW take, preserving the
                // bit-identity guarantees. Mismatched scales fall back to
                // dequant + write_f32 (requantize at the dst scale).
                let mut i = 0;
                while i < n {
                    let so = src_off + i;
                    let do_ = dst_off + i;
                    let sg = so / src.group;
                    let dg = do_ / self.group;
                    let run_end = ((sg + 1) * src.group - so).min((dg + 1) * self.group - do_);
                    let run = run_end.min(n - i);
                    let s_scale = src.scales[sg];
                    let d_scale = self.scales[dg];
                    if d_scale == s_scale || d_scale == 0.0 {
                        if d_scale == 0.0 && s_scale != 0.0 {
                            // A zero-scale group holds only zeros, so
                            // adopting the source scale re-interprets them
                            // as exact zeros — still bit-exact.
                            self.scales[dg] = s_scale;
                        }
                        let s = &src.as_slice::<I8>()[so..so + run];
                        // Borrow note: take the typed view after the scale
                        // update above (both need `&mut self`).
                        self.as_mut_slice::<I8>()[do_..do_ + run].copy_from_slice(s);
                    } else {
                        let mut tmp = vec![0.0f32; run];
                        src.read_f32(so, &mut tmp);
                        self.write_f32(do_, &tmp);
                    }
                    i += run;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference encode vectors generated against IEEE-754 semantics
    /// (cross-checked with numpy's float16 cast and a bit-exact bf16 RNE
    /// model): `(f32 bits, f16 bits, bf16 bits)`.
    const ENCODE_VECTORS: &[(u32, u16, u16)] = &[
        (0x00000000, 0x0000, 0x0000), // 0.0
        (0x80000000, 0x8000, 0x8000), // -0.0
        (0x3f800000, 0x3c00, 0x3f80), // 1.0
        (0xbf800000, 0xbc00, 0xbf80), // -1.0
        (0x3f000000, 0x3800, 0x3f00), // 0.5
        (0x477fe000, 0x7bff, 0x4780), // 65504.0 (f16 max)
        (0x477fefe6, 0x7bff, 0x4780), // 65519.9 (below overflow tie)
        (0x477ff000, 0x7c00, 0x4780), // 65520.0 (tie -> +inf)
        (0x4e6e6b28, 0x7c00, 0x4e6e), // 1e9 (f16 overflow, bf16 fine)
        (0xce6e6b28, 0xfc00, 0xce6e), // -1e9
        (0x33800000, 0x0001, 0x3380), // 2^-24 (smallest f16 subnormal)
        (0x33000000, 0x0000, 0x3300), // 2^-25 (tie -> even -> 0)
        (0x33000001, 0x0001, 0x3300), // just above 2^-25 -> rounds up
        (0x38800000, 0x0400, 0x3880), // 2^-14 (smallest f16 normal)
        (0x38000000, 0x0200, 0x3800), // 2^-15 (subnormal)
        (0x3f801000, 0x3c00, 0x3f80), // 1 + 2^-11 (tie -> even, down)
        (0x3f800800, 0x3c00, 0x3f80), // 1 + 2^-12 (rounds down)
        (0x3f801800, 0x3c01, 0x3f80), // 1 + 3*2^-12 (rounds up)
        (0x40490fdb, 0x4248, 0x4049), // pi
        (0xc02df84d, 0xc170, 0xc02e), // -e
    ];

    #[test]
    fn f16_encode_matches_reference_vectors() {
        for &(bits, f16, _) in ENCODE_VECTORS {
            let got = f32_to_f16_bits(f32::from_bits(bits));
            assert_eq!(got, f16, "f32 bits {bits:#010x}: got {got:#06x}, want {f16:#06x}");
        }
    }

    #[test]
    fn bf16_encode_matches_reference_vectors() {
        for &(bits, _, bf16) in ENCODE_VECTORS {
            let got = f32_to_bf16_bits(f32::from_bits(bits));
            assert_eq!(got, bf16, "f32 bits {bits:#010x}: got {got:#06x}, want {bf16:#06x}");
        }
    }

    // The exhaustive 65536-pattern round-trip sweeps live in
    // rust/tests/dtype_numerics.rs (`conversion_round_trip_sweeps`), which
    // the CI dtype matrix runs under both debug (overflow checks on the
    // bit-twiddling) and --release — not duplicated here.

    fn via_f16(x: f32) -> f32 {
        F16::from_f32(x).to_f32()
    }

    fn via_bf16(x: f32) -> f32 {
        Bf16::from_f32(x).to_f32()
    }

    #[test]
    fn special_values_survive_conversion() {
        for dtype_conv in [via_f16 as fn(f32) -> f32, via_bf16] {
            assert_eq!(dtype_conv(f32::INFINITY), f32::INFINITY);
            assert_eq!(dtype_conv(f32::NEG_INFINITY), f32::NEG_INFINITY);
            assert!(dtype_conv(f32::NAN).is_nan());
            let z = dtype_conv(0.0);
            assert_eq!(z, 0.0);
            assert!(z.is_sign_positive());
            let nz = dtype_conv(-0.0);
            assert_eq!(nz, 0.0);
            assert!(nz.is_sign_negative());
        }
    }

    #[test]
    fn conversion_error_is_within_unit_roundoff() {
        // Deterministic sweep of magnitudes across both dtypes' normal
        // ranges: |round(x) - x| <= u * |x| for normal values.
        let mut x = 6.2e-5f32; // above the f16 subnormal range
        while x < 6.0e4 {
            for &v in &[x, -x, x * 1.337, x * 0.9113] {
                let f16_err = (F16::from_f32(v).to_f32() - v).abs();
                assert!(
                    f16_err <= KvDtype::F16.unit_roundoff() * v.abs(),
                    "f16 err {f16_err} at {v}"
                );
                let bf_err = (Bf16::from_f32(v).to_f32() - v).abs();
                assert!(
                    bf_err <= KvDtype::Bf16.unit_roundoff() * v.abs(),
                    "bf16 err {bf_err} at {v}"
                );
            }
            x *= 1.7;
        }
    }

    #[test]
    fn slab_typed_views_and_f32_adapters_agree() {
        for dtype in KvDtype::ALL {
            let mut slab = KvSlab::zeroed(dtype, 11);
            assert_eq!(slab.len(), 11);
            let scale_bytes = if dtype == KvDtype::Int8 { 4 } else { 0 };
            assert_eq!(slab.payload_bytes(), 11 * dtype.bytes() + scale_bytes);
            let src: Vec<f32> = (0..7).map(|i| i as f32 * 0.25 - 0.8).collect();
            slab.write_f32(3, &src);
            let mut back = vec![0.0f32; 7];
            slab.read_f32(3, &mut back);
            for (a, b) in back.iter().zip(&src) {
                let tol = dtype.unit_roundoff() * (1.0 + b.abs());
                assert!((a - b).abs() <= tol, "{dtype:?}: {a} vs {b}");
            }
            // Elements before the write stay zero.
            let mut head = vec![1.0f32; 3];
            slab.read_f32(0, &mut head);
            assert_eq!(head, vec![0.0; 3]);
        }
    }

    #[test]
    fn slab_copy_range_is_bit_exact() {
        for dtype in KvDtype::ALL {
            let mut a = KvSlab::zeroed(dtype, 8);
            let src: Vec<f32> = (0..8).map(|i| (i as f32).sin()).collect();
            a.write_f32(0, &src);
            let mut b = KvSlab::zeroed(dtype, 8);
            b.copy_range_from(&a, 2, 5, 3);
            let (mut from_a, mut from_b) = (vec![0.0f32; 3], vec![0.0f32; 3]);
            a.read_f32(2, &mut from_a);
            b.read_f32(5, &mut from_b);
            assert_eq!(from_a, from_b, "{dtype:?}");
        }
    }

    #[test]
    #[should_panic(expected = "slab is")]
    fn slab_typed_view_checks_dtype() {
        let slab = KvSlab::zeroed(KvDtype::F16, 4);
        let _ = slab.as_slice::<f32>();
    }

    #[test]
    fn dtype_parse_and_labels_round_trip() {
        for dtype in KvDtype::ALL {
            assert_eq!(KvDtype::parse(dtype.label()), Some(dtype));
        }
        assert_eq!(KvDtype::parse("fp16"), Some(KvDtype::F16));
        assert_eq!(KvDtype::parse("bfloat16"), Some(KvDtype::Bf16));
        assert_eq!(KvDtype::parse("i8"), Some(KvDtype::Int8));
        assert_eq!(KvDtype::parse("uint8"), None);
    }

    #[test]
    fn int8_write_read_round_trips_within_one_step() {
        let mut slab = KvSlab::zeroed_grouped(KvDtype::Int8, 16, 8);
        let src: Vec<f32> = (0..8).map(|i| (i as f32 - 3.5) * 0.31).collect();
        slab.write_f32(0, &src);
        let mut back = vec![0.0f32; 8];
        slab.read_f32(0, &mut back);
        let group_max = src.iter().fold(0f32, |m, x| m.max(x.abs()));
        // Fresh quantization: within half a step of the group scale.
        let half_step = group_max / 254.0 + 1e-7;
        for (a, b) in back.iter().zip(&src) {
            assert!((a - b).abs() <= half_step, "{a} vs {b}");
        }
        // Second group untouched: scale stays 0 and reads give exact zeros.
        assert_eq!(slab.group_scale(1), 0.0);
        let mut tail = vec![1.0f32; 8];
        slab.read_f32(8, &mut tail);
        assert_eq!(tail, vec![0.0; 8]);
    }

    #[test]
    fn int8_requant_on_grow_stays_within_budget() {
        let mut slab = KvSlab::zeroed_grouped(KvDtype::Int8, 8, 8);
        let first: Vec<f32> = vec![0.5, -0.25, 0.125, 0.75];
        slab.write_f32(0, &first);
        // A later, larger write forces the group scale to grow and the
        // earlier elements to requantize.
        let second: Vec<f32> = vec![4.0, -2.0, 1.0, -4.0];
        slab.write_f32(4, &second);
        let mut back = vec![0.0f32; 8];
        slab.read_f32(0, &mut back);
        let group_max = 4.0f32;
        // One full step (fresh half step + requant half step) of the final
        // group max bounds every element — the unit_roundoff contract.
        let step = group_max * KvDtype::Int8.unit_roundoff() + 1e-7;
        for (i, (a, b)) in back.iter().zip(first.iter().chain(&second)).enumerate() {
            assert!((a - b).abs() <= step, "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn int8_copy_adopts_scale_bit_exactly_and_requants_on_mismatch() {
        let mut a = KvSlab::zeroed_grouped(KvDtype::Int8, 8, 8);
        let src: Vec<f32> = (0..8).map(|i| (i as f32).sin()).collect();
        a.write_f32(0, &src);

        // Fresh destination group: adopts the source scale, bytes bit-exact.
        let mut b = KvSlab::zeroed_grouped(KvDtype::Int8, 8, 8);
        b.copy_range_from(&a, 0, 0, 8);
        assert_eq!(b.group_scale(0), a.group_scale(0));
        assert_eq!(I8::as_i8(b.as_slice::<I8>()), I8::as_i8(a.as_slice::<I8>()));

        // Destination with a different established scale: requant fallback
        // lands within one step of the source's dequantized values.
        let mut c = KvSlab::zeroed_grouped(KvDtype::Int8, 8, 8);
        c.write_f32(0, &[2.0; 8]);
        c.copy_range_from(&a, 0, 0, 8);
        let (mut from_a, mut from_c) = (vec![0.0f32; 8], vec![0.0f32; 8]);
        a.read_f32(0, &mut from_a);
        c.read_f32(0, &mut from_c);
        let step = 2.0 * KvDtype::Int8.unit_roundoff() + 1e-7;
        for (x, y) in from_a.iter().zip(&from_c) {
            assert!((x - y).abs() <= step, "{x} vs {y}");
        }
    }
}
