//! Chunked KV storage and the pool-based chunk allocator (§3.1).
//!
//! A [`Chunk`] holds `c` context tokens plus their key/value tensor slices
//! laid out `[heads, c, head_dim]` so that a per-head slice is contiguous —
//! the chunk-first kernel streams one head's `K^(C)` as a dense `c×d` block.
//! K/V live in dtype-erased [`KvSlab`]s ([`KvShape::dtype`] selects `f32`,
//! `f16` or `bf16` storage); the kernels take typed row views
//! ([`Chunk::k_head`]) monomorphized per dtype, while managers and tests
//! use the widening f32 adapters.
//!
//! The [`ChunkPool`] is the paper's pool allocator (Hill 1992): a free list
//! backed by never-released memory. Freed chunks go back to the free list;
//! fresh chunks come from the free list when possible and from the global
//! allocator otherwise. Accounting distinguishes *allocated* (high-water)
//! from *in-use* bytes so benches can report peak KV cache like Table 4 —
//! and reports the bytes actually allocated at the active dtype (storing at
//! `f16` halves every number relative to `f32`, there is no separate
//! "paper accounting" anymore).

use super::dtype::{KvDtype, KvElem, KvSlab};

/// Static shape of every chunk in a pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvShape {
    /// Number of attention heads `h`.
    pub heads: usize,
    /// Per-head dimension `d`.
    pub head_dim: usize,
    /// Tokens per chunk `c`.
    pub chunk_size: usize,
    /// Storage format of every K/V element.
    pub dtype: KvDtype,
}

impl KvShape {
    /// Shape with the default `f32` storage (see [`KvShape::with_dtype`]).
    pub fn new(heads: usize, head_dim: usize, chunk_size: usize) -> Self {
        assert!(heads > 0 && head_dim > 0 && chunk_size > 0);
        KvShape { heads, head_dim, chunk_size, dtype: KvDtype::F32 }
    }

    /// Same shape, stored at `dtype`.
    pub fn with_dtype(mut self, dtype: KvDtype) -> Self {
        self.dtype = dtype;
        self
    }

    /// Elements in one of K or V for a full chunk.
    pub fn elems_per_tensor(&self) -> usize {
        self.heads * self.chunk_size * self.head_dim
    }

    /// Bytes of K+V storage per chunk as actually allocated (dtype-aware;
    /// int8 includes the per-head f32 scale each of K and V carries).
    pub fn bytes_per_chunk(&self) -> usize {
        let scale_bytes = if self.dtype == KvDtype::Int8 { 2 * self.heads * 4 } else { 0 };
        2 * self.elems_per_tensor() * self.dtype.bytes() + scale_bytes
    }

    /// Allocate one K or V slab for this shape: for int8 the scale groups
    /// are per head (`chunk_size * head_dim` elements), so a head's rows —
    /// the unit the kernels stream — share a single dequant scale.
    pub fn new_slab(&self) -> KvSlab {
        KvSlab::zeroed_grouped(self.dtype, self.elems_per_tensor(), self.chunk_size * self.head_dim)
    }

    /// Offset of `(head, pos)` row inside a chunk tensor.
    #[inline]
    pub fn row_offset(&self, head: usize, pos: usize) -> usize {
        (head * self.chunk_size + pos) * self.head_dim
    }
}

/// Handle to a chunk inside its pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ChunkId(pub u32);

/// One KV chunk: token ids for prefix matching plus K/V tensor slabs.
#[derive(Debug)]
pub struct Chunk {
    /// Context tokens stored here (`len <= chunk_size`); drives tree lookups.
    tokens: Vec<u32>,
    /// Key slab, `[heads, chunk_size, head_dim]` elements.
    k: KvSlab,
    /// Value slab, `[heads, chunk_size, head_dim]` elements.
    v: KvSlab,
}

impl Chunk {
    fn new(shape: &KvShape) -> Self {
        Chunk {
            tokens: Vec::with_capacity(shape.chunk_size),
            k: shape.new_slab(),
            v: shape.new_slab(),
        }
    }

    fn reset(&mut self) {
        self.tokens.clear();
        // K/V rows are overwritten before use; zeroing is not required for
        // correctness but keeps stale data out of debugging dumps. Int8
        // scales must be forgotten, though — fresh writes would otherwise
        // quantize at the previous tenant's scale.
        self.k.reset_scales();
        self.v.reset_scales();
    }

    /// Number of tokens currently stored.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    /// The raw key slab (managers use the f32 adapters on it; kernels use
    /// the typed [`Chunk::k_head`] views).
    pub fn k_slab(&self) -> &KvSlab {
        &self.k
    }

    pub fn v_slab(&self) -> &KvSlab {
        &self.v
    }

    /// K rows for one head: contiguous `[chunk_size, head_dim]` typed
    /// slice. `E` must match `shape.dtype` (kernels dispatch once per call).
    #[inline]
    pub fn k_head<E: KvElem>(&self, shape: &KvShape, head: usize) -> &[E] {
        let base = head * shape.chunk_size * shape.head_dim;
        &self.k.as_slice::<E>()[base..base + shape.chunk_size * shape.head_dim]
    }

    /// V rows for one head.
    #[inline]
    pub fn v_head<E: KvElem>(&self, shape: &KvShape, head: usize) -> &[E] {
        let base = head * shape.chunk_size * shape.head_dim;
        &self.v.as_slice::<E>()[base..base + shape.chunk_size * shape.head_dim]
    }

    /// Dequant scale of head `head`'s K rows (1.0 for float dtypes). The
    /// slab's scale groups are laid out one per head (see
    /// [`KvShape::new_slab`]), so the group index *is* the head index.
    #[inline]
    pub fn k_head_scale(&self, _shape: &KvShape, head: usize) -> f32 {
        self.k.group_scale(head)
    }

    /// Dequant scale of head `head`'s V rows (1.0 for float dtypes).
    #[inline]
    pub fn v_head_scale(&self, _shape: &KvShape, head: usize) -> f32 {
        self.v.group_scale(head)
    }

    /// Append one token and its per-head K/V rows (narrowing f32 to the
    /// storage dtype). `k_rows`/`v_rows` are `[heads, head_dim]`.
    pub fn append(&mut self, shape: &KvShape, token: u32, k_rows: &[f32], v_rows: &[f32]) {
        assert!(self.tokens.len() < shape.chunk_size, "append to full chunk");
        assert_eq!(k_rows.len(), shape.heads * shape.head_dim);
        assert_eq!(v_rows.len(), shape.heads * shape.head_dim);
        let pos = self.tokens.len();
        for h in 0..shape.heads {
            let dst = shape.row_offset(h, pos);
            let src = h * shape.head_dim;
            self.k.write_f32(dst, &k_rows[src..src + shape.head_dim]);
            self.v.write_f32(dst, &v_rows[src..src + shape.head_dim]);
        }
        self.tokens.push(token);
    }

    /// Copy the suffix rows `[from..len)` of `src` into `self` (which must
    /// be empty) — used when a chunk is split at a divergence point. The
    /// copy is bit-exact (no re-rounding through f32).
    pub fn take_suffix_from(&mut self, shape: &KvShape, src: &mut Chunk, from: usize) {
        assert!(self.is_empty());
        assert!(from <= src.len());
        let n = src.len() - from;
        for h in 0..shape.heads {
            for p in 0..n {
                let s = shape.row_offset(h, from + p);
                let d = shape.row_offset(h, p);
                self.k.copy_range_from(&src.k, s, d, shape.head_dim);
                self.v.copy_range_from(&src.v, s, d, shape.head_dim);
            }
        }
        self.tokens.extend_from_slice(&src.tokens[from..]);
        src.tokens.truncate(from);
    }
}

/// Pool-based chunk allocator with a free list (§3.1).
pub struct ChunkPool {
    shape: KvShape,
    slots: Vec<Chunk>,
    free: Vec<ChunkId>,
    in_use: usize,
    peak_in_use: usize,
}

impl ChunkPool {
    pub fn new(shape: KvShape) -> Self {
        ChunkPool { shape, slots: Vec::new(), free: Vec::new(), in_use: 0, peak_in_use: 0 }
    }

    pub fn shape(&self) -> KvShape {
        self.shape
    }

    /// Acquire a chunk: reuse a freed slot if available, otherwise allocate
    /// fresh memory. Memory is never returned to the OS (paper §3.1).
    pub fn acquire(&mut self) -> ChunkId {
        // Chaos site: simulated slab-allocation failure. `acquire` has no
        // error channel, so both `panic` and `err` actions unwind here (the
        // gateway supervisor catches the unwind). No-op unless armed.
        if crate::util::failpoint::armed() {
            if let Some(msg) = crate::util::failpoint::fire("chunk.alloc") {
                panic!("{msg}");
            }
        }
        let id = match self.free.pop() {
            Some(id) => {
                self.slots[id.0 as usize].reset();
                id
            }
            None => {
                let id = ChunkId(self.slots.len() as u32);
                self.slots.push(Chunk::new(&self.shape));
                id
            }
        };
        self.in_use += 1;
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        id
    }

    /// Return a chunk to the free list.
    pub fn release(&mut self, id: ChunkId) {
        debug_assert!(!self.free.contains(&id), "double free of {id:?}");
        self.free.push(id);
        self.in_use -= 1;
    }

    pub fn get(&self, id: ChunkId) -> &Chunk {
        &self.slots[id.0 as usize]
    }

    pub fn get_mut(&mut self, id: ChunkId) -> &mut Chunk {
        &mut self.slots[id.0 as usize]
    }

    /// Two chunks mutably at once (for splits). Panics if `a == b`.
    pub fn get2_mut(&mut self, a: ChunkId, b: ChunkId) -> (&mut Chunk, &mut Chunk) {
        assert_ne!(a, b);
        let (ai, bi) = (a.0 as usize, b.0 as usize);
        if ai < bi {
            let (lo, hi) = self.slots.split_at_mut(bi);
            (&mut lo[ai], &mut hi[0])
        } else {
            let (lo, hi) = self.slots.split_at_mut(ai);
            (&mut hi[0], &mut lo[bi])
        }
    }

    /// Chunks currently handed out.
    pub fn in_use(&self) -> usize {
        self.in_use
    }

    /// High-water mark of simultaneously used chunks.
    pub fn peak_in_use(&self) -> usize {
        self.peak_in_use
    }

    /// Chunks ever allocated (slots), i.e. resident memory.
    pub fn allocated(&self) -> usize {
        self.slots.len()
    }

    /// Resident KV bytes as actually allocated at the pool's dtype.
    pub fn resident_bytes(&self) -> u64 {
        (self.allocated() * self.shape.bytes_per_chunk()) as u64
    }

    /// In-use KV bytes at the pool's dtype (what `/metrics` and Table-4
    /// style benches report, labelled with [`KvShape::dtype`]).
    pub fn in_use_bytes(&self) -> u64 {
        (self.in_use * self.shape.bytes_per_chunk()) as u64
    }

    /// Peak in-use KV bytes at the pool's dtype.
    pub fn peak_bytes(&self) -> u64 {
        (self.peak_in_use * self.shape.bytes_per_chunk()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> KvShape {
        KvShape::new(2, 4, 8)
    }

    fn rows(shape: &KvShape, base: f32) -> (Vec<f32>, Vec<f32>) {
        let n = shape.heads * shape.head_dim;
        let k: Vec<f32> = (0..n).map(|i| base + i as f32).collect();
        let v: Vec<f32> = (0..n).map(|i| base - i as f32).collect();
        (k, v)
    }

    #[test]
    fn append_places_rows_per_head() {
        let s = shape();
        let mut pool = ChunkPool::new(s);
        let id = pool.acquire();
        let (k, v) = rows(&s, 10.0);
        pool.get_mut(id).append(&s, 42, &k, &v);
        let c = pool.get(id);
        assert_eq!(c.tokens(), &[42]);
        // Head 1, pos 0 row must equal k[4..8].
        assert_eq!(&c.k_head::<f32>(&s, 1)[0..4], &k[4..8]);
        assert_eq!(&c.v_head::<f32>(&s, 1)[0..4], &v[4..8]);
    }

    #[test]
    fn append_round_trips_at_every_dtype() {
        for dtype in KvDtype::ALL {
            let s = shape().with_dtype(dtype);
            let mut pool = ChunkPool::new(s);
            let id = pool.acquire();
            let (k, v) = rows(&s, 0.25);
            pool.get_mut(id).append(&s, 7, &k, &v);
            let c = pool.get(id);
            let mut got = vec![0.0f32; s.head_dim];
            c.k_slab().read_f32(s.row_offset(1, 0), &mut got);
            for (g, want) in got.iter().zip(&k[s.head_dim..2 * s.head_dim]) {
                let tol = dtype.unit_roundoff() * (1.0 + want.abs());
                assert!((g - want).abs() <= tol, "{dtype:?}: {g} vs {want}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "append to full chunk")]
    fn append_past_capacity_panics() {
        let s = shape();
        let mut pool = ChunkPool::new(s);
        let id = pool.acquire();
        let (k, v) = rows(&s, 0.0);
        for t in 0..=s.chunk_size as u32 {
            pool.get_mut(id).append(&s, t, &k, &v);
        }
    }

    #[test]
    fn pool_reuses_freed_chunks() {
        let mut pool = ChunkPool::new(shape());
        let a = pool.acquire();
        let b = pool.acquire();
        assert_eq!(pool.allocated(), 2);
        pool.release(a);
        let c = pool.acquire();
        assert_eq!(c, a, "free list must be reused");
        assert_eq!(pool.allocated(), 2, "no fresh allocation");
        assert_eq!(pool.in_use(), 2);
        let _ = b;
    }

    #[test]
    fn pool_never_shrinks() {
        let mut pool = ChunkPool::new(shape());
        let ids: Vec<_> = (0..10).map(|_| pool.acquire()).collect();
        for id in ids {
            pool.release(id);
        }
        assert_eq!(pool.allocated(), 10);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.peak_in_use(), 10);
    }

    #[test]
    fn reacquired_chunk_is_reset() {
        let s = shape();
        let mut pool = ChunkPool::new(s);
        let id = pool.acquire();
        let (k, v) = rows(&s, 1.0);
        pool.get_mut(id).append(&s, 7, &k, &v);
        pool.release(id);
        let id2 = pool.acquire();
        assert_eq!(id2, id);
        assert!(pool.get(id2).is_empty());
    }

    #[test]
    fn split_moves_suffix() {
        let s = shape();
        let mut pool = ChunkPool::new(s);
        let a = pool.acquire();
        for t in 0..6u32 {
            let (k, v) = rows(&s, t as f32);
            pool.get_mut(a).append(&s, t, &k, &v);
        }
        let b = pool.acquire();
        let (ca, cb) = pool.get2_mut(a, b);
        cb.take_suffix_from(&s, ca, 4);
        assert_eq!(pool.get(a).tokens(), &[0, 1, 2, 3]);
        assert_eq!(pool.get(b).tokens(), &[4, 5]);
        // Row for token 4 (head 0) must now be at pos 0 of b.
        let (k4, _) = rows(&s, 4.0);
        assert_eq!(&pool.get(b).k_head::<f32>(&s, 0)[0..4], &k4[0..4]);
    }

    #[test]
    fn byte_accounting_tracks_the_active_dtype() {
        let s = shape(); // 2 heads * 8 tokens * 4 dim = 64 elems per tensor
        assert_eq!(s.elems_per_tensor(), 64);
        assert_eq!(s.bytes_per_chunk(), 512, "f32: 2 tensors x 64 elems x 4B");
        let s16 = s.with_dtype(KvDtype::F16);
        assert_eq!(s16.bytes_per_chunk(), 256, "f16 halves the chunk bytes");
        assert_eq!(s.with_dtype(KvDtype::Bf16).bytes_per_chunk(), 256);
        assert_eq!(
            s.with_dtype(KvDtype::Int8).bytes_per_chunk(),
            128 + 16,
            "int8: 2 tensors x 64 elems x 1B + 2 tensors x 2 heads x 4B scales"
        );

        let mut pool = ChunkPool::new(s16);
        let a = pool.acquire();
        assert_eq!(pool.in_use_bytes(), 256);
        assert_eq!(pool.resident_bytes(), 256);
        pool.release(a);
        assert_eq!(pool.in_use_bytes(), 0);
        assert_eq!(pool.peak_bytes(), 256);
        assert_eq!(pool.resident_bytes(), 256, "pool memory is never released");
    }
}
