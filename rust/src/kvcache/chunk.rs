//! Chunked KV storage and the pool-based chunk allocator (§3.1).
//!
//! A [`Chunk`] holds `c` context tokens plus their key/value tensor slices
//! laid out `[heads, c, head_dim]` so that a per-head slice is contiguous —
//! the chunk-first kernel streams one head's `K^(C)` as a dense `c×d` block.
//!
//! The [`ChunkPool`] is the paper's pool allocator (Hill 1992): a free list
//! backed by never-released memory. Freed chunks go back to the free list;
//! fresh chunks come from the free list when possible and from the global
//! allocator otherwise. Accounting distinguishes *allocated* (high-water)
//! from *in-use* bytes so benches can report peak KV cache like Table 4.

/// Static shape of every chunk in a pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvShape {
    /// Number of attention heads `h`.
    pub heads: usize,
    /// Per-head dimension `d`.
    pub head_dim: usize,
    /// Tokens per chunk `c`.
    pub chunk_size: usize,
}

impl KvShape {
    pub fn new(heads: usize, head_dim: usize, chunk_size: usize) -> Self {
        assert!(heads > 0 && head_dim > 0 && chunk_size > 0);
        KvShape { heads, head_dim, chunk_size }
    }

    /// f32 elements in one of K or V for a full chunk.
    pub fn elems_per_tensor(&self) -> usize {
        self.heads * self.chunk_size * self.head_dim
    }

    /// Bytes of K+V storage per chunk as allocated here (f32).
    pub fn bytes_per_chunk_f32(&self) -> usize {
        2 * self.elems_per_tensor() * 4
    }

    /// Bytes of K+V per chunk *as the paper counts them* (FP16), for
    /// paper-comparable GB numbers.
    pub fn bytes_per_chunk_fp16(&self) -> usize {
        2 * self.elems_per_tensor() * 2
    }

    /// Offset of `(head, pos)` row inside a chunk tensor.
    #[inline]
    pub fn row_offset(&self, head: usize, pos: usize) -> usize {
        (head * self.chunk_size + pos) * self.head_dim
    }
}

/// Handle to a chunk inside its pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ChunkId(pub u32);

/// One KV chunk: token ids for prefix matching plus K/V tensor slices.
#[derive(Debug)]
pub struct Chunk {
    /// Context tokens stored here (`len <= chunk_size`); drives tree lookups.
    tokens: Vec<u32>,
    /// Key slice, `[heads, chunk_size, head_dim]`.
    k: Box<[f32]>,
    /// Value slice, `[heads, chunk_size, head_dim]`.
    v: Box<[f32]>,
}

impl Chunk {
    fn new(shape: &KvShape) -> Self {
        Chunk {
            tokens: Vec::with_capacity(shape.chunk_size),
            k: vec![0.0; shape.elems_per_tensor()].into_boxed_slice(),
            v: vec![0.0; shape.elems_per_tensor()].into_boxed_slice(),
        }
    }

    fn reset(&mut self) {
        self.tokens.clear();
        // K/V rows are overwritten before use; zeroing is not required for
        // correctness but keeps stale data out of debugging dumps.
    }

    /// Number of tokens currently stored.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    pub fn k(&self) -> &[f32] {
        &self.k
    }

    pub fn v(&self) -> &[f32] {
        &self.v
    }

    /// K rows for one head: contiguous `[chunk_size, head_dim]` slice.
    #[inline]
    pub fn k_head(&self, shape: &KvShape, head: usize) -> &[f32] {
        let base = head * shape.chunk_size * shape.head_dim;
        &self.k[base..base + shape.chunk_size * shape.head_dim]
    }

    /// V rows for one head.
    #[inline]
    pub fn v_head(&self, shape: &KvShape, head: usize) -> &[f32] {
        let base = head * shape.chunk_size * shape.head_dim;
        &self.v[base..base + shape.chunk_size * shape.head_dim]
    }

    /// Append one token and its per-head K/V rows.
    /// `k_rows`/`v_rows` are `[heads, head_dim]`.
    pub fn append(&mut self, shape: &KvShape, token: u32, k_rows: &[f32], v_rows: &[f32]) {
        assert!(self.tokens.len() < shape.chunk_size, "append to full chunk");
        assert_eq!(k_rows.len(), shape.heads * shape.head_dim);
        assert_eq!(v_rows.len(), shape.heads * shape.head_dim);
        let pos = self.tokens.len();
        for h in 0..shape.heads {
            let dst = shape.row_offset(h, pos);
            let src = h * shape.head_dim;
            self.k[dst..dst + shape.head_dim].copy_from_slice(&k_rows[src..src + shape.head_dim]);
            self.v[dst..dst + shape.head_dim].copy_from_slice(&v_rows[src..src + shape.head_dim]);
        }
        self.tokens.push(token);
    }

    /// Copy the suffix rows `[from..len)` of `src` into `self` (which must be
    /// empty) — used when a chunk is split at a divergence point.
    pub fn take_suffix_from(&mut self, shape: &KvShape, src: &mut Chunk, from: usize) {
        assert!(self.is_empty());
        assert!(from <= src.len());
        let n = src.len() - from;
        for h in 0..shape.heads {
            for p in 0..n {
                let s = shape.row_offset(h, from + p);
                let d = shape.row_offset(h, p);
                self.k[d..d + shape.head_dim].copy_from_slice(&src.k[s..s + shape.head_dim]);
                self.v[d..d + shape.head_dim].copy_from_slice(&src.v[s..s + shape.head_dim]);
            }
        }
        self.tokens.extend_from_slice(&src.tokens[from..]);
        src.tokens.truncate(from);
    }
}

/// Pool-based chunk allocator with a free list (§3.1).
pub struct ChunkPool {
    shape: KvShape,
    slots: Vec<Chunk>,
    free: Vec<ChunkId>,
    in_use: usize,
    peak_in_use: usize,
}

impl ChunkPool {
    pub fn new(shape: KvShape) -> Self {
        ChunkPool { shape, slots: Vec::new(), free: Vec::new(), in_use: 0, peak_in_use: 0 }
    }

    pub fn shape(&self) -> KvShape {
        self.shape
    }

    /// Acquire a chunk: reuse a freed slot if available, otherwise allocate
    /// fresh memory. Memory is never returned to the OS (paper §3.1).
    pub fn acquire(&mut self) -> ChunkId {
        let id = match self.free.pop() {
            Some(id) => {
                self.slots[id.0 as usize].reset();
                id
            }
            None => {
                let id = ChunkId(self.slots.len() as u32);
                self.slots.push(Chunk::new(&self.shape));
                id
            }
        };
        self.in_use += 1;
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        id
    }

    /// Return a chunk to the free list.
    pub fn release(&mut self, id: ChunkId) {
        debug_assert!(!self.free.contains(&id), "double free of {id:?}");
        self.free.push(id);
        self.in_use -= 1;
    }

    pub fn get(&self, id: ChunkId) -> &Chunk {
        &self.slots[id.0 as usize]
    }

    pub fn get_mut(&mut self, id: ChunkId) -> &mut Chunk {
        &mut self.slots[id.0 as usize]
    }

    /// Two chunks mutably at once (for splits). Panics if `a == b`.
    pub fn get2_mut(&mut self, a: ChunkId, b: ChunkId) -> (&mut Chunk, &mut Chunk) {
        assert_ne!(a, b);
        let (ai, bi) = (a.0 as usize, b.0 as usize);
        if ai < bi {
            let (lo, hi) = self.slots.split_at_mut(bi);
            (&mut lo[ai], &mut hi[0])
        } else {
            let (lo, hi) = self.slots.split_at_mut(ai);
            (&mut hi[0], &mut lo[bi])
        }
    }

    /// Chunks currently handed out.
    pub fn in_use(&self) -> usize {
        self.in_use
    }

    /// High-water mark of simultaneously used chunks.
    pub fn peak_in_use(&self) -> usize {
        self.peak_in_use
    }

    /// Chunks ever allocated (slots), i.e. resident memory.
    pub fn allocated(&self) -> usize {
        self.slots.len()
    }

    /// Resident KV bytes as allocated (f32).
    pub fn resident_bytes_f32(&self) -> u64 {
        (self.allocated() * self.shape.bytes_per_chunk_f32()) as u64
    }

    /// In-use KV bytes counted at FP16 like the paper's Table 4.
    pub fn in_use_bytes_fp16(&self) -> u64 {
        (self.in_use * self.shape.bytes_per_chunk_fp16()) as u64
    }

    /// Peak in-use KV bytes counted at FP16.
    pub fn peak_bytes_fp16(&self) -> u64 {
        (self.peak_in_use * self.shape.bytes_per_chunk_fp16()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> KvShape {
        KvShape::new(2, 4, 8)
    }

    fn rows(shape: &KvShape, base: f32) -> (Vec<f32>, Vec<f32>) {
        let n = shape.heads * shape.head_dim;
        let k: Vec<f32> = (0..n).map(|i| base + i as f32).collect();
        let v: Vec<f32> = (0..n).map(|i| base - i as f32).collect();
        (k, v)
    }

    #[test]
    fn append_places_rows_per_head() {
        let s = shape();
        let mut pool = ChunkPool::new(s);
        let id = pool.acquire();
        let (k, v) = rows(&s, 10.0);
        pool.get_mut(id).append(&s, 42, &k, &v);
        let c = pool.get(id);
        assert_eq!(c.tokens(), &[42]);
        // Head 1, pos 0 row must equal k[4..8].
        assert_eq!(&c.k_head(&s, 1)[0..4], &k[4..8]);
        assert_eq!(&c.v_head(&s, 1)[0..4], &v[4..8]);
    }

    #[test]
    #[should_panic(expected = "append to full chunk")]
    fn append_past_capacity_panics() {
        let s = shape();
        let mut pool = ChunkPool::new(s);
        let id = pool.acquire();
        let (k, v) = rows(&s, 0.0);
        for t in 0..=s.chunk_size as u32 {
            pool.get_mut(id).append(&s, t, &k, &v);
        }
    }

    #[test]
    fn pool_reuses_freed_chunks() {
        let mut pool = ChunkPool::new(shape());
        let a = pool.acquire();
        let b = pool.acquire();
        assert_eq!(pool.allocated(), 2);
        pool.release(a);
        let c = pool.acquire();
        assert_eq!(c, a, "free list must be reused");
        assert_eq!(pool.allocated(), 2, "no fresh allocation");
        assert_eq!(pool.in_use(), 2);
        let _ = b;
    }

    #[test]
    fn pool_never_shrinks() {
        let mut pool = ChunkPool::new(shape());
        let ids: Vec<_> = (0..10).map(|_| pool.acquire()).collect();
        for id in ids {
            pool.release(id);
        }
        assert_eq!(pool.allocated(), 10);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.peak_in_use(), 10);
    }

    #[test]
    fn reacquired_chunk_is_reset() {
        let s = shape();
        let mut pool = ChunkPool::new(s);
        let id = pool.acquire();
        let (k, v) = rows(&s, 1.0);
        pool.get_mut(id).append(&s, 7, &k, &v);
        pool.release(id);
        let id2 = pool.acquire();
        assert_eq!(id2, id);
        assert!(pool.get(id2).is_empty());
    }

    #[test]
    fn split_moves_suffix() {
        let s = shape();
        let mut pool = ChunkPool::new(s);
        let a = pool.acquire();
        for t in 0..6u32 {
            let (k, v) = rows(&s, t as f32);
            pool.get_mut(a).append(&s, t, &k, &v);
        }
        let b = pool.acquire();
        let (ca, cb) = pool.get2_mut(a, b);
        cb.take_suffix_from(&s, ca, 4);
        assert_eq!(pool.get(a).tokens(), &[0, 1, 2, 3]);
        assert_eq!(pool.get(b).tokens(), &[4, 5]);
        // Row for token 4 (head 0) must now be at pos 0 of b.
        let (k4, _) = rows(&s, 4.0);
        assert_eq!(&pool.get(b).k_head(&s, 0)[0..4], &k4[0..4]);
    }

    #[test]
    fn byte_accounting() {
        let s = shape(); // 2 heads * 8 tokens * 4 dim = 64 elems per tensor
        assert_eq!(s.elems_per_tensor(), 64);
        assert_eq!(s.bytes_per_chunk_f32(), 512);
        assert_eq!(s.bytes_per_chunk_fp16(), 256);
        let mut pool = ChunkPool::new(s);
        let a = pool.acquire();
        assert_eq!(pool.in_use_bytes_fp16(), 256);
        pool.release(a);
        assert_eq!(pool.in_use_bytes_fp16(), 0);
        assert_eq!(pool.peak_bytes_fp16(), 256);
    }
}
