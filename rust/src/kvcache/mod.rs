//! KV-cache managers: the paper's prefix-aware chunked tree (PAKV, §3.1)
//! plus the two baseline layouts it is evaluated against (monolithic dense
//! tensors and vLLM-style paging).

pub mod chunk;
pub mod dtype;
pub mod monolithic;
pub mod paged;
pub mod retain;
pub mod tree;

pub use chunk::{Chunk, ChunkId, ChunkPool, KvShape};
pub use dtype::{quantize_i8, Bf16, F16, I8, KvDtype, KvElem, KvSlab};
pub use monolithic::MonolithicKvCache;
pub use paged::{PagedKvCache, PageId};
pub use retain::{PrefixRetainer, TieringConfig, PIN_ID_BASE};
pub use tree::{CtxEntry, InsertOutcome, PrefixTree, SeqId, SharingStats, TreeContext};
