//! Microkernel benchmark harness — builds the paper's §4.1 workload
//! ("sequences processed in batch mode ... each prefilled with n_p prompt
//! tokens, the leading n_s shared") against any of the six kernels, and
//! measures real decode steps on this host's memory hierarchy.
//!
//! Used by `benches/table3_microkernel.rs`, `fig3_completion_sweep.rs`,
//! `fig4_batch_sweep.rs` and the ablation bench.

use crate::attention::{
    flash_style_attention, naive_attention, paged_attention, tpp_attention, tpp_attention_2d,
    tpp_attention_buffered, tpp_attention_seq_only, xformers_style_attention, Queries,
    Tpp2dScratch, TppScratch,
};
use crate::kvcache::{KvDtype, KvShape, MonolithicKvCache, PagedKvCache, PrefixTree, SeqId};
use crate::perf_model::AttentionImpl;
use crate::util::rng::Pcg64;
use crate::util::threadpool::ThreadPool;

/// §4.1 workload parameters.
#[derive(Debug, Clone, Copy)]
pub struct MicroConfig {
    pub batch: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub chunk_size: usize,
    /// Prompt tokens per sequence (n_p).
    pub prompt_tokens: usize,
    /// Leading tokens shared across the batch (n_s ≤ n_p).
    pub shared_tokens: usize,
    /// Decode headroom reserved in the monolithic layout.
    pub max_new_tokens: usize,
    pub seed: u64,
    /// KV storage format for every cache layout under test.
    pub dtype: KvDtype,
}

impl MicroConfig {
    /// The paper's kernel defaults: h=32, d=128, c=64 (§4.1) at f32
    /// storage, scaled down in quick mode by the benches.
    pub fn paper(batch: usize, prompt: usize, shared: usize) -> Self {
        MicroConfig {
            batch,
            heads: 32,
            head_dim: 128,
            chunk_size: 64,
            prompt_tokens: prompt,
            shared_tokens: shared,
            max_new_tokens: 2048,
            seed: 42,
            dtype: KvDtype::F32,
        }
    }

    pub fn shape(&self) -> KvShape {
        KvShape::new(self.heads, self.head_dim, self.chunk_size).with_dtype(self.dtype)
    }

    /// Prompt tokens of sequence `i`: `shared` leading tokens common to the
    /// batch, the remainder unique per sequence.
    pub fn prompt_of(&self, i: usize) -> Vec<u32> {
        assert!(self.shared_tokens <= self.prompt_tokens);
        let mut p: Vec<u32> = (0..self.shared_tokens as u32).collect();
        p.extend(
            (0..(self.prompt_tokens - self.shared_tokens) as u32)
                .map(|j| 1_000_000 + i as u32 * 100_000 + j),
        );
        p
    }
}

/// Cheap deterministic KV fill (identical across cache layouts).
fn kv_fill(seed: u64) -> impl FnMut(usize, u32, &mut [f32], &mut [f32]) {
    move |pos, token, k: &mut [f32], v: &mut [f32]| {
        // One LCG stream per (pos, token); ~2 ops per element.
        let mut s = seed ^ (pos as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ (token as u64) << 1;
        let mut next = || {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 40) as f32 / (1u32 << 24) as f32) - 0.5
        };
        for x in k.iter_mut() {
            *x = next();
        }
        for x in v.iter_mut() {
            *x = next();
        }
    }
}

enum CacheState {
    Tree(Box<PrefixTree>),
    Mono(Box<MonolithicKvCache>),
    Paged(Box<PagedKvCache>),
}

/// Ablation switches for the ChunkAttn path: which TPP kernel variant
/// serves decode steps, and whether the tree context is cached lazily.
/// [`AblationConfig::default`] is the production configuration (2D
/// schedule + lazy context); the ablation bench flips one switch at a time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AblationConfig {
    pub kernel: TppVariant,
    pub lazy_context: bool,
}

impl Default for AblationConfig {
    fn default() -> Self {
        AblationConfig { kernel: TppVariant::Parallel2d, lazy_context: true }
    }
}

/// One kernel + its cache, ready to run decode steps.
pub struct KernelBench {
    pub kind: AttentionImpl,
    cfg: MicroConfig,
    ablation: AblationConfig,
    cache: CacheState,
    order: Vec<SeqId>,
    q: Vec<f32>,
    out: Vec<f32>,
    scratch: TppScratch,
    scratch2d: Tpp2dScratch,
    pool: ThreadPool,
    rng: Pcg64,
    decoded: usize,
    kv_row_scratch: (Vec<f32>, Vec<f32>),
}

impl KernelBench {
    /// Build the cache for `kind` with the production ablation defaults.
    pub fn new(cfg: MicroConfig, kind: AttentionImpl) -> Self {
        Self::with_ablation(cfg, kind, AblationConfig::default())
    }

    /// Build the cache for `kind` and prefill the §4.1 workload.
    pub fn with_ablation(cfg: MicroConfig, kind: AttentionImpl, ablation: AblationConfig) -> Self {
        let shape = cfg.shape();
        let mut fill = kv_fill(cfg.seed);
        let mut order = Vec::with_capacity(cfg.batch);
        let cache = match kind {
            AttentionImpl::ChunkAttn => {
                let mut tree = PrefixTree::new(shape);
                tree.lazy_context = ablation.lazy_context;
                for i in 0..cfg.batch {
                    tree.insert_sequence(SeqId(i as u64), &cfg.prompt_of(i), &mut fill);
                }
                let ctx = tree.context();
                order = ctx.seq_order.clone();
                CacheState::Tree(Box::new(tree))
            }
            AttentionImpl::Naive | AttentionImpl::Xformers | AttentionImpl::FlashAttn => {
                let mut mono = MonolithicKvCache::new(shape);
                for i in 0..cfg.batch {
                    let cap = cfg.prompt_tokens + cfg.max_new_tokens;
                    mono.insert_sequence(SeqId(i as u64), &cfg.prompt_of(i), cap, &mut fill);
                    order.push(SeqId(i as u64));
                }
                CacheState::Mono(Box::new(mono))
            }
            AttentionImpl::PagedAttn | AttentionImpl::PagedAttnShared => {
                let mut paged = PagedKvCache::new(shape, cfg.chunk_size);
                for i in 0..cfg.batch {
                    let sid = SeqId(i as u64);
                    let prompt = cfg.prompt_of(i);
                    if kind == AttentionImpl::PagedAttnShared && i > 0 && cfg.shared_tokens > 0 {
                        paged.insert_sequence_shared(
                            sid,
                            SeqId(0),
                            &prompt,
                            cfg.shared_tokens,
                            &mut fill,
                        );
                    } else {
                        paged.insert_sequence(sid, &prompt, &mut fill);
                    }
                    order.push(sid);
                }
                CacheState::Paged(Box::new(paged))
            }
        };
        let mut rng = Pcg64::new(cfg.seed, 1);
        let mut q = vec![0.0f32; cfg.heads * cfg.batch * cfg.head_dim];
        rng.fill_uniform_f32(&mut q, -1.0, 1.0);
        let out = vec![0.0f32; q.len()];
        let scratch = TppScratch::new(&shape, cfg.batch);
        let hd = cfg.heads * cfg.head_dim;
        KernelBench {
            kind,
            cfg,
            ablation,
            cache,
            order,
            q,
            out,
            scratch,
            scratch2d: Tpp2dScratch::new(),
            pool: ThreadPool::default_for_host(),
            rng,
            decoded: 0,
            kv_row_scratch: (vec![0.0; hd], vec![0.0; hd]),
        }
    }

    /// Run one decode-step attention over the current cache state.
    /// Returns the number of query tokens processed (= batch).
    pub fn decode_step(&mut self) -> u64 {
        if self.kind == AttentionImpl::ChunkAttn {
            return self.decode_step_variant(self.ablation.kernel);
        }
        let cfg = &self.cfg;
        let q = Queries::new(&self.q, cfg.heads, cfg.batch, cfg.head_dim);
        match (&mut self.cache, self.kind) {
            (CacheState::Mono(mono), AttentionImpl::Naive) => {
                naive_attention(mono, &self.order, &q, &mut self.out);
            }
            (CacheState::Mono(mono), AttentionImpl::Xformers) => {
                xformers_style_attention(mono, &self.order, &q, 32, &mut self.out);
            }
            (CacheState::Mono(mono), AttentionImpl::FlashAttn) => {
                flash_style_attention(mono, &self.order, &q, 16, &mut self.out);
            }
            (CacheState::Paged(paged), _) => {
                paged_attention(paged, &self.order, &q, &mut self.out);
            }
            _ => unreachable!("cache/kind mismatch"),
        }
        cfg.batch as u64
    }

    /// TPP kernel variants over the tree cache (panics on other caches).
    pub fn decode_step_variant(&mut self, variant: TppVariant) -> u64 {
        let cfg = &self.cfg;
        let q = Queries::new(&self.q, cfg.heads, cfg.batch, cfg.head_dim);
        let CacheState::Tree(tree) = &mut self.cache else {
            panic!("variant requires ChunkAttn cache")
        };
        let ctx = tree.context();
        match variant {
            TppVariant::Parallel2d => {
                tpp_attention_2d(tree, &ctx, &q, &self.pool, &mut self.scratch2d, &mut self.out)
            }
            TppVariant::Fused => {
                tpp_attention(tree, &ctx, &q, &self.pool, &mut self.scratch, &mut self.out)
            }
            TppVariant::Buffered => tpp_attention_buffered(tree, &ctx, &q, &mut self.out),
            TppVariant::SeqFirstOnly => {
                tpp_attention_seq_only(tree, &ctx, &q, &mut self.scratch, &mut self.out)
            }
        }
        cfg.batch as u64
    }

    /// Append one decoded token to every sequence (sequences diverge, as in
    /// Fig. 3's n_c sweep), and refresh the query values.
    pub fn append_round(&mut self) {
        let base = 2_000_000u32 + self.decoded as u32;
        let hd = self.cfg.heads * self.cfg.head_dim;
        let (ref mut k_row, ref mut v_row) = self.kv_row_scratch;
        let mut fill = kv_fill(self.cfg.seed ^ 0xDEC0DE);
        for i in 0..self.cfg.batch {
            let sid = SeqId(i as u64);
            let token = base + i as u32 * 10_000; // unique per sequence
            fill(self.cfg.prompt_tokens + self.decoded, token, k_row, v_row);
            match &mut self.cache {
                CacheState::Tree(tree) => tree.append_token(sid, token, k_row, v_row),
                CacheState::Mono(mono) => mono.append_token(sid, k_row, v_row),
                CacheState::Paged(paged) => paged.append_token(sid, k_row, v_row),
            }
        }
        self.decoded += 1;
        // New decode step, new query content.
        self.rng.fill_uniform_f32(&mut self.q, -1.0, 1.0);
        debug_assert_eq!(hd, k_row.len());
        // ChunkAttn: sequence order can change when the tree restructures.
        if let CacheState::Tree(tree) = &mut self.cache {
            self.order = tree.context().seq_order.clone();
        }
    }

    /// Tokens decoded since prefill.
    pub fn decoded(&self) -> usize {
        self.decoded
    }

    /// Worker count of the kernel's thread pool (for bench records).
    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    /// In-use KV bytes as actually allocated at the configured dtype —
    /// memory side of Table 3 configs (label with [`MicroConfig::dtype`]).
    pub fn kv_bytes(&self) -> u64 {
        match &self.cache {
            CacheState::Tree(t) => t.pool().in_use_bytes(),
            CacheState::Mono(m) => m.in_use_bytes(),
            CacheState::Paged(p) => p.in_use_bytes(),
        }
    }

    pub fn output(&self) -> &[f32] {
        &self.out
    }

    pub fn config(&self) -> &MicroConfig {
        &self.cfg
    }
}

/// TPP kernel variants for the ablation bench.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TppVariant {
    /// Production 2D (head × chunk-run) parallel schedule.
    Parallel2d,
    /// Head-partitioned fused kernel (§3.3 CPU form) — the 1D baseline.
    Fused,
    /// Algorithms 1+2 verbatim with partial buffers, single-threaded.
    Buffered,
    /// No chunk-first batching (PAKV without TPP).
    SeqFirstOnly,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MicroConfig {
        MicroConfig {
            batch: 6,
            heads: 2,
            head_dim: 16,
            chunk_size: 8,
            prompt_tokens: 40,
            shared_tokens: 24,
            max_new_tokens: 16,
            seed: 7,
            dtype: KvDtype::F32,
        }
    }

    #[test]
    fn all_kernels_produce_identical_outputs() {
        // Same logical KV in every layout → same attention output. The
        // ChunkAttn row order may differ (DFS order), so compare via maps.
        let mut results: Vec<(AttentionImpl, Vec<SeqId>, Vec<f32>)> = Vec::new();
        for kind in AttentionImpl::ALL {
            let mut kb = KernelBench::new(cfg(), kind);
            kb.decode_step();
            results.push((kind, kb.order.clone(), kb.output().to_vec()));
        }
        let c = cfg();
        let (_, ref_order, ref_out) = &results[0];
        for (kind, order, out) in &results[1..] {
            for (row, sid) in order.iter().enumerate() {
                let ref_row = ref_order.iter().position(|s| s == sid).unwrap();
                for h in 0..c.heads {
                    for i in 0..c.head_dim {
                        let a = out[(h * c.batch + row) * c.head_dim + i];
                        let b = ref_out[(h * c.batch + ref_row) * c.head_dim + i];
                        assert!(
                            (a - b).abs() < 3e-4,
                            "{kind:?} row {row} h {h} i {i}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn outputs_stay_identical_as_decode_proceeds() {
        let mut tpp = KernelBench::new(cfg(), AttentionImpl::ChunkAttn);
        let mut naive = KernelBench::new(cfg(), AttentionImpl::Naive);
        for _ in 0..12 {
            tpp.append_round();
            naive.append_round();
        }
        // Use identical queries.
        naive.q.copy_from_slice(&tpp.q);
        tpp.decode_step();
        naive.decode_step();
        let c = cfg();
        for (row, sid) in tpp.order.iter().enumerate() {
            let nrow = naive.order.iter().position(|s| s == sid).unwrap();
            for h in 0..c.heads {
                for i in 0..c.head_dim {
                    let a = tpp.output()[(h * c.batch + row) * c.head_dim + i];
                    let b = naive.output()[(h * c.batch + nrow) * c.head_dim + i];
                    assert!((a - b).abs() < 3e-4, "row {row} h {h} i {i}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn tpp_variants_agree() {
        let mut kb = KernelBench::new(cfg(), AttentionImpl::ChunkAttn);
        kb.decode_step_variant(TppVariant::Parallel2d);
        let two_d = kb.output().to_vec();
        kb.decode_step_variant(TppVariant::Fused);
        let fused = kb.output().to_vec();
        kb.decode_step_variant(TppVariant::Buffered);
        let buffered = kb.output().to_vec();
        kb.decode_step_variant(TppVariant::SeqFirstOnly);
        let seq_only = kb.output().to_vec();
        for i in 0..fused.len() {
            assert!((fused[i] - two_d[i]).abs() < 1e-4);
            assert!((fused[i] - buffered[i]).abs() < 1e-4);
            assert!((fused[i] - seq_only[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn production_default_is_the_2d_schedule() {
        let ab = AblationConfig::default();
        assert_eq!(ab.kernel, TppVariant::Parallel2d);
        assert!(ab.lazy_context);
        // decode_step routes ChunkAttn through the configured variant.
        let mut kb = KernelBench::new(cfg(), AttentionImpl::ChunkAttn);
        kb.decode_step();
        let default_out = kb.output().to_vec();
        kb.decode_step_variant(TppVariant::Parallel2d);
        assert_eq!(kb.output(), default_out.as_slice());
    }

    #[test]
    fn kv_bytes_reflect_sharing() {
        let tree = KernelBench::new(cfg(), AttentionImpl::ChunkAttn);
        let mono = KernelBench::new(cfg(), AttentionImpl::Naive);
        let paged = KernelBench::new(cfg(), AttentionImpl::PagedAttn);
        let paged_shared = KernelBench::new(cfg(), AttentionImpl::PagedAttnShared);
        assert!(tree.kv_bytes() < paged.kv_bytes());
        assert!(paged_shared.kv_bytes() < paged.kv_bytes());
        assert!(paged.kv_bytes() < mono.kv_bytes(), "mono counts headroom");
    }

    #[test]
    fn half_precision_storage_halves_bytes_and_preserves_outputs() {
        let mut f32_kb = KernelBench::new(cfg(), AttentionImpl::ChunkAttn);
        let mut cfg16 = cfg();
        cfg16.dtype = KvDtype::F16;
        let mut f16_kb = KernelBench::new(cfg16, AttentionImpl::ChunkAttn);
        assert_eq!(f16_kb.kv_bytes() * 2, f32_kb.kv_bytes());
        f32_kb.decode_step();
        f16_kb.decode_step();
        // Same prompts, same queries: outputs differ only by the storage
        // rounding of K/V (~2^-11 relative for f16).
        for (a, b) in f16_kb.output().iter().zip(f32_kb.output()) {
            assert!((a - b).abs() < 1e-2 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn shared_zero_builds_disjoint_tree() {
        let mut c = cfg();
        c.shared_tokens = 0;
        let mut kb = KernelBench::new(c, AttentionImpl::ChunkAttn);
        assert_eq!(kb.decode_step(), c.batch as u64);
        let CacheState::Tree(tree) = &mut kb.cache else { panic!() };
        assert!((tree.sharing_stats().sharing_ratio() - 0.0).abs() < 1e-12);
    }
}
