//! The real serving engine ("ChunkLlama" §4.2): continuous batching over a
//! prefix-tree KV cache, with the transformer forward pass delegated to a
//! [`ModelRunner`] — either the PJRT-compiled JAX model (L2/L1 artifacts,
//! see `runtime::PjrtModel`) or an in-process synthetic runner for tests.
//!
//! Per iteration the engine:
//! 1. admits queued requests (continuous batching) into the *prefill
//!    queue* — prefix-aware, so requests sharing the longest cached or
//!    in-progress prefix admit together;
//! 2. advances prefill: each in-progress prompt's unmatched suffix is
//!    split into chunk-aligned slices (prefix lookup first, §3.2, so
//!    matched tokens cost nothing), round-robin under a per-step token
//!    budget — one 4096-token cold prompt can no longer stall in-flight
//!    decoders for its whole prefill (head-of-line blocking);
//! 3. runs one batched decode step through the runner (which performs the
//!    TPP attention over the tree's chunks);
//! 4. appends each sequence's fresh K/V rows to the tree and retires
//!    completed sequences (their private chunks return to the pool).
//!
//! A partially prefilled prompt is a first-class tree resident: later
//! arrivals match against the slices already inserted, and a follower
//! whose prompt shares more with an in-progress leader than is resident
//! yet *defers* its own first slice, so the leader's prefill becomes the
//! follower's cache hit instead of duplicated compute.

use super::planner::{PlanInputs, PlannerConfig, SchedPolicyKind, StepPlan, StepPlanner};
use super::scheduler::{FinishedSeq, PrefillingSeq, Removed, Scheduler};
use crate::kvcache::tree::common_prefix;
use crate::kvcache::{
    KvDtype, KvShape, PrefixRetainer, PrefixTree, SeqId, TieringConfig, TreeContext, PIN_ID_BASE,
};
use crate::metrics::{MetricsRecorder, RequestRecord, StepTiming};
use crate::util::trace;
use crate::workload::Request;
use std::collections::BTreeMap;
use std::time::Instant;

/// Result of prefilling a (possibly partial) prompt suffix slice.
pub struct PrefillOutput {
    /// K rows for each suffix position: `[suffix_len][heads_total * head_dim]`.
    pub k_rows: Vec<Vec<f32>>,
    pub v_rows: Vec<Vec<f32>>,
    /// First generated token (greedy from the last-position logits).
    /// `Some` iff the slice was final (`is_final` was passed to
    /// [`ModelRunner::prefill`]): mid-prompt slices produce K/V only.
    pub next_token: Option<u32>,
}

/// Result of one batched decode step, rows in `ctx.seq_order`.
pub struct DecodeOutput {
    /// Next token per sequence.
    pub next_tokens: Vec<u32>,
    /// K/V rows of the *input* token per sequence (to append to the tree).
    pub k_rows: Vec<Vec<f32>>,
    pub v_rows: Vec<Vec<f32>>,
}

/// The model forward pass, abstracted so the engine is runner-agnostic.
pub trait ModelRunner {
    /// Total KV heads stored per token: `n_layers * heads` (layers are
    /// stacked along the head axis of the tree's chunks).
    fn heads_total(&self) -> usize;
    fn head_dim(&self) -> usize;

    /// Prefill `suffix_tokens` (prompt positions `pos_offset..`), given the
    /// dense KV of everything before the slice — matched prefix plus any
    /// earlier slices of the same prompt (`[heads_total, prefix_len,
    /// head_dim]`, with `prefix_len == pos_offset`). Chunked prefill calls
    /// this once per slice; `is_final` marks the slice containing the last
    /// prompt position, whose output must carry `next_token` (the first
    /// completion token). Mid-prompt slices may skip the logits work.
    fn prefill(
        &mut self,
        suffix_tokens: &[u32],
        pos_offset: usize,
        prefix_k: &[f32],
        prefix_v: &[f32],
        prefix_len: usize,
        is_final: bool,
    ) -> anyhow::Result<PrefillOutput>;

    /// One decode step: `last_tokens[i]`/`positions[i]` belong to
    /// `ctx.seq_order[i]`; attention context comes from the tree chunks.
    fn decode(
        &mut self,
        tree: &PrefixTree,
        ctx: &TreeContext,
        last_tokens: &[u32],
        positions: &[usize],
    ) -> anyhow::Result<DecodeOutput>;
}

#[derive(Debug, Clone)]
struct SeqState {
    last_token: u32,
    /// Tokens already in the tree for this sequence (== next position).
    position: usize,
    completion: Vec<u32>,
    /// Owning tenant, for the planner's per-tenant decode counters —
    /// cached here so the decode loop never rebuilds an id→tenant map.
    tenant: usize,
}

/// Engine statistics (cumulative).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    pub prefill_tokens_computed: u64,
    pub prefill_tokens_reused: u64,
    /// Prefill slices executed (== prompts prefilled when monolithic).
    pub prefill_chunks_total: u64,
    /// Requests whose first slice deferred (at least once) to an
    /// in-progress leader sharing a longer prefix — the deferred tokens
    /// become pure reuse. Counted once per request, not per polling pass.
    pub prefill_deferrals: u64,
    pub decode_steps: u64,
    pub decoded_tokens: u64,
    pub prefill_time_s: f64,
    pub decode_time_s: f64,
}

/// The continuous-batching serving engine over PAKV.
pub struct Engine<R: ModelRunner> {
    tree: PrefixTree,
    runner: R,
    sched: Scheduler,
    states: BTreeMap<u64, SeqState>,
    stats: EngineStats,
    started: Instant,
    /// Optional LRU retention of hot tenants' shared prefixes (see
    /// `kvcache::retain`): prefixes stay warm across idle periods.
    retainer: Option<PrefixRetainer>,
    metrics: MetricsRecorder,
    /// (admitted_at, first_token_at, reused_tokens) per live request.
    timing: BTreeMap<u64, (f64, f64, usize)>,
    /// Token-major (`[pos][heads_total * head_dim]`) dense K/V of each
    /// in-progress prompt's resident prefix. Filled from the tree once at
    /// the first slice, then extended with each slice's own output, so
    /// chunked prefill appends O(slice) per step instead of re-walking
    /// (and re-widening) the whole tree prefix every slice. Dropped at
    /// activation or cancellation.
    prefill_kv: BTreeMap<u64, (Vec<f32>, Vec<f32>)>,
    /// Incrementally invalidated decode context: valid while the tree's
    /// generation counter still equals `ctx_generation`. Lets steady-state
    /// decode steps (in-place tail appends only) skip `PrefixTree::context`
    /// entirely — no rebuild, no clone.
    ctx_cache: Option<TreeContext>,
    ctx_generation: u64,
    /// The policy-driven step planner: ranks admissions, rotates partial
    /// decode batches, grants eviction allowances — one [`StepPlan`] per
    /// engine iteration, all charged to the step token budget.
    planner: StepPlanner,
    /// Phase breakdown of the most recent [`Engine::step`], measured
    /// always-on with plain monotonic reads. The gateway stepper reads it
    /// per step for the `/debug/steps` ring buffer and Chrome-trace spans.
    last_step_timing: StepTiming,
}

impl<R: ModelRunner> Engine<R> {
    /// Engine with `f32` KV storage (see [`Engine::with_dtype`]).
    pub fn new(runner: R, chunk_size: usize, max_batch: usize) -> Self {
        Self::with_dtype(runner, chunk_size, max_batch, KvDtype::F32)
    }

    /// Engine whose prefix-tree KV cache stores K/V at `dtype` — `f16`
    /// halves resident KV bytes (2× more shared prefixes retainable under
    /// the same budget) and halves the bytes streamed per chunk in the
    /// bandwidth-bound chunk-first phase. The runner still produces and
    /// consumes f32 rows; narrowing happens at the tree's write seam.
    pub fn with_dtype(runner: R, chunk_size: usize, max_batch: usize, dtype: KvDtype) -> Self {
        let shape =
            KvShape::new(runner.heads_total(), runner.head_dim(), chunk_size).with_dtype(dtype);
        Engine {
            tree: PrefixTree::new(shape),
            runner,
            sched: Scheduler::new(max_batch),
            states: BTreeMap::new(),
            stats: EngineStats::default(),
            started: Instant::now(),
            retainer: None,
            metrics: MetricsRecorder::new(),
            timing: BTreeMap::new(),
            prefill_kv: BTreeMap::new(),
            ctx_cache: None,
            ctx_generation: 0,
            planner: StepPlanner::new(PlannerConfig::default()),
            last_step_timing: StepTiming::default(),
        }
    }

    /// Select the admission-scheduling policy (`--sched-policy`). The
    /// default, [`SchedPolicyKind::PrefixGreedy`], reproduces the
    /// pre-planner engine bit-for-bit. Resets planner state (deficits,
    /// wait clocks) — call before serving, not mid-flight.
    pub fn set_sched_policy(&mut self, kind: SchedPolicyKind) {
        let mut cfg = self.planner.config().clone();
        cfg.policy = kind;
        self.set_planner_config(cfg);
    }

    /// Replace the whole planner configuration (policy, DRR quantum and
    /// weights, aging boost, eviction allowance, tenant-metric cap).
    pub fn set_planner_config(&mut self, cfg: PlannerConfig) {
        self.planner = StepPlanner::new(cfg);
    }

    /// The step planner (policy kind, per-tenant counters, decode lag).
    pub fn planner(&self) -> &StepPlanner {
        &self.planner
    }

    /// The prefix retainer, when retention is enabled (eviction counters).
    pub fn retainer(&self) -> Option<&PrefixRetainer> {
        self.retainer.as_ref()
    }

    /// Aggregated serving metrics (exposition format via
    /// `metrics::render_exposition`).
    pub fn metrics(&self) -> &MetricsRecorder {
        &self.metrics
    }

    /// Mutable metrics access for external drivers that observe events the
    /// engine cannot (the gateway records inter-token gaps at the moment
    /// each token is handed to its stream).
    pub fn metrics_mut(&mut self) -> &mut MetricsRecorder {
        &mut self.metrics
    }

    /// Phase breakdown of the most recent [`Engine::step`].
    pub fn last_step_timing(&self) -> StepTiming {
        self.last_step_timing
    }

    /// Keep hot shared prefixes resident across idle periods, bounded by a
    /// chunk budget with LRU eviction.
    pub fn enable_prefix_retention(&mut self, budget_chunks: usize) {
        self.retainer = Some(PrefixRetainer::new(budget_chunks));
    }

    /// Tier cold retained prefixes: int8 re-narrow past `demote_after`
    /// LRU ticks, spill file past `spill_after` (see
    /// [`crate::kvcache::TieringConfig`]). Requires retention to be
    /// enabled first; a promoted prefix rejoins the tree *before* prefix
    /// matching at admission, so kernels only ever see hot chunks.
    pub fn set_retention_tiering(&mut self, cfg: TieringConfig) {
        if let Some(r) = &mut self.retainer {
            r.set_tiering(cfg);
        }
    }

    /// Enable chunked prefill: unmatched prompt suffixes advance in
    /// `chunk_tokens`-sized slices interleaved with decode steps, and each
    /// engine step spends at most `step_budget` tokens across prefill
    /// slices and decode tokens. Either knob at 0 disables it (the default
    /// is the monolithic whole-suffix prefill). `step_budget` should
    /// exceed `max_batch`, or a full decode batch leaves no prefill
    /// headroom.
    pub fn set_chunked_prefill(&mut self, chunk_tokens: usize, step_budget: usize) {
        self.sched.set_chunked_prefill(chunk_tokens, step_budget);
    }

    pub fn submit(&mut self, request: Request) {
        assert!(request.id < PIN_ID_BASE, "request ids must stay below the pin range");
        self.sched.submit(request);
    }

    /// Cap the admission queue (see [`Scheduler::set_queue_limit`]);
    /// `try_submit` rejects beyond it.
    pub fn set_queue_limit(&mut self, limit: Option<usize>) {
        self.sched.set_queue_limit(limit);
    }

    /// Bound per-request history retention (scheduler `finished` entries
    /// and metrics records) so a long-running server's memory does not
    /// grow with total request count. Lifetime counters are unaffected.
    pub fn set_history_limit(&mut self, limit: usize) {
        self.sched.set_finished_history_limit(Some(limit));
        self.metrics.set_record_limit(Some(limit));
    }

    /// Submit with admission control: returns `false` (and counts the
    /// rejection) when the queue is full. The gateway maps this to 429.
    pub fn try_submit(&mut self, request: Request) -> bool {
        assert!(request.id < PIN_ID_BASE, "request ids must stay below the pin range");
        self.sched.try_submit(request)
    }

    /// Cancel a request mid-flight: removes it from the queue or the
    /// decode batch, frees its private chunks back to the tree pool, and
    /// drops its per-sequence state. Safe between [`Engine::step`] calls;
    /// returns `false` if the id is unknown (already finished/cancelled).
    pub fn cancel(&mut self, id: u64) -> bool {
        // Drop the planner's wait-clock / decode-lag state eagerly (it
        // would also age out lazily on the next plan).
        self.planner.forget(id);
        match self.sched.remove(id) {
            None => false,
            Some(Removed::Queued(_)) => {
                self.metrics.cancelled += 1;
                true
            }
            Some(Removed::Prefilling(pf)) => {
                // Mid-prefill: tree residency exists once the first slice
                // landed; release it (shared chunks stay with survivors).
                if pf.filled > 0 {
                    self.tree.remove_sequence(SeqId(id));
                }
                self.prefill_kv.remove(&id);
                self.metrics.cancelled += 1;
                true
            }
            Some(Removed::Active(_)) => {
                // Active sequences always hold a tree path (inserted at
                // prefill); removing it releases every chunk no other live
                // sequence references and invalidates cached contexts via
                // the generation bump.
                if self.tree.sequence_len(SeqId(id)).is_some() {
                    self.tree.remove_sequence(SeqId(id));
                }
                self.states.remove(&id);
                self.timing.remove(&id);
                self.metrics.cancelled += 1;
                true
            }
        }
    }

    /// Drop the retained completion state of a finished (or cancelled)
    /// request, returning the tokens generated so far. Long-running
    /// drivers (the HTTP gateway) call this after delivering the final
    /// token so `states` does not grow with total request count.
    pub fn release(&mut self, id: u64) -> Option<Vec<u32>> {
        self.states.remove(&id).map(|s| s.completion)
    }

    /// Ids of every in-flight request: queued, prefilling, or active.
    /// The supervisor's conservative quarantine set when a failure cannot
    /// be attributed to one sequence.
    pub fn inflight_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.sched.queue().iter().map(|r| r.id).collect();
        ids.extend(self.sched.prefilling().iter().map(|p| p.request.id));
        ids.extend(self.sched.active().iter().map(|s| s.request.id));
        ids
    }

    /// Repair bookkeeping after a panic unwound out of [`Engine::step`],
    /// leaving a partially-applied step behind. Returns `(orphans,
    /// finished)`:
    ///
    /// - `orphans` — request ids whose scheduler entry was lost mid-step
    ///   (a panic inside the prefill phase unwinds past the
    ///   `put_back_prefilling` restore seam, dropping the detached prefill
    ///   queue while partial tree residency stays behind). Their residency
    ///   and caches are purged here; the caller must fail their streams.
    /// - `finished` — sequences whose tokens appended before the panic met
    ///   their budget; retired normally so the caller streams them out.
    ///
    /// The caller should run [`PrefixTree::check_invariants`] afterwards
    /// and escalate to [`Engine::hard_reset`] if structural damage remains.
    pub fn recover_after_panic(&mut self) -> (Vec<u64>, Vec<FinishedSeq>) {
        // The cached context may describe half-applied tree topology; drop
        // it so the next decode rebuilds from the tree itself.
        self.ctx_cache = None;
        let mut orphans = Vec::new();
        for sid in self.tree.sequence_ids() {
            let id = sid.0;
            if id >= PIN_ID_BASE {
                continue; // retention pins are engine-owned, never orphans
            }
            let known = self.sched.is_prefilling(id)
                || self.sched.active().iter().any(|s| s.request.id == id)
                || self.sched.queue().iter().any(|r| r.id == id);
            if !known {
                self.tree.remove_sequence(sid);
                self.prefill_kv.remove(&id);
                self.states.remove(&id);
                self.timing.remove(&id);
                self.planner.forget(id);
                orphans.push(id);
            }
        }
        // Tokens appended before the panic were never credited (the credit
        // step runs after the full append loop); reconcile the scheduler's
        // generated counts against the per-sequence completion state, then
        // retire anything that reached its budget.
        let mut credits = Vec::new();
        for s in self.sched.active() {
            let have =
                self.states.get(&s.request.id).map(|st| st.completion.len()).unwrap_or(0);
            if have > s.generated {
                credits.push((s.request.id, have - s.generated));
            }
        }
        for (id, n) in credits {
            self.sched.credit_tokens(id, n);
        }
        let finished = self.sched.retire_finished(self.now());
        for f in &finished {
            if self.tree.sequence_len(SeqId(f.request.id)).is_some() {
                self.tree.remove_sequence(SeqId(f.request.id));
            }
            self.record_finished(f);
        }
        (orphans, finished)
    }

    /// Last-resort recovery: drop every sequence, retention pin, prefix
    /// cache, and queue entry and rebuild the tree from its shape. The
    /// engine object itself (configuration, counters, finished history)
    /// survives, so the gateway keeps serving new requests on a clean
    /// slate. Returns the dropped in-flight request ids.
    pub fn hard_reset(&mut self) -> Vec<u64> {
        let dropped = self.sched.clear_inflight();
        for id in &dropped {
            self.planner.forget(*id);
        }
        let shape = self.tree.shape();
        self.tree = PrefixTree::new(shape);
        self.states.clear();
        self.timing.clear();
        self.prefill_kv.clear();
        self.ctx_cache = None;
        self.ctx_generation = 0;
        if let Some(r) = &self.retainer {
            let mut fresh = PrefixRetainer::new(r.budget_chunks());
            fresh.set_tiering(r.tiering().clone());
            self.retainer = Some(fresh);
        }
        dropped
    }

    pub fn is_idle(&self) -> bool {
        self.sched.is_idle()
    }

    /// Whether an idle engine still has amortized maintenance to do
    /// (pinned prefixes over the retention budget, or pins cold enough to
    /// demote/spill). Idle drivers (the gateway stepper) keep calling
    /// [`Engine::step`] while this holds so the eviction credit keeps
    /// accruing — and cold prefixes keep tiering down — between requests.
    pub fn needs_maintenance(&self) -> bool {
        self.retainer
            .as_ref()
            .map(|r| r.over_budget(&self.tree) || r.tiering_pending())
            .unwrap_or(false)
    }

    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    pub fn tree(&self) -> &PrefixTree {
        &self.tree
    }

    pub fn scheduler(&self) -> &Scheduler {
        &self.sched
    }

    fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Seconds since the engine started — the clock request timing uses.
    /// External drivers stamp `Request::arrival_s` with this so queueing
    /// delay and TTFT metrics are measured on one consistent clock.
    pub fn clock(&self) -> f64 {
        self.now()
    }

    /// Run one engine iteration (plan + admission + prefills + one decode
    /// step + amortized eviction). Returns sequences that finished this
    /// iteration.
    ///
    /// The iteration executes one [`StepPlan`]: the planner's policy
    /// ranks admissions, the budget splits across decode (a partial batch
    /// when tight), prefill slices, and an eviction allowance, and the
    /// engine applies each part in order. External drivers (the HTTP
    /// gateway's stepper thread) pump this in their own loop, interleaving
    /// [`Engine::try_submit`] / [`Engine::cancel`] between iterations;
    /// `run_to_completion` below is the offline-trace driver over the
    /// same primitive.
    pub fn step(&mut self) -> anyhow::Result<Vec<FinishedSeq>> {
        // Chaos site: whole-step latency (`sleep`), failure (`err`), or
        // stepper panic (`panic`). Strictly a no-op unless armed.
        if let Some(msg) = crate::util::failpoint::fire("engine.step") {
            return Err(anyhow::anyhow!(msg));
        }
        // Phase timing is always on (a handful of monotonic reads per
        // step): the per-phase histograms on /metrics must populate with
        // tracing disarmed. `trace` only gates the span *event* capture.
        let step_t0 = Instant::now();
        let mut timing = StepTiming::default();
        let slices_before = self.stats.prefill_chunks_total;

        let t = Instant::now();
        let plan = self.plan_step();
        timing.plan_s = t.elapsed().as_secs_f64();
        timing.admitted = plan.admit_ids.len();
        if trace::armed() {
            for id in &plan.admit_ids {
                trace::instant("admitted", "request", *id, vec![]);
            }
        }

        let t = Instant::now();
        let mut finished_early = self.admit_and_prefill(&plan)?;
        timing.prefill_s = t.elapsed().as_secs_f64();
        timing.prefill_slices = (self.stats.prefill_chunks_total - slices_before) as usize;

        if self.sched.batch_size() > 0 {
            finished_early.extend(self.decode_once(&plan, &mut timing)?);
        }
        // Spend the eviction allowance even on decode-less steps, so pins
        // created by a prefill-only iteration still amortize out. With no
        // step budget the grant is unbounded — the historical burst.
        let t = Instant::now();
        if let Some(retainer) = &mut self.retainer {
            // Tiering runs before budget eviction: a demotion frees the
            // same chunks an eviction would, but keeps the prefix
            // promotable. The active-prompt snapshot guards any pin a
            // live sequence's tree context still depends on; it is built
            // only when a pin is actually cold (tiering_pending), so the
            // common hot step pays one O(pins) scan at most.
            if retainer.tiering_pending() {
                let mut active: Vec<Vec<u32>> = self
                    .sched
                    .prefilling()
                    .iter()
                    .map(|p| p.request.prompt.clone())
                    .collect();
                active.extend(self.sched.active().iter().map(|a| a.request.prompt.clone()));
                retainer.run_tiering(&mut self.tree, &active);
            }
            let grant = if self.sched.step_token_budget().is_none() {
                usize::MAX
            } else {
                plan.evict_tokens
            };
            retainer.enforce_budget_amortized(&mut self.tree, grant);
        }
        timing.evict_s = t.elapsed().as_secs_f64();
        timing.finished = finished_early.len();
        timing.total_s = step_t0.elapsed().as_secs_f64();
        self.metrics.record_step_timing(&timing);
        self.last_step_timing = timing;
        Ok(finished_early)
    }

    /// Ask the planner for this iteration's [`StepPlan`] from a snapshot
    /// of the queue, the prefill queue, the decode batch, and the
    /// retainer's budget state.
    fn plan_step(&mut self) -> StepPlan {
        let tree = &self.tree;
        let cached = |req: &Request| tree.match_prefix(&req.prompt);
        let retainer_over_budget =
            self.retainer.as_ref().map(|r| r.over_budget(tree)).unwrap_or(false);
        self.planner.plan(&PlanInputs {
            queue: self.sched.queue(),
            prefilling: self.sched.prefilling(),
            active: self.sched.active(),
            free_slots: self.sched.free_slots(),
            step_budget: self.sched.step_token_budget(),
            retainer_over_budget,
            cached_match: &cached,
        })
    }

    /// Admission + prefill phase. The plan's policy-ranked requests join
    /// the prefill queue; the engine then advances in-progress prompts in
    /// chunk-aligned slices, round-robin, under the plan's prefill token
    /// budget (decode and eviction shares were carved out by the planner,
    /// and a completing prompt reserves one more token for its first
    /// decode, so a step never exceeds the budget). With chunking
    /// disabled this degenerates to the old behavior: every admitted
    /// prompt prefills fully in its admission step. Returns requests
    /// whose one-token budget finished at prefill.
    fn admit_and_prefill(&mut self, plan: &StepPlan) -> anyhow::Result<Vec<FinishedSeq>> {
        let now = self.now();
        self.sched.admit_prefilling_ids(&plan.admit_ids, now);
        let budget = plan.prefill_budget;
        let chunk_tokens = self.sched.prefill_chunk_tokens();
        let mut pending: Vec<PrefillingSeq> = self.sched.take_prefilling().into();
        // The queue is detached while slices run; restore it before
        // propagating any runner error, or admitted requests (and their
        // partial tree residency) would be orphaned unreachable by
        // cancellation.
        let result = self.advance_prefill(&mut pending, budget, chunk_tokens);
        self.sched.put_back_prefilling(pending.into());
        result?;
        // Requests whose budget is a single token finish at prefill.
        let mut finished_early = Vec::new();
        for f in self.sched.retire_finished(self.now()) {
            self.tree.remove_sequence(SeqId(f.request.id));
            self.record_finished(&f);
            finished_early.push(f);
        }
        Ok(finished_early)
    }

    /// Advance the detached prefill queue under `budget` tokens, promoting
    /// completed prompts into the decode batch. Entries are consumed from
    /// `pending` only on activation, so the caller can restore whatever
    /// remains even when a slice errors.
    fn advance_prefill(
        &mut self,
        pending: &mut Vec<PrefillingSeq>,
        mut budget: usize,
        chunk_tokens: usize,
    ) -> anyhow::Result<()> {
        // Round-robin one slice per prompt per pass: a short prompt behind
        // a 4096-token one prefills on its first pass instead of
        // inheriting the head-of-line stall inside the prefill queue.
        let mut progressed = true;
        while budget > 0 && progressed {
            progressed = false;
            let mut i = 0usize;
            while i < pending.len() && budget > 0 {
                let (leaders, rest) = pending.split_at_mut(i);
                let pf = &mut rest[0];
                let prompt_len = pf.request.prompt.len();
                let first_slice = pf.filled == 0;
                let (start, matched) = if first_slice {
                    // Promote any demoted/spilled pinned prefix of this
                    // prompt back into the tree *before* the lookup: the
                    // dequantized rows must be resident for match_prefix
                    // to see them, and the kernel must never be handed a
                    // quantized-at-rest copy.
                    if let Some(retainer) = &mut self.retainer {
                        retainer.promote_for_prompt(&mut self.tree, &pf.request.prompt);
                    }
                    // First slice: prefix lookup against everything
                    // resident right now — including slices leaders have
                    // produced earlier in this very step. Never match the
                    // entire prompt: the model still needs the last
                    // position's logits to start decoding.
                    let m = self.tree.match_prefix(&pf.request.prompt).min(prompt_len - 1);
                    // Defer while an earlier in-progress prompt will push
                    // the matchable prefix further: the leader's prefill
                    // becomes this request's cache hit instead of
                    // duplicated compute.
                    let will_extend = leaders
                        .iter()
                        .any(|l| common_prefix(&l.request.prompt, &pf.request.prompt) > m);
                    if will_extend {
                        // Count requests that deferred, not polling
                        // iterations: the same waiting follower re-enters
                        // this branch every pass until its leader lands.
                        if !pf.deferred {
                            pf.deferred = true;
                            self.stats.prefill_deferrals += 1;
                            trace::instant("deferred", "request", pf.request.id, vec![]);
                        }
                        i += 1;
                        continue;
                    }
                    (m, m)
                } else {
                    (pf.filled, pf.reused)
                };
                let remaining = prompt_len - start;
                let mut take = remaining.min(chunk_tokens).min(budget);
                if start + take == prompt_len && budget < take + 1 {
                    // The final slice promotes the sequence into this
                    // step's decode batch; reserve one budget token for
                    // that decode so the whole step stays within budget.
                    take -= 1;
                }
                if take == 0 {
                    i += 1;
                    continue;
                }
                let is_final = start + take == prompt_len;
                let t0 = Instant::now();
                let id = pf.request.id;
                if first_slice {
                    // Dense rows of the matched prefix, read (and widened)
                    // from the tree exactly once; later slices of this
                    // prompt extend the cache with their own output below
                    // instead of re-walking the tree.
                    let rows = self.gather_prefix_rows(&pf.request.prompt, start);
                    self.prefill_kv.insert(id, rows);
                }
                let (pk, pv) = {
                    let shape = self.tree.shape();
                    let (ck, cv) =
                        self.prefill_kv.get(&id).expect("prefix cache created at first slice");
                    debug_assert_eq!(ck.len(), start * shape.heads * shape.head_dim);
                    (
                        head_major(ck, start, shape.heads, shape.head_dim),
                        head_major(cv, start, shape.heads, shape.head_dim),
                    )
                };
                let slice = &pf.request.prompt[start..start + take];
                // Chaos site: injected runner prefill-slice failure. The
                // `[seq:<id>]` tag (also stitched onto real runner errors
                // below) lets the supervisor quarantine only this request
                // once retries are exhausted.
                if crate::util::failpoint::armed() {
                    if let Some(msg) =
                        crate::util::failpoint::fire_tagged("engine.prefill", &format!("seq:{id}"))
                    {
                        return Err(anyhow::anyhow!(msg));
                    }
                }
                let out = self
                    .runner
                    .prefill(slice, start, &pk, &pv, start, is_final)
                    .map_err(|e| anyhow::anyhow!("prefill slice failed [seq:{id}]: {e}"))?;
                anyhow::ensure!(
                    out.k_rows.len() == take,
                    "prefill returned {} rows for {take} suffix tokens",
                    out.k_rows.len()
                );
                if first_slice {
                    // `matched` is clamped to len-1, but the tree may hold
                    // the entire prompt (an identical prompt admitted
                    // earlier): insert matches maximally and calls back
                    // only for truly-unmatched positions, so any extra
                    // computed row is simply dropped.
                    self.tree.insert_sequence(
                        SeqId(id),
                        &pf.request.prompt[..start + take],
                        &mut |pos, _tok, k, v| {
                            debug_assert!(pos >= matched);
                            k.copy_from_slice(&out.k_rows[pos - start]);
                            v.copy_from_slice(&out.v_rows[pos - start]);
                        },
                    );
                    pf.reused = matched;
                } else {
                    self.tree.extend_sequence(SeqId(id), slice, &mut |pos, _tok, k, v| {
                        k.copy_from_slice(&out.k_rows[pos - start]);
                        v.copy_from_slice(&out.v_rows[pos - start]);
                    });
                }
                pf.filled = start + take;
                budget -= take;
                progressed = true;
                self.stats.prefill_chunks_total += 1;
                self.stats.prefill_tokens_computed += take as u64;
                self.stats.prefill_time_s += t0.elapsed().as_secs_f64();
                if trace::armed() {
                    let end_us = trace::now_us();
                    let dur_us = t0.elapsed().as_micros() as u64;
                    trace::span(
                        &format!("prefill_slice[{start}..{}]", start + take),
                        "request",
                        id,
                        end_us.saturating_sub(dur_us),
                        dur_us,
                        vec![("tokens", take.to_string()), ("reused", matched.to_string())],
                    );
                }
                if is_final {
                    // Prompt fully resident: the prefix cache is done.
                    self.prefill_kv.remove(&id);
                    // The reserved decode token for the fresh sequence.
                    budget = budget.saturating_sub(1);
                    let next = out.next_token.ok_or_else(|| {
                        anyhow::anyhow!("final prefill slice must produce the first token")
                    })?;
                    self.states.insert(
                        id,
                        SeqState {
                            last_token: next,
                            position: prompt_len,
                            completion: vec![next],
                            tenant: pf.request.tenant,
                        },
                    );
                    if let Some(retainer) = &mut self.retainer {
                        let shared = pf.request.shared_tokens.min(prompt_len);
                        retainer.touch(&pf.request.prompt);
                        if shared > 0 {
                            let prefix = pf.request.prompt[..shared].to_vec();
                            retainer.pin(&mut self.tree, &prefix);
                        }
                    }
                    self.stats.prefill_tokens_reused += pf.reused as u64;
                    self.timing.insert(id, (pf.admitted_at, self.now(), pf.reused));
                    trace::instant("first_token", "request", id, vec![]);
                    let done = pending.remove(i);
                    self.sched.activate(done);
                    // The prefill step emitted the first completion token.
                    self.sched.credit_tokens(id, 1);
                    // `i` now indexes the next entry — don't advance.
                } else {
                    // Extend the prefix cache with this slice's rows so
                    // the next slice starts from memory, not the tree.
                    let cache = self.prefill_kv.get_mut(&id).expect("cache created above");
                    for r in &out.k_rows {
                        cache.0.extend_from_slice(r);
                    }
                    for r in &out.v_rows {
                        cache.1.extend_from_slice(r);
                    }
                    i += 1;
                }
            }
        }
        Ok(())
    }

    /// Decode phase: one batched decode step over the plan's share of the
    /// active sequences, appending fresh K/V rows and retiring completed
    /// sequences. Sequences in `plan.decode_skip` sit this step out (the
    /// budget was too tight for the full batch): their rows are computed
    /// and discarded like pin phantoms, their state does not advance, and
    /// the planner's lag rotation guarantees they decode within
    /// `ceil(batch / decode_take)` steps.
    fn decode_once(
        &mut self,
        plan: &StepPlan,
        timing: &mut StepTiming,
    ) -> anyhow::Result<Vec<FinishedSeq>> {
        // One batched decode step. Pin sequences (prefix retention) are
        // phantom rows: they get dummy queries and their outputs are
        // discarded — they exist only to keep shared chunks referenced.
        let t0 = Instant::now();
        // Incremental context caching: topology only changes on admission,
        // retirement, or chunk-boundary crossings, so on every other step
        // the cached context is reused without touching the tree.
        let generation = self.tree.generation();
        if self.ctx_cache.is_none() || self.ctx_generation != generation {
            // `context_fresh` bypasses the tree's own lazy cache: this is
            // the only context cache on the serving path, so the context is
            // not retained twice.
            self.ctx_cache = Some(self.tree.context_fresh());
            self.ctx_generation = generation;
            self.metrics.context_rebuilds += 1;
        } else {
            self.metrics.context_cache_hits += 1;
        }
        let ctx = self.ctx_cache.as_ref().expect("context populated above");
        let (mut last_tokens, mut positions) = (Vec::new(), Vec::new());
        for sid in &ctx.seq_order {
            match self.states.get(&sid.0) {
                Some(st) => {
                    last_tokens.push(st.last_token);
                    positions.push(st.position);
                }
                None => {
                    // Pins and partially prefilled prompts are phantom
                    // rows: resident in the tree (so their chunks stay
                    // shared/referenced and later arrivals can match
                    // them) but not decoding yet.
                    debug_assert!(
                        sid.0 >= PIN_ID_BASE || self.sched.is_prefilling(sid.0),
                        "unknown non-pin sequence {sid:?}"
                    );
                    last_tokens.push(0);
                    positions.push(0);
                }
            }
        }
        // Chaos site: whole-batch decode failure (no single sequence is
        // implicated, so the supervisor quarantines conservatively).
        if let Some(msg) = crate::util::failpoint::fire("engine.decode") {
            return Err(anyhow::anyhow!(msg));
        }
        // Clear any kernel-phase residue a previously failed step left on
        // this thread, then drain what *this* decode's kernel reports.
        let _ = trace::take_kernel_phases();
        let t_dec = Instant::now();
        let out = self.runner.decode(&self.tree, ctx, &last_tokens, &positions)?;
        let decode_call_s = t_dec.elapsed().as_secs_f64();
        let (chunk_first_us, seq_first_us) = trace::take_kernel_phases();
        timing.chunk_first_s = chunk_first_us as f64 / 1e6;
        timing.seq_first_s = seq_first_us as f64 / 1e6;
        let t_append = Instant::now();
        let mut decoded = 0usize;
        for (i, sid) in ctx.seq_order.iter().enumerate() {
            if plan.decode_skip.contains(&sid.0) {
                continue; // lagged this step; rows discarded like a phantom
            }
            let Some(st) = self.states.get_mut(&sid.0) else { continue };
            // Chaos site: per-sequence panic mid-decode, after earlier rows
            // of this very batch already appended — the partial-step
            // scenario `recover_after_panic` repairs. Tagged so only this
            // sequence is quarantined.
            if crate::util::failpoint::armed() {
                if let Some(msg) = crate::util::failpoint::fire_tagged(
                    "engine.decode.append",
                    &format!("seq:{}", sid.0),
                ) {
                    panic!("{msg}");
                }
            }
            self.tree.append_token(*sid, last_tokens[i], &out.k_rows[i], &out.v_rows[i]);
            st.position += 1;
            st.last_token = out.next_tokens[i];
            st.completion.push(out.next_tokens[i]);
            let tenant = st.tenant;
            decoded += 1;
            self.planner.note_decode_token(tenant);
        }
        self.stats.decode_steps += 1;
        self.stats.decoded_tokens += decoded as u64;
        self.stats.decode_time_s += t0.elapsed().as_secs_f64();
        self.metrics.record_decode_step(t0.elapsed().as_secs_f64() * 1e6, decoded);
        // `append` is the decode time not inside the kernel's two phases:
        // the runner-call remainder (query build, sampling bookkeeping)
        // plus the tree append loop above.
        timing.append_s = (decode_call_s - timing.chunk_first_s - timing.seq_first_s).max(0.0)
            + t_append.elapsed().as_secs_f64();
        timing.decode_batch = decoded;

        // Retire completed sequences (skipped ones generated nothing).
        let finished = self.sched.step_decode_skipping(&plan.decode_skip, self.now());
        for f in &finished {
            self.tree.remove_sequence(SeqId(f.request.id));
            self.record_finished(f);
        }
        Ok(finished)
    }

    fn record_finished(&mut self, f: &FinishedSeq) {
        self.planner.forget(f.request.id);
        let (admitted, first_token, reused) =
            self.timing.remove(&f.request.id).unwrap_or((f.admitted_at, f.admitted_at, 0));
        self.metrics.record_request(RequestRecord {
            arrival_s: f.request.arrival_s,
            admitted_s: admitted,
            first_token_s: first_token,
            finished_s: f.finished_at,
            prompt_tokens: f.request.prompt.len(),
            completion_tokens: f.generated,
            reused_prompt_tokens: reused,
        });
    }

    /// Completion tokens generated so far for a (possibly finished) request.
    pub fn completion_of(&self, id: u64) -> Option<&[u32]> {
        self.states.get(&id).map(|s| s.completion.as_slice())
    }

    /// Run until all submitted requests finish; returns them. Keeps
    /// stepping an idle engine while amortized eviction work remains
    /// ([`Engine::needs_maintenance`]), so offline drivers end under the
    /// retention budget just like the pre-planner inline eviction did —
    /// each such step grants at least one eviction token, so the loop
    /// terminates once the pins drain.
    pub fn run_to_completion(&mut self) -> anyhow::Result<Vec<FinishedSeq>> {
        let mut all = Vec::new();
        while !self.sched.is_idle() {
            all.extend(self.step()?);
        }
        while self.needs_maintenance() {
            self.step()?;
        }
        Ok(all)
    }

    /// Dense token-major (`[pos][heads_total * head_dim]`) K/V rows of a
    /// resident prefix, widened from the storage dtype to the f32 the
    /// runner consumes. Token-major so chunked prefill can append each
    /// slice's fresh rows in O(slice); [`head_major`] re-lays it out into
    /// the runner contract per call.
    fn gather_prefix_rows(&self, tokens: &[u32], matched: usize) -> (Vec<f32>, Vec<f32>) {
        let shape = self.tree.shape();
        let d = shape.head_dim;
        let row = shape.heads * d;
        let mut k = vec![0.0f32; matched * row];
        let mut v = vec![0.0f32; matched * row];
        if matched == 0 {
            return (k, v);
        }
        // Walk matching chunks from the roots.
        let probe = &tokens[..matched];
        let mut pos = 0usize;
        while pos < matched {
            let (usable, chunk) =
                self.tree.find_chunk_at(probe, pos).expect("matched prefix must be present");
            let take = usable.min(matched - pos);
            for h in 0..shape.heads {
                for p in 0..take {
                    let src = (h * shape.chunk_size + p) * d;
                    let dst = (pos + p) * row + h * d;
                    chunk.k_slab().read_f32(src, &mut k[dst..dst + d]);
                    chunk.v_slab().read_f32(src, &mut v[dst..dst + d]);
                }
            }
            pos += take;
        }
        (k, v)
    }
}

/// Re-layout token-major rows (`[len][heads * d]`) into the dense
/// `[heads, len, d]` buffer [`ModelRunner::prefill`] takes as its prefix.
fn head_major(rows: &[f32], len: usize, heads: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; heads * len * d];
    for p in 0..len {
        for h in 0..heads {
            let src = (p * heads + h) * d;
            let dst = (h * len + p) * d;
            out[dst..dst + d].copy_from_slice(&rows[src..src + d]);
        }
    }
    out
}

pub mod testing {
    use super::*;

    /// Deterministic in-process model: KV rows and next tokens are hashes
    /// of (token, position). Exercises the engine's tree/scheduler logic
    /// without artifacts; the PJRT runner is tested in `rust/tests/`.
    pub struct SyntheticRunner {
        pub heads_total: usize,
        pub head_dim: usize,
        pub vocab: u32,
    }

    impl SyntheticRunner {
        pub fn kv_row(&self, token: u32, pos: usize, which: u8) -> Vec<f32> {
            let n = self.heads_total * self.head_dim;
            let mut s = (token as u64) << 20 | (pos as u64) << 2 | which as u64;
            (0..n)
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                    ((s >> 40) as f32 / (1u32 << 24) as f32) - 0.5
                })
                .collect()
        }

        fn next_token(&self, last: u32, pos: usize) -> u32 {
            (last.wrapping_mul(2654435761).wrapping_add(pos as u32)) % self.vocab
        }
    }

    impl ModelRunner for SyntheticRunner {
        fn heads_total(&self) -> usize {
            self.heads_total
        }
        fn head_dim(&self) -> usize {
            self.head_dim
        }

        fn prefill(
            &mut self,
            suffix_tokens: &[u32],
            pos_offset: usize,
            _pk: &[f32],
            _pv: &[f32],
            _prefix_len: usize,
            is_final: bool,
        ) -> anyhow::Result<PrefillOutput> {
            let k_rows = suffix_tokens
                .iter()
                .enumerate()
                .map(|(i, &t)| self.kv_row(t, pos_offset + i, 0))
                .collect();
            let v_rows = suffix_tokens
                .iter()
                .enumerate()
                .map(|(i, &t)| self.kv_row(t, pos_offset + i, 1))
                .collect();
            let next_token = is_final.then(|| {
                let last = *suffix_tokens.last().expect("prefill slices are non-empty");
                self.next_token(last, pos_offset + suffix_tokens.len())
            });
            Ok(PrefillOutput { k_rows, v_rows, next_token })
        }

        fn decode(
            &mut self,
            _tree: &PrefixTree,
            ctx: &TreeContext,
            last_tokens: &[u32],
            positions: &[usize],
        ) -> anyhow::Result<DecodeOutput> {
            let b = ctx.seq_order.len();
            let mut out = DecodeOutput {
                next_tokens: Vec::with_capacity(b),
                k_rows: Vec::with_capacity(b),
                v_rows: Vec::with_capacity(b),
            };
            for i in 0..b {
                out.k_rows.push(self.kv_row(last_tokens[i], positions[i], 0));
                out.v_rows.push(self.kv_row(last_tokens[i], positions[i], 1));
                out.next_tokens.push(self.next_token(last_tokens[i], positions[i] + 1));
            }
            Ok(out)
        }
    }

    /// [`SyntheticRunner`] plus the production attention path: every
    /// decode step also runs the TPP kernel
    /// ([`crate::attention::tpp_attention_2d`]) over the live tree with
    /// deterministic queries. Tokens and K/V rows are the same hashes as
    /// the plain synthetic runner (completions are identical), but gateway
    /// runs through this runner execute — and therefore time — both
    /// kernel phases exactly as a real serving path would, populating the
    /// `step_phase_seconds{phase="chunk_first"/"seq_first"}` histograms
    /// and the Chrome-trace kernel spans. Used by the HTTP gateway, the
    /// bench-http load generator, and the observability e2e suite.
    pub struct KernelRunner {
        inner: SyntheticRunner,
        pool: crate::util::threadpool::ThreadPool,
        scratch: crate::attention::Tpp2dScratch,
        q: Vec<f32>,
        out: Vec<f32>,
    }

    impl KernelRunner {
        pub fn new(heads_total: usize, head_dim: usize, vocab: u32) -> Self {
            KernelRunner {
                inner: SyntheticRunner { heads_total, head_dim, vocab },
                pool: crate::util::threadpool::ThreadPool::default_for_host(),
                scratch: crate::attention::Tpp2dScratch::new(),
                q: Vec::new(),
                out: Vec::new(),
            }
        }
    }

    impl ModelRunner for KernelRunner {
        fn heads_total(&self) -> usize {
            self.inner.heads_total
        }

        fn head_dim(&self) -> usize {
            self.inner.head_dim
        }

        fn prefill(
            &mut self,
            suffix_tokens: &[u32],
            pos_offset: usize,
            prefix_k: &[f32],
            prefix_v: &[f32],
            prefix_len: usize,
            is_final: bool,
        ) -> anyhow::Result<PrefillOutput> {
            self.inner.prefill(suffix_tokens, pos_offset, prefix_k, prefix_v, prefix_len, is_final)
        }

        fn decode(
            &mut self,
            tree: &PrefixTree,
            ctx: &TreeContext,
            last_tokens: &[u32],
            positions: &[usize],
        ) -> anyhow::Result<DecodeOutput> {
            let b = ctx.seq_order.len();
            let shape = tree.shape();
            let n = shape.heads * b * shape.head_dim;
            self.q.clear();
            self.q.resize(n, 0.0);
            // Deterministic per-row queries (same hash family as kv_row).
            for r in 0..b {
                let mut s = (last_tokens[r] as u64) << 24 | (positions[r] as u64) << 3 | 0b101;
                for h in 0..shape.heads {
                    let base = (h * b + r) * shape.head_dim;
                    for x in &mut self.q[base..base + shape.head_dim] {
                        s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                        *x = ((s >> 40) as f32 / (1u32 << 24) as f32) - 0.5;
                    }
                }
            }
            self.out.clear();
            self.out.resize(n, 0.0);
            let q = crate::attention::Queries::new(&self.q, shape.heads, b, shape.head_dim);
            crate::attention::tpp_attention_2d(
                tree,
                ctx,
                &q,
                &self.pool,
                &mut self.scratch,
                &mut self.out,
            );
            self.inner.decode(tree, ctx, last_tokens, positions)
        }
    }

    /// Wraps a runner with a per-token prefill delay, emulating the
    /// prefill FLOPs of a real model so head-of-line effects are
    /// observable in wall time (the decode side is paced by the gateway's
    /// `decode_interval`). Used by the mixed-workload bench and the
    /// interleaving e2e tests.
    pub struct PacedRunner<R> {
        pub inner: R,
        pub prefill_us_per_token: u64,
    }

    impl<R: ModelRunner> ModelRunner for PacedRunner<R> {
        fn heads_total(&self) -> usize {
            self.inner.heads_total()
        }

        fn head_dim(&self) -> usize {
            self.inner.head_dim()
        }

        fn prefill(
            &mut self,
            suffix_tokens: &[u32],
            pos_offset: usize,
            prefix_k: &[f32],
            prefix_v: &[f32],
            prefix_len: usize,
            is_final: bool,
        ) -> anyhow::Result<PrefillOutput> {
            if self.prefill_us_per_token > 0 {
                std::thread::sleep(std::time::Duration::from_micros(
                    self.prefill_us_per_token * suffix_tokens.len() as u64,
                ));
            }
            self.inner.prefill(suffix_tokens, pos_offset, prefix_k, prefix_v, prefix_len, is_final)
        }

        fn decode(
            &mut self,
            tree: &PrefixTree,
            ctx: &TreeContext,
            last_tokens: &[u32],
            positions: &[usize],
        ) -> anyhow::Result<DecodeOutput> {
            self.inner.decode(tree, ctx, last_tokens, positions)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testing::SyntheticRunner;
    use super::*;

    fn request(id: u64, prompt: Vec<u32>, completion: usize) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            tenant: 0,
            prompt,
            shared_tokens: 0,
            max_new_tokens: completion,
        }
    }

    fn engine() -> Engine<SyntheticRunner> {
        Engine::new(SyntheticRunner { heads_total: 4, head_dim: 8, vocab: 101 }, 4, 4)
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine();
        e.submit(request(0, (0..10).collect(), 5));
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(e.completion_of(0).unwrap().len(), 5);
        assert_eq!(e.tree().num_sequences(), 0, "tree cleaned up");
        assert_eq!(e.tree().pool().in_use(), 0);
    }

    #[test]
    fn prefix_lookup_skips_recompute() {
        let mut e = engine();
        let sys: Vec<u32> = (0..16).collect();
        let mut p1 = sys.clone();
        p1.extend([100, 101]);
        let mut p2 = sys.clone();
        p2.extend([200, 201]);
        e.submit(request(0, p1, 3));
        e.submit(request(1, p2, 3));
        e.run_to_completion().unwrap();
        let stats = e.stats();
        assert_eq!(stats.prefill_tokens_reused, 16, "second request reuses the system prompt");
        assert_eq!(stats.prefill_tokens_computed, 18 + 2);
    }

    #[test]
    fn identical_prompts_reuse_all_but_last() {
        let mut e = engine();
        let p: Vec<u32> = (0..12).collect();
        e.submit(request(0, p.clone(), 2));
        e.submit(request(1, p, 2));
        e.run_to_completion().unwrap();
        // Second prefill recomputes only the final position (needed for
        // logits).
        assert_eq!(e.stats().prefill_tokens_reused, 11);
    }

    #[test]
    fn deterministic_completions_independent_of_batching() {
        // The same request must decode the same tokens whether it runs
        // alone or batched with others (synthetic runner is per-sequence
        // deterministic).
        let mut solo = engine();
        solo.submit(request(0, vec![5, 6, 7, 8], 6));
        solo.run_to_completion().unwrap();
        let expect = solo.completion_of(0).unwrap().to_vec();

        let mut batched = engine();
        batched.submit(request(0, vec![5, 6, 7, 8], 6));
        batched.submit(request(1, vec![5, 6, 9, 9], 6));
        batched.submit(request(2, vec![1, 2, 3, 4, 5], 6));
        batched.run_to_completion().unwrap();
        assert_eq!(batched.completion_of(0).unwrap(), expect.as_slice());
    }

    #[test]
    fn continuous_batching_admits_when_slot_frees() {
        let mut e = Engine::new(SyntheticRunner { heads_total: 2, head_dim: 4, vocab: 11 }, 4, 2);
        e.submit(request(0, vec![1, 2, 3], 2));
        e.submit(request(1, vec![1, 2, 4], 8));
        e.submit(request(2, vec![9, 9, 9], 2));
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 3);
        assert_eq!(e.scheduler().peak_batch(), 2);
    }

    #[test]
    fn prefix_retention_survives_idle_periods() {
        let mut e = engine();
        e.enable_prefix_retention(1000);
        let sys: Vec<u32> = (0..16).collect();
        let mut p1 = sys.clone();
        p1.extend([100, 101]);
        e.submit(Request { shared_tokens: 16, ..request(0, p1, 2) });
        e.run_to_completion().unwrap();
        // All sequences gone, but the pinned system prompt stayed warm.
        assert!(e.tree().pool().in_use() > 0, "prefix retained");
        let mut p2 = sys.clone();
        p2.extend([200, 201]);
        e.submit(Request { shared_tokens: 16, ..request(1, p2, 2) });
        e.run_to_completion().unwrap();
        assert_eq!(
            e.stats().prefill_tokens_reused,
            16,
            "second request hits the retained prefix across the idle gap"
        );
    }

    #[test]
    fn retention_budget_bounds_memory() {
        let mut e = engine();
        e.enable_prefix_retention(4); // 4 chunks of 4 tokens
        for tenant in 0..5u64 {
            let sys: Vec<u32> = (0..16).map(|i| tenant as u32 * 1000 + i).collect();
            e.submit(Request { shared_tokens: 16, ..request(tenant, sys, 1) });
            e.run_to_completion().unwrap();
        }
        assert!(e.tree().pool().in_use() <= 5, "LRU eviction keeps the pool bounded");
        e.tree().check_invariants().unwrap();
    }

    #[test]
    fn promoted_prefix_restores_the_cache_hit_at_admission() {
        let mut e = engine();
        e.enable_prefix_retention(1000);
        e.set_retention_tiering(TieringConfig {
            demote_after: 1,
            spill_after: 0,
            spill_dir: None,
        });
        let sys: Vec<u32> = (0..16).collect();
        let mut p1 = sys.clone();
        p1.extend([100, 101]);
        e.submit(Request { shared_tokens: 16, ..request(0, p1, 2) });
        e.run_to_completion().unwrap();
        // Unrelated traffic ages the pin; the maintenance pass demotes it.
        e.submit(request(1, vec![500, 501, 502], 1));
        e.run_to_completion().unwrap();
        assert_eq!(e.retainer().unwrap().demotions_total(), 1);
        assert_eq!(e.tree().pool().in_use(), 0, "demoted prefix left the tree");
        let reused_before = e.stats().prefill_tokens_reused;
        // A prompt carrying the prefix promotes it back before matching.
        let mut p2 = sys.clone();
        p2.extend([200, 201]);
        e.submit(Request { shared_tokens: 16, ..request(2, p2, 2) });
        e.run_to_completion().unwrap();
        assert_eq!(e.retainer().unwrap().promotions_total(), 1);
        assert_eq!(
            e.stats().prefill_tokens_reused - reused_before,
            16,
            "promoted prefix is a full cache hit at admission"
        );
        e.tree().check_invariants().unwrap();
    }

    #[test]
    fn decode_racing_tiering_never_demotes_an_inflight_prefix() {
        // The same workload with and without tiering: the in-flight guard
        // must keep the decoder's pinned prefix hot for its whole
        // lifetime, so the completions are identical and the demotion
        // only lands once the sequence has retired.
        let run = |tiered: bool| -> Vec<u32> {
            let mut e = engine();
            e.enable_prefix_retention(1000);
            if tiered {
                e.set_retention_tiering(TieringConfig {
                    demote_after: 1,
                    spill_after: 0,
                    spill_dir: None,
                });
            }
            let sys: Vec<u32> = (0..16).collect();
            let mut p0 = sys.clone();
            p0.push(100);
            e.submit(Request { shared_tokens: 16, ..request(0, p0, 1) });
            e.run_to_completion().unwrap();
            // A long decoder over the pinned prefix...
            let mut pa = sys.clone();
            pa.push(200);
            e.submit(Request { shared_tokens: 16, ..request(1, pa, 24) });
            // ...racing one-shot prompts whose admissions tick the
            // retainer clock past the demote threshold every step.
            let mut next_id = 2u64;
            for _ in 0..400 {
                if e.completion_of(1).map(|c| c.len() >= 24).unwrap_or(false) {
                    break;
                }
                e.submit(request(next_id, vec![900 + next_id as u32, 901, 902], 1));
                next_id += 1;
                e.step().unwrap();
                if tiered && e.scheduler().active().iter().any(|a| a.request.id == 1) {
                    assert_eq!(
                        e.retainer().unwrap().demotions_total(),
                        0,
                        "a prefix under a live decode must not demote mid-step"
                    );
                }
            }
            assert_eq!(e.completion_of(1).unwrap().len(), 24, "decoder finished");
            e.run_to_completion().unwrap();
            if tiered {
                assert!(
                    e.retainer().unwrap().demotions_total() >= 1,
                    "once the decoder retires, the cold pin demotes"
                );
            }
            e.tree().check_invariants().unwrap();
            e.completion_of(1).unwrap().to_vec()
        };
        assert_eq!(run(true), run(false), "tiering never perturbs an in-flight decode");
    }

    #[test]
    fn cancel_mid_decode_releases_private_chunks() {
        let mut e = engine();
        let sys: Vec<u32> = (0..16).collect();
        let mut p1 = sys.clone();
        p1.push(100);
        let mut p2 = sys.clone();
        p2.push(200);
        e.submit(request(0, p1, 64));
        e.submit(request(1, p2, 64));
        e.step().unwrap(); // both admitted and decoding
        let before = e.tree().pool().in_use();
        assert!(e.cancel(0), "active sequence cancels");
        assert!(!e.cancel(0), "double cancel is a no-op");
        assert!(e.tree().pool().in_use() < before, "private chunks released");
        e.tree().check_invariants().unwrap();
        // The surviving sequence still decodes to completion.
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].request.id, 1);
        assert_eq!(e.metrics().cancelled, 1);
        assert_eq!(e.tree().pool().in_use(), 0, "everything returned to the pool");
    }

    #[test]
    fn cancel_queued_request_never_prefills() {
        let mut e = Engine::new(SyntheticRunner { heads_total: 2, head_dim: 4, vocab: 11 }, 4, 1);
        e.submit(request(0, vec![1, 2, 3], 8));
        e.submit(request(1, vec![4, 5, 6], 8));
        e.step().unwrap(); // 0 active (batch=1), 1 still queued
        assert!(e.cancel(1));
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(e.stats().prefill_tokens_computed, 3, "request 1 never prefilled");
        assert_eq!(e.metrics().cancelled, 1);
    }

    #[test]
    fn try_submit_respects_queue_limit() {
        let mut e = Engine::new(SyntheticRunner { heads_total: 2, head_dim: 4, vocab: 11 }, 4, 1);
        e.set_queue_limit(Some(2));
        assert!(e.try_submit(request(0, vec![1, 2], 2)));
        assert!(e.try_submit(request(1, vec![1, 3], 2)));
        assert!(!e.try_submit(request(2, vec![1, 4], 2)), "queue at capacity");
        assert_eq!(e.scheduler().admission_rejections(), 1);
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 2, "accepted requests still complete");
    }

    #[test]
    fn release_drops_completion_state() {
        let mut e = engine();
        e.submit(request(0, (0..8).collect(), 3));
        e.run_to_completion().unwrap();
        let tokens = e.release(0).expect("finished request retains completion until released");
        assert_eq!(tokens.len(), 3);
        assert!(e.release(0).is_none());
        assert!(e.completion_of(0).is_none());
    }

    #[test]
    fn f16_storage_serves_identically_and_halves_kv_bytes() {
        let run = |dtype: KvDtype| {
            let mut e = Engine::with_dtype(
                SyntheticRunner { heads_total: 4, head_dim: 8, vocab: 101 },
                4,
                4,
                dtype,
            );
            let sys: Vec<u32> = (0..16).collect();
            for i in 0..3u64 {
                let mut p = sys.clone();
                p.extend([100 + i as u32, 200 + i as u32]);
                e.submit(request(i, p, 4));
            }
            e.run_to_completion().unwrap();
            let completions: Vec<Vec<u32>> =
                (0..3).map(|i| e.completion_of(i).unwrap().to_vec()).collect();
            (completions, e.tree().pool().peak_bytes(), e.tree().pool().peak_in_use())
        };
        let (c32, bytes32, chunks32) = run(KvDtype::F32);
        let (c16, bytes16, chunks16) = run(KvDtype::F16);
        // The synthetic runner's sampling is KV-independent, so decoded
        // tokens (and therefore tree shapes) match exactly.
        assert_eq!(c32, c16);
        assert_eq!(chunks32, chunks16, "dtype must not change tree topology");
        assert_eq!(bytes16 * 2, bytes32, "f16 stores exactly half the bytes");
    }

    #[test]
    fn metrics_recorder_tracks_requests_and_steps() {
        let mut e = engine();
        let sys: Vec<u32> = (0..12).collect();
        let mut p2 = sys.clone();
        p2.push(99);
        e.submit(request(0, sys, 3));
        e.submit(request(1, p2, 3));
        e.run_to_completion().unwrap();
        let m = e.metrics();
        assert_eq!(m.requests().len(), 2);
        assert!(m.decode_tokens >= 4);
        assert!(m.prefix_hit_rate() > 0.3, "second prompt reused the first's prefix");
        let text = crate::metrics::render_exposition(m, "t");
        assert!(text.contains("t_requests_total 2"));
    }

    #[test]
    fn identical_prompts_in_one_batch_hit_the_full_prompt_clamp() {
        // Two identical prompts admitted in the same engine step: the
        // follower's prefix lookup happens after the leader's prefill has
        // inserted the full prompt, so the tree internally matches all 12
        // tokens while the engine clamps to 11 (the model still needs the
        // last position's logits). The extra computed row is dropped, the
        // tree's refcounts stay consistent, and both decode identically.
        let run = |chunk_tokens: usize, budget: usize| {
            let mut e = engine();
            if chunk_tokens > 0 {
                e.set_chunked_prefill(chunk_tokens, budget);
            }
            let p: Vec<u32> = (0..12).collect();
            e.submit(request(0, p.clone(), 3));
            e.submit(request(1, p, 3));
            let done = e.run_to_completion().unwrap();
            assert_eq!(done.len(), 2);
            e.tree().check_invariants().unwrap();
            assert_eq!(e.tree().pool().in_use(), 0, "everything returned to the pool");
            let stats = e.stats();
            assert_eq!(
                stats.prefill_tokens_reused, 11,
                "follower reuses all but the last position"
            );
            assert_eq!(
                stats.prefill_tokens_computed,
                12 + 1,
                "leader computes 12, follower recomputes only the logits position"
            );
            let c0 = e.completion_of(0).unwrap().to_vec();
            let c1 = e.completion_of(1).unwrap().to_vec();
            assert_eq!(c0, c1, "identical prompts decode identically");
            c0
        };
        let mono = run(0, 0);
        let chunked = run(4, 16);
        assert_eq!(mono, chunked, "chunked prefill must not change completions");
    }

    #[test]
    fn chunked_prefill_interleaves_and_respects_the_step_budget() {
        let mut e = Engine::new(SyntheticRunner { heads_total: 2, head_dim: 4, vocab: 101 }, 8, 4);
        e.set_chunked_prefill(8, 24);
        // Two active decoders with long completion budgets.
        e.submit(request(0, vec![1, 2, 3], 64));
        e.submit(request(1, vec![4, 5, 6], 64));
        e.step().unwrap();
        assert_eq!(e.scheduler().batch_size(), 2);
        // A 200-token cold prompt joins; per step it may prefill at most
        // 24 - 2 (decode) tokens, in 8-token slices.
        e.submit(request(2, (1000..1200).collect(), 2));
        let mut prev = e.stats();
        let mut prefill_steps = 0;
        let mut decode_alongside = 0;
        let mut all_finished = Vec::new();
        for _ in 0..64 {
            all_finished.extend(e.step().unwrap());
            let s = e.stats();
            let spent = (s.prefill_tokens_computed - prev.prefill_tokens_computed)
                + (s.decoded_tokens - prev.decoded_tokens);
            assert!(spent <= 24, "engine step spent {spent} tokens, budget is 24");
            if s.prefill_chunks_total > prev.prefill_chunks_total {
                prefill_steps += 1;
                if s.decode_steps > prev.decode_steps {
                    decode_alongside += 1;
                }
            }
            prev = s;
            if e.scheduler().prefill_depth() == 0 {
                break;
            }
        }
        assert!(prefill_steps >= 2, "200-token prefill must span multiple engine steps");
        assert!(decode_alongside >= 2, "decode must keep running between prefill slices");
        assert_eq!(e.scheduler().prefill_depth(), 0, "cold prompt finished prefilling");
        e.tree().check_invariants().unwrap();
        all_finished.extend(e.run_to_completion().unwrap());
        assert_eq!(all_finished.len(), 3);
    }

    #[test]
    fn sibling_defers_to_inflight_leader_and_reuses_its_prefill() {
        let mut e = engine(); // chunk_size 4, max_batch 4
        e.set_chunked_prefill(4, 8);
        let sys: Vec<u32> = (0..64).collect();
        let mut p1 = sys.clone();
        p1.extend([100, 101]);
        let mut p2 = sys.clone();
        p2.extend([200, 201]);
        e.submit(request(0, p1, 2));
        e.submit(request(1, p2, 2));
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        let stats = e.stats();
        // The follower deferred its first slice while the leader was
        // mid-prefill, so the whole shared prefix became pure reuse.
        assert!(stats.prefill_deferrals > 0, "follower must defer to the in-flight leader");
        assert_eq!(stats.prefill_tokens_reused, 64, "entire shared prefix reused");
        assert_eq!(stats.prefill_tokens_computed, 66 + 2, "only the two private suffixes computed");
        e.tree().check_invariants().unwrap();
    }

    #[test]
    fn chunked_prefill_matches_monolithic_end_state() {
        // Same workload, chunked vs monolithic: identical completions,
        // identical reuse accounting, empty tree at the end.
        let run = |chunked: bool| {
            let mut e = engine();
            if chunked {
                e.set_chunked_prefill(4, 12);
            }
            let sys: Vec<u32> = (0..32).collect();
            for i in 0..3u64 {
                let mut p = sys.clone();
                p.extend([100 + i as u32, 200 + i as u32]);
                e.submit(request(i, p, 5));
            }
            e.run_to_completion().unwrap();
            let completions: Vec<Vec<u32>> =
                (0..3).map(|i| e.completion_of(i).unwrap().to_vec()).collect();
            e.tree().check_invariants().unwrap();
            assert_eq!(e.tree().pool().in_use(), 0);
            (completions, e.stats().prefill_tokens_reused)
        };
        let (mono, mono_reused) = run(false);
        let (chunked, chunked_reused) = run(true);
        assert_eq!(mono, chunked);
        assert!(
            chunked_reused >= mono_reused,
            "deferral can only increase reuse: {chunked_reused} vs {mono_reused}"
        );
    }

    #[test]
    fn degenerate_one_token_budget_still_makes_progress() {
        // Regression: a step budget of 1 can never fit a final slice plus
        // its reserved decode token; the scheduler clamps it to 2 so the
        // engine cannot spin forever on the last prompt position.
        let mut e = engine();
        e.set_chunked_prefill(1, 1);
        e.submit(request(0, vec![1, 2, 3, 4, 5], 2));
        let mut steps = 0;
        while !e.is_idle() {
            e.step().unwrap();
            steps += 1;
            assert!(steps < 1000, "engine livelocked under a degenerate token budget");
        }
        assert_eq!(e.completion_of(0).unwrap().len(), 2);
    }

    #[test]
    fn cancel_mid_prefill_releases_partial_residency() {
        let mut e = engine();
        e.set_chunked_prefill(4, 8);
        e.submit(request(0, (0..64).collect(), 4));
        e.step().unwrap(); // first slices land; prompt far from complete
        assert_eq!(e.scheduler().prefill_depth(), 1);
        assert!(e.tree().pool().in_use() > 0, "partial resident holds chunks");
        assert!(e.cancel(0), "mid-prefill cancel succeeds");
        assert_eq!(e.tree().pool().in_use(), 0, "partial chunks released");
        assert_eq!(e.metrics().cancelled, 1);
        assert!(e.is_idle());
        e.tree().check_invariants().unwrap();
    }

    fn trequest(id: u64, tenant: usize, prompt: Vec<u32>, completion: usize) -> Request {
        Request { tenant, ..request(id, prompt, completion) }
    }

    /// Shared harness for the starvation tests: a hot tenant floods the
    /// queue with prefix-sharing requests (3 new arrivals per step, more
    /// than the 2-slot batch can drain) while one cold-tenant request
    /// waits. Returns the step at which the cold request left the queue,
    /// or None if it was still queued after `horizon` steps.
    fn cold_tenant_admission_step(
        policy: SchedPolicyKind,
        aging_boost: usize,
        horizon: usize,
    ) -> Option<usize> {
        let mut e = Engine::new(SyntheticRunner { heads_total: 2, head_dim: 4, vocab: 101 }, 8, 2);
        e.enable_prefix_retention(1000);
        e.set_chunked_prefill(8, 24);
        e.set_planner_config(PlannerConfig {
            policy,
            aging_boost_tokens: aging_boost,
            ..PlannerConfig::default()
        });
        let shared: Vec<u32> = (0..32).collect();
        // Warm + pin the hot tenant's prefix so every storm request scores
        // a 32-token match from the very first plan.
        let mut warm = shared.clone();
        warm.push(1999);
        e.submit(Request { shared_tokens: 32, ..trequest(999_999, 0, warm, 1) });
        e.run_to_completion().unwrap();
        let cold_id = 1_000_000u64;
        e.submit(trequest(cold_id, 9, (5000..5024).collect(), 1));
        let mut next_hot = 0u64;
        for step in 1..=horizon {
            for _ in 0..3 {
                let mut p = shared.clone();
                p.push(2000 + next_hot as u32);
                e.submit(trequest(next_hot, 0, p, 1));
                next_hot += 1;
            }
            e.step().unwrap();
            if !e.scheduler().queue().iter().any(|r| r.id == cold_id) {
                return Some(step);
            }
        }
        None
    }

    #[test]
    fn prefix_greedy_starves_a_cold_tenant_under_a_sharing_storm() {
        // The motivating failure: greedy longest-shared-prefix admission
        // never picks the cold tenant while sharers are queued — and the
        // storm outpaces the batch, so one always is.
        assert_eq!(
            cold_tenant_admission_step(SchedPolicyKind::PrefixGreedy, 32, 60),
            None,
            "prefix-greedy should starve the cold tenant for the whole horizon"
        );
    }

    #[test]
    fn aging_admits_the_cold_tenant_within_its_bound() {
        // Boost 4 tokens/step vs a 32-token shared prefix: only sharers
        // arriving within ceil(32/4) + 1 = 9 steps of the cold request can
        // outrank it forever; the storm ahead of that threshold is 3 * 9 =
        // 27 requests, drained at ~2 per step. A 60-step bound is several
        // times that drain time.
        let admitted = cold_tenant_admission_step(SchedPolicyKind::Aging, 4, 60)
            .expect("aging must admit the cold tenant");
        assert!(admitted <= 45, "cold tenant admitted only at step {admitted}");
    }

    #[test]
    fn drr_admits_the_cold_tenant_within_one_round_robin_turn() {
        // Quantum 256 covers any prompt here outright, so the cold
        // tenant's first deficit credit admits it the first time the
        // round-robin reaches tenant 9 with a free slot.
        let admitted = cold_tenant_admission_step(SchedPolicyKind::Drr, 32, 60)
            .expect("drr must admit the cold tenant");
        assert!(admitted <= 6, "cold tenant admitted only at step {admitted}");
    }

    #[test]
    fn prefix_greedy_reproduces_the_historical_admission_order() {
        // Mirror of the scheduler's prefix_aware_admission_groups_sharers
        // scenario, realized through the planner-driven engine step:
        // longest cached match admits first, sibling sharers group with
        // the in-flight leader, the cold request waits. The planner's
        // prefix-greedy ranking is additionally pbt-checked bit-for-bit
        // against a literal copy of the pre-planner loop in
        // coordinator::planner::tests.
        let mut e = engine(); // chunk 4, max_batch 4
        // Warm the tree: a resident 8-token prefix for tenant A.
        e.submit(request(0, (0..8).collect(), 1));
        e.run_to_completion().unwrap();
        e.enable_prefix_retention(1000);
        let mut warm = (0..8).collect::<Vec<u32>>();
        warm.push(99);
        e.submit(Request { shared_tokens: 8, ..request(1, warm, 1) });
        e.run_to_completion().unwrap();
        // Queue: cold (FCFS first), then a sharer of the retained prefix,
        // then a sharer of that sharer.
        let cold: Vec<u32> = (500..540).collect();
        let mut sharer_b: Vec<u32> = (0..8).collect();
        sharer_b.extend([200, 201, 202, 203]);
        let mut sharer_c = sharer_b.clone();
        sharer_c.push(204);
        // Completion 4: still mid-decode after the admission step, so the
        // realized batch order is observable below.
        e.submit(request(10, cold, 4));
        e.submit(request(11, sharer_b, 4));
        e.submit(request(12, sharer_c, 4));
        // One step admits all three (3 free slots); the *order* is what
        // the policy decides. Completion order of equal-length decodes
        // preserves admission order, but assert directly on the planner's
        // realized admission: sharers before the cold request.
        let tree = &e.tree;
        let cached = |r: &Request| tree.match_prefix(&r.prompt);
        let mut sched_clone_order = Vec::new();
        {
            let items: Vec<crate::coordinator::planner::QueueItem<'_>> = e
                .sched
                .queue()
                .iter()
                .map(|r| crate::coordinator::planner::QueueItem {
                    id: r.id,
                    tenant: r.tenant,
                    prompt: &r.prompt,
                    cached: cached(r),
                    waited_steps: 0,
                })
                .collect();
            sched_clone_order
                .extend(crate::coordinator::planner::rank_prefix_greedy(&items, &[], 3));
        }
        assert_eq!(sched_clone_order, vec![11, 12, 10], "sharers group ahead of the cold request");
        e.step().unwrap();
        let admitted: Vec<u64> = e
            .sched
            .prefilling()
            .iter()
            .map(|p| p.request.id)
            .chain(e.sched.active().iter().map(|a| a.request.id))
            .collect();
        // All three fit the batch; the engine's realized order must match
        // the ranking (activated entries keep their admission order).
        let mut realized: Vec<u64> = admitted;
        realized.retain(|id| [10, 11, 12].contains(id));
        assert_eq!(realized, vec![11, 12, 10], "realized admission order follows the ranking");
        e.run_to_completion().unwrap();
    }

    #[test]
    fn partial_decode_batches_respect_a_tight_budget_and_bound_lag() {
        // Budget 3 under a 4-sequence batch: each step decodes only 3
        // sequences, rotating so no sequence lags more than one step, and
        // completions still match an unconstrained run bit-for-bit.
        let run = |tight: bool| {
            let mut e =
                Engine::new(SyntheticRunner { heads_total: 2, head_dim: 4, vocab: 101 }, 8, 4);
            for i in 0..4u64 {
                e.submit(request(i, vec![10 + i as u32, 20, 30], 6));
            }
            // Admit + prefill everything unconstrained first.
            e.step().unwrap();
            assert_eq!(e.scheduler().batch_size(), 4);
            if tight {
                e.set_chunked_prefill(4, 3);
            }
            let mut prev = e.stats();
            let mut steps = 0;
            while !e.is_idle() {
                e.step().unwrap();
                steps += 1;
                let s = e.stats();
                let spent = (s.prefill_tokens_computed - prev.prefill_tokens_computed)
                    + (s.decoded_tokens - prev.decoded_tokens);
                if tight {
                    assert!(spent <= 3, "step spent {spent} tokens under a budget of 3");
                }
                prev = s;
                assert!(steps < 200, "partial decode must not livelock");
            }
            let completions: Vec<Vec<u32>> =
                (0..4).map(|i| e.completion_of(i).unwrap().to_vec()).collect();
            (completions, e.planner().max_decode_lag())
        };
        let (full, _) = run(false);
        let (tight, lag) = run(true);
        assert_eq!(full, tight, "lagged decode must not change any completion");
        assert!(lag >= 1, "a 4-batch under budget 3 must actually lag someone");
        assert!(lag <= 1, "rotation bound ceil(4/3)-1 = 1 exceeded: lag {lag}");
    }

    #[test]
    fn pin_eviction_is_amortized_under_the_step_budget() {
        // A 16-token pin over a 2-chunk budget, with only 2 eviction
        // tokens granted per step: the pin must fall, but only after
        // several steps of bounded work — and every step's total spend
        // (prefill + decode + eviction grants) stays within the budget.
        let mut e = engine(); // chunk 4
        e.enable_prefix_retention(2);
        e.set_chunked_prefill(4, 12);
        e.set_planner_config(PlannerConfig {
            evict_step_tokens: 2,
            ..PlannerConfig::default()
        });
        let sys: Vec<u32> = (0..16).collect();
        let mut p = sys.clone();
        p.extend([100, 101]);
        e.submit(Request { shared_tokens: 16, ..request(0, p, 3) });
        let mut prev_evict = 0u64;
        let mut prev = e.stats();
        let mut over_budget_steps = 0;
        while !e.is_idle() {
            e.step().unwrap();
            let s = e.stats();
            let evict = e.retainer().unwrap().eviction_tokens_total();
            let spent = (s.prefill_tokens_computed - prev.prefill_tokens_computed)
                + (s.decoded_tokens - prev.decoded_tokens)
                + (evict - prev_evict);
            assert!(spent <= 12, "step spent {spent} tokens, budget is 12");
            assert!(evict - prev_evict <= 2, "eviction grant exceeded evict_step_tokens");
            prev = s;
            prev_evict = evict;
        }
        // Pinned 16 tokens over a 2-chunk (8-token) budget: eviction takes
        // ceil(16/2) = 8 further steps of 2-token grants.
        assert!(e.tree().pool().in_use() > 2, "pin still resident right after the request");
        for _ in 0..20 {
            e.step().unwrap();
            if e.retainer().unwrap().over_budget(e.tree()) {
                over_budget_steps += 1;
            }
        }
        assert_eq!(e.tree().pool().in_use(), 0, "pin eventually evicted");
        assert!(over_budget_steps >= 3, "eviction must span several steps (amortized)");
        assert_eq!(e.retainer().unwrap().evicted_pins_total(), 1);
        assert!(e.retainer().unwrap().evicted_chunks_total() >= 4);
        e.tree().check_invariants().unwrap();
    }

    #[test]
    fn per_tenant_counters_track_admissions_and_decode_tokens() {
        let mut e = engine();
        e.submit(trequest(0, 3, (0..8).collect(), 2));
        e.submit(trequest(1, 5, (100..108).collect(), 3));
        e.run_to_completion().unwrap();
        let (tenants, _) = e.planner().tenant_counters();
        assert_eq!(tenants.get(&3).unwrap().admitted, 1);
        assert_eq!(tenants.get(&5).unwrap().admitted, 1);
        // The first completion token is credited at prefill, so decode
        // steps produce completion-1 tokens per request.
        assert_eq!(tenants.get(&3).unwrap().decode_tokens, 1);
        assert_eq!(tenants.get(&5).unwrap().decode_tokens, 2);
    }

    #[test]
    fn tree_grows_and_shrinks_with_load() {
        let mut e = engine();
        for i in 0..6 {
            let mut p: Vec<u32> = (0..20).collect(); // shared system prompt
            p.push(100 + i as u32);
            e.submit(request(i, p, 4));
        }
        e.run_to_completion().unwrap();
        assert_eq!(e.tree().pool().in_use(), 0);
        assert!(e.tree().pool().allocated() > 0, "pool retains capacity");
        e.tree().check_invariants().err().map(|e| panic!("{e}"));
    }
}
