//! Virtual-time end-to-end serving simulator — regenerates Figure 5 and
//! Table 4 at Llama2-7B scale without the authors' A100 testbed.
//!
//! The *control plane is real*: the actual [`Scheduler`] (continuous
//! batching), the actual [`PrefixTree`] / [`PagedKvCache`] managers (run in
//! token-accounting mode: KV shape 1×1 so the structures and their
//! invariants are exercised while bytes are priced analytically), and real
//! per-request latency accounting. Only the *GPU kernel time* is priced by
//! the calibrated A100 roofline ([`perf_model`]) instead of being measured
//! — the substitution documented in DESIGN.md §2.

use std::collections::BTreeMap;

use super::planner::{make_policy, PlannerConfig, QueueItem, SchedPolicyKind};
use super::scheduler::{FinishedSeq, Scheduler};
use crate::kvcache::{KvDtype, KvShape, MonolithicKvCache, PagedKvCache, PrefixTree, SeqId};
use crate::model::ModelConfig;
use crate::perf_model::{attention_step_cost, AttentionImpl, CacheSharingState, HardwareModel};
use crate::workload::Trace;

/// Serving system being simulated (a Figure 5 line).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    /// ChunkLlama: prefix tree + TPP kernel + prefill prefix lookup.
    ChunkLlama,
    /// vLLM 0.2.7: paged KV, private pages, PagedAttention kernel.
    Vllm,
    /// HF text-generation-inference: contiguous per-sequence KV, naive-ish
    /// decode attention (Table 3's non-paged baseline constants).
    Tgi,
}

impl SystemKind {
    pub fn label(&self) -> &'static str {
        match self {
            SystemKind::ChunkLlama => "ChunkLlama",
            SystemKind::Vllm => "vLLM",
            SystemKind::Tgi => "TGI",
        }
    }

    fn attention_impl(&self) -> AttentionImpl {
        match self {
            SystemKind::ChunkLlama => AttentionImpl::ChunkAttn,
            SystemKind::Vllm => AttentionImpl::PagedAttn,
            SystemKind::Tgi => AttentionImpl::Naive,
        }
    }
}

/// Simulation parameters.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub system: SystemKind,
    pub max_batch: usize,
    /// Chunk size (ChunkLlama) / page size (vLLM), tokens.
    pub chunk_size: usize,
    /// Capacity headroom a monolithic server reserves per sequence
    /// (prompt + max_new_tokens), matching TGI's preallocation.
    pub mono_headroom: usize,
    /// Admission-scheduling policy (`--sched-policy`); the same planner
    /// policies the live engine runs, so Table-4-style comparisons can be
    /// re-run per policy. The default degenerates to FCFS on single-
    /// tenant traces (all scores tie).
    pub policy: SchedPolicyKind,
    /// Storage dtype the token accounting prices KV bytes at
    /// (`--kv-dtype`). F16 reproduces the paper's Table-4 convention;
    /// int8 halves the per-token cost and adds the per-head scale
    /// overhead the real chunks carry.
    pub kv_dtype: KvDtype,
}

impl SimConfig {
    pub fn new(system: SystemKind) -> Self {
        SimConfig {
            system,
            max_batch: 32,
            chunk_size: 64,
            mono_headroom: 0,
            policy: SchedPolicyKind::PrefixGreedy,
            kv_dtype: KvDtype::F16,
        }
    }
}

/// Result of one simulated trace.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub system: SystemKind,
    /// Mean of per-request normalized latency (ms per completion token) —
    /// the paper's Fig 5 / Table 4 headline metric.
    pub normalized_latency_ms_per_tok: f64,
    pub p99_normalized_latency: f64,
    /// Peak KV cache bytes priced at `SimConfig::kv_dtype` (the f16
    /// default is Table 4's accounting convention).
    pub peak_kv_bytes: u64,
    pub peak_batch: usize,
    /// Completion tokens per simulated second.
    pub decode_tps: f64,
    pub finished_requests: usize,
    pub sim_duration_s: f64,
    /// Total GPU-seconds spent in self-attention vs everything else
    /// (diagnostics for the ablation bench).
    pub attn_time_s: f64,
    pub other_time_s: f64,
}

/// Token-accounting KV manager: the real structures at KV shape 1×1.
enum KvAccounting {
    Tree(PrefixTree),
    Paged(PagedKvCache, BTreeMap<usize, SeqId>), // tenant -> donor seq
    Mono(MonolithicKvCache),
}

impl KvAccounting {
    fn peak_tokens_bytes(&self, model: &ModelConfig, shape: &KvShape) -> u64 {
        // Structures run at shape heads=1, head_dim=1 in the configured
        // storage dtype, so peak token *counts* come from dividing peak
        // structure bytes by that shape's exact per-token cost (for int8
        // this includes the per-chunk scale bytes the slabs carry). The
        // count is then priced at the real model: `kv_bytes_per_token` is
        // an FP16 convention (2 bytes/element — the paper's Table 4), so
        // other dtypes rescale by `dtype.bytes() / 2`; at real head_dim ×
        // chunk_size granularity the int8 scale overhead per element is
        // negligible and is not re-added.
        let unit = shape.bytes_per_chunk() as f64 / shape.chunk_size as f64;
        let bytes = match self {
            KvAccounting::Tree(t) => t.pool().peak_bytes() as f64,
            KvAccounting::Paged(p, _) => p.peak_bytes() as f64,
            KvAccounting::Mono(m) => m.peak_bytes() as f64,
        };
        let dtype_scale = shape.dtype.bytes() as f64 / 2.0;
        (bytes / unit * model.kv_bytes_per_token() * dtype_scale) as u64
    }
}

/// Run one trace through one simulated system.
pub fn simulate(
    cfg: &SimConfig,
    model: &ModelConfig,
    hw: &HardwareModel,
    trace: &Trace,
) -> SimResult {
    // Token-accounting shape in the configured storage dtype (`--kv-dtype`;
    // the f16 default reproduces Table 4's fp16 pricing).
    let shape = KvShape::new(1, 1, cfg.chunk_size).with_dtype(cfg.kv_dtype);
    let mut kv = match cfg.system {
        SystemKind::ChunkLlama => KvAccounting::Tree(PrefixTree::new(shape)),
        SystemKind::Vllm => {
            KvAccounting::Paged(PagedKvCache::new(shape, cfg.chunk_size), BTreeMap::new())
        }
        SystemKind::Tgi => KvAccounting::Mono(MonolithicKvCache::new(shape)),
    };
    let mut sched = Scheduler::new(cfg.max_batch);
    let mut policy = make_policy(&PlannerConfig { policy: cfg.policy, ..PlannerConfig::default() });
    // Wait clocks for the aging policy, in scheduling iterations —
    // mirrors `StepPlanner::plan`'s first_seen bookkeeping (seed on first
    // sighting, prune on admission/disappearance) so the sim's
    // waited_steps semantics match the live engine's.
    let mut sched_iter: u64 = 0;
    let mut first_seen: BTreeMap<u64, u64> = BTreeMap::new();
    let mut now = 0.0f64;
    let mut next_arrival = 0usize;
    let mut attn_time = 0.0f64;
    let mut other_time = 0.0f64;
    let mut decoded_tokens = 0u64;
    let mut fill = |_pos: usize, _tok: u32, k: &mut [f32], v: &mut [f32]| {
        k[0] = 0.0;
        v[0] = 0.0;
    };

    let total = trace.requests.len();
    let mut finished: Vec<FinishedSeq> = Vec::new();
    while finished.len() < total {
        // Deliver arrivals up to `now`.
        while next_arrival < total && trace.requests[next_arrival].arrival_s <= now {
            sched.submit(trace.requests[next_arrival].clone());
            next_arrival += 1;
        }
        // If nothing is running or queued, jump to the next arrival.
        if sched.is_idle() {
            if next_arrival < total {
                now = trace.requests[next_arrival].arrival_s;
                continue;
            }
            break;
        }
        // Admit into free slots, ranked by the configured policy; prefill
        // each admitted request.
        sched_iter += 1;
        let queued_now: Vec<u64> = sched.queue().iter().map(|r| r.id).collect();
        first_seen.retain(|id, _| queued_now.contains(id));
        for &id in &queued_now {
            first_seen.entry(id).or_insert(sched_iter);
        }
        let slots = cfg.max_batch.saturating_sub(sched.batch_size());
        let admitted = if slots == 0 || sched.queued() == 0 {
            Vec::new()
        } else {
            let items: Vec<QueueItem<'_>> = sched
                .queue()
                .iter()
                .map(|r| QueueItem {
                    id: r.id,
                    tenant: r.tenant,
                    prompt: &r.prompt,
                    cached: match &kv {
                        KvAccounting::Tree(tree) => tree.match_prefix(&r.prompt),
                        _ => 0,
                    },
                    waited_steps: sched_iter - first_seen.get(&r.id).copied().unwrap_or(sched_iter),
                })
                .collect();
            let ids = policy.rank_admission(&items, &[], slots);
            for id in &ids {
                first_seen.remove(id);
            }
            sched.admit_ids(&ids, now)
        };
        for seq in &admitted {
            let req = &seq.request;
            let sid = SeqId(req.id);
            let prefill_tokens = match &mut kv {
                KvAccounting::Tree(tree) => {
                    let matched = tree.match_prefix(&req.prompt);
                    tree.insert_sequence(sid, &req.prompt, &mut fill);
                    req.prompt.len() - matched // prefix lookup skips compute
                }
                KvAccounting::Paged(paged, donors) => {
                    // vLLM 0.2.7: private pages, full prefill recompute.
                    if let Some(&donor) = donors.get(&req.tenant) {
                        // (kept for the PagedAttn* ablation; plain vLLM
                        // inserts privately)
                        let _ = donor;
                    }
                    paged.insert_sequence(sid, &req.prompt, &mut fill);
                    donors.entry(req.tenant).or_insert(sid);
                    req.prompt.len()
                }
                KvAccounting::Mono(mono) => {
                    let cap = req.prompt.len() + req.max_new_tokens + cfg.mono_headroom;
                    mono.insert_sequence(sid, &req.prompt, cap, &mut fill);
                    req.prompt.len()
                }
            };
            if prefill_tokens > 0 {
                let t = hw.latency_s(&model.prefill_cost(prefill_tokens));
                now += t;
                other_time += t;
            }
        }
        if sched.batch_size() == 0 {
            continue;
        }
        // One decode iteration: price per-layer modules at this batch, plus
        // attention per tenant group (sharing-aware).
        let b = sched.batch_size();
        let layer_other = hw.latency_s(&model.qkv_projection_cost(b))
            + hw.latency_s(&model.out_projection_cost(b))
            + hw.latency_s(&model.mlp_cost(b));
        let mut layer_attn = 0.0;
        let mut groups: BTreeMap<usize, (usize, usize, usize)> = BTreeMap::new();
        for s in sched.active() {
            let e = groups.entry(s.request.tenant).or_insert((0, 0, usize::MAX));
            e.0 += 1;
            e.1 += s.context_len();
            e.2 = e.2.min(s.request.shared_tokens);
        }
        for (_tenant, (gb, ctx_sum, shared)) in groups {
            let imp = cfg.system.attention_impl();
            let shared = if imp.prefix_aware() && gb > 1 { shared } else { 0 };
            let state =
                CacheSharingState { batch: gb, context: ctx_sum / gb, shared };
            layer_attn += attention_step_cost(hw, model, imp, &state);
        }
        let step_attn = layer_attn * model.n_layers as f64;
        let step_other =
            layer_other * model.n_layers as f64 + hw.latency_s(&model.lm_head_cost(b));
        now += step_attn + step_other;
        attn_time += step_attn;
        other_time += step_other;
        decoded_tokens += b as u64;

        // Append one token per active sequence, retire completed ones.
        let active_ids: Vec<SeqId> = sched.active().iter().map(|s| SeqId(s.request.id)).collect();
        for sid in active_ids {
            match &mut kv {
                KvAccounting::Tree(tree) => tree.append_token(sid, 0, &[0.0], &[0.0]),
                KvAccounting::Paged(paged, _) => paged.append_token(sid, &[0.0], &[0.0]),
                KvAccounting::Mono(mono) => mono.append_token(sid, &[0.0], &[0.0]),
            }
        }
        for done in sched.step_decode(now) {
            let sid = SeqId(done.request.id);
            match &mut kv {
                KvAccounting::Tree(tree) => tree.remove_sequence(sid),
                KvAccounting::Paged(paged, donors) => {
                    // Keep the donor map consistent if the donor leaves.
                    if donors.get(&done.request.tenant) == Some(&sid) {
                        donors.remove(&done.request.tenant);
                    }
                    paged.remove_sequence(sid);
                }
                KvAccounting::Mono(mono) => mono.remove_sequence(sid),
            }
            finished.push(done);
        }
    }

    let mut lat: Vec<f64> = finished.iter().map(|f| f.normalized_latency_ms_per_tok()).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = lat.iter().sum::<f64>() / lat.len().max(1) as f64;
    let p99 = if lat.is_empty() { 0.0 } else { lat[((lat.len() - 1) as f64 * 0.99) as usize] };
    SimResult {
        system: cfg.system,
        normalized_latency_ms_per_tok: mean,
        p99_normalized_latency: p99,
        peak_kv_bytes: kv.peak_tokens_bytes(model, &shape),
        peak_batch: sched.peak_batch(),
        decode_tps: decoded_tokens as f64 / now.max(1e-9),
        finished_requests: finished.len(),
        sim_duration_s: now,
        attn_time_s: attn_time,
        other_time_s: other_time,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TraceConfig;

    fn trace(rps: f64, n: usize, system_tokens: usize, completion: usize) -> Trace {
        Trace::poisson_synthetic(
            &TraceConfig {
                rps,
                n_requests: n,
                n_tenants: 1,
                tenant_skew: 0.0,
                query_tokens: 32,
                completion_tokens: completion,
                seed: 3,
            },
            system_tokens,
        )
    }

    fn run(system: SystemKind, trace: &Trace) -> SimResult {
        let cfg = SimConfig::new(system);
        simulate(&cfg, &ModelConfig::llama2_7b(), &HardwareModel::a100_80g(), trace)
    }

    #[test]
    fn all_requests_finish() {
        let t = trace(1.0, 60, 1024, 64);
        for sys in [SystemKind::ChunkLlama, SystemKind::Vllm, SystemKind::Tgi] {
            let r = run(sys, &t);
            assert_eq!(r.finished_requests, 60, "{sys:?}");
            assert!(r.normalized_latency_ms_per_tok > 0.0);
        }
    }

    #[test]
    fn peak_kv_accounting_honors_the_configured_dtype() {
        // Same trace, same system: f32 doubles the f16 peak and int8
        // roughly halves it (exactly, up to the per-chunk scale bytes the
        // int8 slabs carry). Latency is dtype-independent in the sim.
        let t = trace(0.8, 40, 1024, 64);
        for sys in [SystemKind::ChunkLlama, SystemKind::Vllm, SystemKind::Tgi] {
            let at = |d: KvDtype| {
                let cfg = SimConfig { kv_dtype: d, ..SimConfig::new(sys) };
                simulate(&cfg, &ModelConfig::llama2_7b(), &HardwareModel::a100_80g(), &t)
            };
            let half = at(KvDtype::F16);
            let full = at(KvDtype::F32);
            let int8 = at(KvDtype::Int8);
            assert_eq!(full.peak_kv_bytes, 2 * half.peak_kv_bytes, "{sys:?}");
            let want = half.peak_kv_bytes as f64 / 2.0;
            let ratio = int8.peak_kv_bytes as f64 / want;
            assert!(
                (0.9..=1.1).contains(&ratio),
                "{sys:?}: int8 peak {} not ~half of f16 {}",
                int8.peak_kv_bytes,
                half.peak_kv_bytes
            );
            assert_eq!(
                half.normalized_latency_ms_per_tok, int8.normalized_latency_ms_per_tok,
                "{sys:?}: accounting dtype must not change simulated timing"
            );
        }
    }

    #[test]
    fn chunkllama_beats_vllm_with_shared_prefix() {
        // Table 4 shape: n_p=2048-ish shared prompt, ChunkLlama faster and
        // with far smaller peak KV.
        let t = trace(0.8, 80, 2048, 128);
        let chunk = run(SystemKind::ChunkLlama, &t);
        let vllm = run(SystemKind::Vllm, &t);
        assert!(
            chunk.normalized_latency_ms_per_tok < vllm.normalized_latency_ms_per_tok,
            "chunk {} vs vllm {}",
            chunk.normalized_latency_ms_per_tok,
            vllm.normalized_latency_ms_per_tok
        );
        let ratio = vllm.peak_kv_bytes as f64 / chunk.peak_kv_bytes as f64;
        assert!(ratio > 2.0, "kv reduction {ratio}");
    }

    #[test]
    fn no_regression_without_sharing() {
        // Table 4 rows with n_s=0: ChunkLlama within ~10% of vLLM.
        let t = Trace::poisson_synthetic(
            &TraceConfig {
                rps: 0.6,
                n_requests: 40,
                n_tenants: 40, // every request its own tenant: nothing shared
                tenant_skew: 0.0,
                query_tokens: 32,
                completion_tokens: 64,
                seed: 5,
            },
            1024,
        );
        let chunk = run(SystemKind::ChunkLlama, &t);
        let vllm = run(SystemKind::Vllm, &t);
        let rel = chunk.normalized_latency_ms_per_tok / vllm.normalized_latency_ms_per_tok;
        assert!((0.85..1.1).contains(&rel), "rel {rel}");
    }

    #[test]
    fn saturation_raises_latency() {
        // Fig 5 shape: latency explodes as RPS exceeds capacity.
        let low = run(SystemKind::Vllm, &trace(0.2, 40, 1024, 64));
        let high = run(SystemKind::Vllm, &trace(8.0, 40, 1024, 64));
        assert!(
            high.normalized_latency_ms_per_tok > 2.0 * low.normalized_latency_ms_per_tok,
            "low {} high {}",
            low.normalized_latency_ms_per_tok,
            high.normalized_latency_ms_per_tok
        );
    }

    #[test]
    fn tgi_memory_exceeds_vllm() {
        // Monolithic preallocation wastes capacity vs paging.
        let t = trace(0.5, 40, 512, 256);
        let tgi = run(SystemKind::Tgi, &t);
        let vllm = run(SystemKind::Vllm, &t);
        assert!(tgi.peak_kv_bytes > vllm.peak_kv_bytes);
    }
}
