//! Layer-3 coordination: continuous-batching scheduler (§2.2), the real
//! serving engine over PAKV+TPP, the microkernel bench harness (§4.1), and
//! the virtual-time end-to-end simulator (§4.2).

pub mod engine;
pub mod microbench;
pub mod planner;
pub mod scheduler;
pub mod sim;

pub use engine::{DecodeOutput, Engine, EngineStats, ModelRunner, PrefillOutput};
pub use microbench::{AblationConfig, KernelBench, MicroConfig, TppVariant};
pub use planner::{
    PlannerConfig, SchedPolicy, SchedPolicyKind, StepPlan, StepPlanner, TenantCounters,
};
pub use scheduler::{ActiveSeq, FinishedSeq, PrefillingSeq, Removed, Scheduler};
pub use sim::{simulate, SimConfig, SimResult, SystemKind};
