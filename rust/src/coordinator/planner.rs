//! Policy-driven step planning: who gets the shared budget, each step.
//!
//! ChunkAttention's prefix-aware KV cache makes *sharing* cheap, but the
//! serving loop still has to decide *who* shares: greedy
//! longest-shared-prefix admission maximizes reuse and can starve a cold
//! tenant behind a storm of prefix-sharing arrivals (the RelayAttention /
//! Prompt Cache observation that long-system-prompt wins are realized or
//! lost at the scheduler). This module centralizes those decisions in a
//! [`StepPlanner`]: once per engine iteration it produces a single
//! [`StepPlan`] —
//!
//! - which queued requests to admit (ranked by the pluggable
//!   [`SchedPolicy`]),
//! - which active sequences decode this step (a *partial* batch when the
//!   per-step token budget is tight, rotated so no sequence lags more
//!   than a bounded number of steps),
//! - how many eviction tokens the [`PrefixRetainer`] may spend
//!   (amortizing pinned-prefix eviction instead of between-step bursts),
//! - and how many tokens remain for prefill slices —
//!
//! all charged against one per-step token budget, so
//! `prefill + decode + eviction <= budget` holds for every policy.
//!
//! Three policies ship behind `--sched-policy`:
//!
//! - [`PrefixGreedy`]: today's behavior, bit-for-bit — longest
//!   cached/in-progress prefix match first, FCFS tiebreak.
//! - [`Drr`]: per-tenant deficit round-robin with configurable weights;
//!   a tenant's admissions are proportional to its weight regardless of
//!   how well its prompts share.
//! - [`Aging`]: prefix-greedy plus a wait-time boost, so a cold tenant's
//!   score grows every step it waits and admission within
//!   `ceil(max_prefix_score / aging_boost_tokens)` frees-of-a-slot is
//!   guaranteed.
//!
//! [`PrefixRetainer`]: crate::kvcache::PrefixRetainer

use std::collections::BTreeMap;

use crate::kvcache::tree::common_prefix;
use crate::workload::Request;

use super::scheduler::{ActiveSeq, PrefillingSeq};

/// Which scheduling policy ranks admissions (`--sched-policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicyKind {
    /// Longest cached/in-progress shared prefix first, FCFS tiebreak —
    /// the historical behavior, preserved bit-for-bit.
    PrefixGreedy,
    /// Per-tenant deficit round-robin with configurable weights.
    Drr,
    /// Prefix-greedy plus a per-step wait boost: starvation-free.
    Aging,
}

impl SchedPolicyKind {
    /// Parse a `--sched-policy` value.
    pub fn parse(s: &str) -> Option<SchedPolicyKind> {
        match s {
            "prefix-greedy" => Some(SchedPolicyKind::PrefixGreedy),
            "drr" => Some(SchedPolicyKind::Drr),
            "aging" => Some(SchedPolicyKind::Aging),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SchedPolicyKind::PrefixGreedy => "prefix-greedy",
            SchedPolicyKind::Drr => "drr",
            SchedPolicyKind::Aging => "aging",
        }
    }
}

/// Planner tuning knobs. The defaults keep `prefix-greedy` identical to
/// the pre-planner engine.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    pub policy: SchedPolicyKind,
    /// DRR: tokens credited to a tenant's deficit per round-robin visit.
    /// A tenant admits its head-of-line request once its deficit covers
    /// the prompt length, so relative admission rates follow
    /// `quantum * weight`.
    pub drr_quantum: usize,
    /// DRR: per-tenant weights (tenant id, weight); unlisted tenants get
    /// weight 1. Parsed from `--tenant-weights 0=4,3=2`.
    pub tenant_weights: Vec<(usize, u32)>,
    /// Aging: admission-score boost (in shared-prefix-token equivalents)
    /// per step a request has waited in the queue. Bounds starvation: a
    /// request waiting `ceil(L / boost)` steps outranks any sharer whose
    /// matchable prefix is at most `L` tokens.
    pub aging_boost_tokens: usize,
    /// Eviction-token allowance granted per step (charged against the
    /// step budget) while the retainer is over its chunk budget. With no
    /// step budget configured the allowance is unbounded (the historical
    /// between-step burst).
    pub evict_step_tokens: usize,
    /// Bounded per-tenant metric cardinality: tenants beyond this many
    /// distinct ids aggregate into one overflow bucket.
    pub tenant_metrics_cap: usize,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            policy: SchedPolicyKind::PrefixGreedy,
            drr_quantum: 256,
            tenant_weights: Vec::new(),
            aging_boost_tokens: 32,
            evict_step_tokens: 256,
            tenant_metrics_cap: 16,
        }
    }
}

/// One queued request as the ranking policies see it.
#[derive(Debug, Clone, Copy)]
pub struct QueueItem<'a> {
    pub id: u64,
    pub tenant: usize,
    pub prompt: &'a [u32],
    /// Longest prefix of `prompt` already resident in the KV cache.
    pub cached: usize,
    /// Planner steps this request has waited in the queue.
    pub waited_steps: u64,
}

/// The admission-ranking seam: a policy orders queued requests into free
/// batch slots. Everything else in the step plan (budget split, decode
/// rotation, eviction allowance) is policy-independent budget enforcement
/// owned by [`StepPlanner`].
pub trait SchedPolicy: Send {
    fn kind(&self) -> SchedPolicyKind;

    /// Return up to `slots` request ids in admission order. `prefilling`
    /// carries the prompts of requests already admitted but still
    /// prefilling (their content is matchable, so policies may group
    /// sharers with them).
    fn rank_admission(
        &mut self,
        queue: &[QueueItem<'_>],
        prefilling: &[&[u32]],
        slots: usize,
    ) -> Vec<u64>;
}

/// Greedy longest-shared-prefix admission with FCFS tiebreaks — exactly
/// the pre-planner `Scheduler::admit_prefilling` algorithm (regression-
/// tested against a literal copy of it below).
#[derive(Debug, Default)]
pub struct PrefixGreedy;

/// Score + argmax selection shared by [`PrefixGreedy`] and [`Aging`]:
/// seed each queued request's score once (tree match folded with
/// affinity to the prefilling set), then per admitted slot fold in just
/// the newly selected prompt — the only term that can change. `boost(i)`
/// adds the policy-specific additive term (0 for prefix-greedy).
fn rank_greedy_with_boost(
    queue: &[QueueItem<'_>],
    prefilling: &[&[u32]],
    slots: usize,
    boost: impl Fn(&QueueItem<'_>) -> usize,
) -> Vec<u64> {
    let mut order = Vec::new();
    let mut remaining: Vec<&QueueItem<'_>> = queue.iter().collect();
    let mut scores: Vec<usize> = remaining
        .iter()
        .map(|it| {
            let mut s = it.cached;
            for p in prefilling {
                s = s.max(common_prefix(p, it.prompt));
            }
            s.saturating_add(boost(it))
        })
        .collect();
    while order.len() < slots && !remaining.is_empty() {
        let mut best = 0usize;
        let mut best_score = 0usize;
        for (i, &s) in scores.iter().enumerate() {
            if s > best_score {
                best = i;
                best_score = s;
            }
        }
        scores.remove(best);
        let picked = remaining.remove(best);
        order.push(picked.id);
        for (s, it) in scores.iter_mut().zip(remaining.iter()) {
            *s = (*s).max(common_prefix(picked.prompt, it.prompt).saturating_add(boost(it)));
        }
    }
    order
}

impl SchedPolicy for PrefixGreedy {
    fn kind(&self) -> SchedPolicyKind {
        SchedPolicyKind::PrefixGreedy
    }

    fn rank_admission(
        &mut self,
        queue: &[QueueItem<'_>],
        prefilling: &[&[u32]],
        slots: usize,
    ) -> Vec<u64> {
        rank_greedy_with_boost(queue, prefilling, slots, |_| 0)
    }
}

/// Prefix-greedy plus `waited_steps * boost`: reuse still wins while the
/// queue is fresh, but a request's score grows every step it waits, so a
/// cold tenant is admitted within `ceil(L / boost)` slot-frees, where `L`
/// bounds any competitor's matchable prefix.
#[derive(Debug)]
pub struct Aging {
    pub boost_tokens: usize,
}

impl SchedPolicy for Aging {
    fn kind(&self) -> SchedPolicyKind {
        SchedPolicyKind::Aging
    }

    fn rank_admission(
        &mut self,
        queue: &[QueueItem<'_>],
        prefilling: &[&[u32]],
        slots: usize,
    ) -> Vec<u64> {
        let boost = self.boost_tokens;
        rank_greedy_with_boost(queue, prefilling, slots, |it| {
            (it.waited_steps as usize).saturating_mul(boost)
        })
    }
}

/// Deficit round-robin over tenants: each visit credits a tenant's
/// deficit with `quantum * weight` tokens; a tenant admits its
/// head-of-line (FCFS within tenant) request when the deficit covers the
/// prompt length. Tenants with nothing queued forfeit their deficit, so
/// credit cannot be hoarded across idle periods.
#[derive(Debug)]
pub struct Drr {
    pub quantum: usize,
    pub weights: BTreeMap<usize, u32>,
    deficits: BTreeMap<usize, u64>,
    /// Last tenant served, so the round-robin resumes after it.
    cursor: Option<usize>,
}

impl Drr {
    pub fn new(quantum: usize, weights: &[(usize, u32)]) -> Self {
        Drr {
            quantum: quantum.max(1),
            weights: weights.iter().copied().collect(),
            deficits: BTreeMap::new(),
            cursor: None,
        }
    }

    fn weight(&self, tenant: usize) -> u64 {
        (*self.weights.get(&tenant).unwrap_or(&1)).max(1) as u64
    }
}

impl SchedPolicy for Drr {
    fn kind(&self) -> SchedPolicyKind {
        SchedPolicyKind::Drr
    }

    fn rank_admission(
        &mut self,
        queue: &[QueueItem<'_>],
        _prefilling: &[&[u32]],
        slots: usize,
    ) -> Vec<u64> {
        // FCFS within tenant: tenants keyed in first-appearance order.
        let mut tenants: Vec<usize> = Vec::new();
        let mut heads: BTreeMap<usize, Vec<&QueueItem<'_>>> = BTreeMap::new();
        for it in queue {
            let entry = heads.entry(it.tenant).or_default();
            if entry.is_empty() {
                tenants.push(it.tenant);
            }
            entry.push(it);
        }
        // Forfeit deficits of tenants with nothing queued.
        self.deficits.retain(|t, _| heads.contains_key(t));
        // Resume the round after the cursor tenant.
        if let Some(cur) = self.cursor {
            if let Some(pos) = tenants.iter().position(|&t| t == cur) {
                tenants.rotate_left((pos + 1) % tenants.len());
            }
        }
        let mut order = Vec::new();
        let mut rr = 0usize;
        while order.len() < slots {
            if heads.values().all(|v| v.is_empty()) {
                break; // every tenant's queue is drained
            }
            // One admission may need several credit rounds (quantum below
            // the head-of-line prompt cost); each visit to a non-empty
            // tenant grows its deficit by `quantum * weight >= 1`, so some
            // deficit covers its head within ceil(max_cost / quantum)
            // passes and the loop terminates.
            loop {
                let t = tenants[rr % tenants.len()];
                rr += 1;
                let pending = heads.get_mut(&t).expect("tenants derive from heads keys");
                if pending.is_empty() {
                    continue;
                }
                let credit = self.quantum as u64 * self.weight(t);
                let deficit = self.deficits.entry(t).or_insert(0);
                *deficit = deficit.saturating_add(credit);
                let head = pending[0];
                let cost = head.prompt.len() as u64;
                if *deficit >= cost {
                    *deficit -= cost;
                    pending.remove(0);
                    order.push(head.id);
                    self.cursor = Some(t);
                    break;
                }
            }
        }
        order
    }
}

/// Build the policy object for a kind.
pub fn make_policy(cfg: &PlannerConfig) -> Box<dyn SchedPolicy> {
    match cfg.policy {
        SchedPolicyKind::PrefixGreedy => Box::new(PrefixGreedy),
        SchedPolicyKind::Drr => Box::new(Drr::new(cfg.drr_quantum, &cfg.tenant_weights)),
        SchedPolicyKind::Aging => Box::new(Aging { boost_tokens: cfg.aging_boost_tokens.max(1) }),
    }
}

/// Per-tenant serving counters (bounded cardinality; see
/// [`StepPlanner::tenant_counters`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct TenantCounters {
    /// Requests admitted into the prefill queue.
    pub admitted: u64,
    /// Steps in which a queued request of this tenant was passed over by
    /// a later-arrived admission (an out-of-FCFS-order bypass).
    pub deferred: u64,
    /// Decode tokens produced for this tenant's sequences.
    pub decode_tokens: u64,
}

/// What the planner needs to see to plan one step. Borrowed views only —
/// the planner never mutates engine state directly.
pub struct PlanInputs<'a> {
    pub queue: &'a std::collections::VecDeque<Request>,
    pub prefilling: &'a std::collections::VecDeque<PrefillingSeq>,
    pub active: &'a [ActiveSeq],
    /// Free batch slots (max_batch - active - prefilling).
    pub free_slots: usize,
    /// Per-step token budget; `None` = unbounded.
    pub step_budget: Option<usize>,
    /// Whether the prefix retainer is over its chunk budget (the cheap
    /// resident fast-path check) and has pins to spend.
    pub retainer_over_budget: bool,
    /// Longest resident prefix of a queued request's prompt.
    pub cached_match: &'a dyn Fn(&Request) -> usize,
}

/// One step's scheduling decisions, all charged to the same budget:
/// `decode_take + prefill_budget + evict_tokens <= step_budget`.
#[derive(Debug, Clone)]
pub struct StepPlan {
    /// Queued request ids to admit, in admission order.
    pub admit_ids: Vec<u64>,
    /// Active sequence ids that sit this decode step out (partial decode
    /// under a tight budget). Empty = full batch, the historical path.
    pub decode_skip: Vec<u64>,
    /// Decode tokens this step will spend (`active - skipped`).
    pub decode_take: usize,
    /// Eviction-token allowance granted to the retainer this step.
    pub evict_tokens: usize,
    /// Tokens left for prefill slices.
    pub prefill_budget: usize,
}

/// The per-step planner: owns the policy, the admission wait clocks, the
/// decode-lag rotation, and the per-tenant counters.
pub struct StepPlanner {
    cfg: PlannerConfig,
    policy: Box<dyn SchedPolicy>,
    /// Planner step counter (one per [`StepPlanner::plan`] call).
    step: u64,
    /// Queued request id -> step it was first seen (for aging).
    first_seen: BTreeMap<u64, u64>,
    /// Active sequence id -> consecutive decode steps skipped.
    decode_lag: BTreeMap<u64, u64>,
    /// Highest decode lag ever reached (observability + lag-bound tests).
    max_lag_observed: u64,
    tenants: BTreeMap<usize, TenantCounters>,
    overflow: TenantCounters,
}

impl StepPlanner {
    pub fn new(cfg: PlannerConfig) -> Self {
        let policy = make_policy(&cfg);
        StepPlanner {
            cfg,
            policy,
            step: 0,
            first_seen: BTreeMap::new(),
            decode_lag: BTreeMap::new(),
            max_lag_observed: 0,
            tenants: BTreeMap::new(),
            overflow: TenantCounters::default(),
        }
    }

    pub fn config(&self) -> &PlannerConfig {
        &self.cfg
    }

    pub fn policy_kind(&self) -> SchedPolicyKind {
        self.policy.kind()
    }

    /// Highest consecutive decode-lag any sequence has accumulated.
    pub fn max_decode_lag(&self) -> u64 {
        self.max_lag_observed
    }

    /// Per-tenant counters, plus the overflow bucket aggregating tenants
    /// beyond the cardinality cap (`None` key in exposition: "other").
    pub fn tenant_counters(&self) -> (&BTreeMap<usize, TenantCounters>, &TenantCounters) {
        (&self.tenants, &self.overflow)
    }

    fn tenant_mut(&mut self, tenant: usize) -> &mut TenantCounters {
        if self.tenants.contains_key(&tenant) || self.tenants.len() < self.cfg.tenant_metrics_cap {
            self.tenants.entry(tenant).or_default()
        } else {
            &mut self.overflow
        }
    }

    /// Record one decode token for a tenant (called by the engine as it
    /// appends decode output, so newly activated sequences count too).
    pub fn note_decode_token(&mut self, tenant: usize) {
        self.tenant_mut(tenant).decode_tokens += 1;
    }

    /// Forget a request's wait/lag state (cancelled or finished).
    pub fn forget(&mut self, id: u64) {
        self.first_seen.remove(&id);
        self.decode_lag.remove(&id);
    }

    /// Produce this step's plan. Mutates planner state: wait clocks tick,
    /// decode lags rotate, per-tenant admission/deferral counters bump.
    pub fn plan(&mut self, inputs: &PlanInputs<'_>) -> StepPlan {
        self.step += 1;
        let step = self.step;

        // --- Admission ranking ------------------------------------------------
        // Tick wait clocks: a request waits from the first step it is seen
        // queued. Prune ids no longer queued (admitted or cancelled).
        let queued_ids: std::collections::BTreeSet<u64> =
            inputs.queue.iter().map(|r| r.id).collect();
        self.first_seen.retain(|id, _| queued_ids.contains(id));
        for r in inputs.queue {
            self.first_seen.entry(r.id).or_insert(step);
        }
        // Rank (and pay the per-request cached_match tree walks) only
        // when a slot is actually free: a saturated batch must not spend
        // O(queue × prompt) scoring work per step on an empty decision.
        let admit_ids = if inputs.free_slots == 0 || inputs.queue.is_empty() {
            Vec::new()
        } else {
            let items: Vec<QueueItem<'_>> = inputs
                .queue
                .iter()
                .map(|r| QueueItem {
                    id: r.id,
                    tenant: r.tenant,
                    prompt: &r.prompt,
                    cached: (inputs.cached_match)(r),
                    waited_steps: step - self.first_seen.get(&r.id).copied().unwrap_or(step),
                })
                .collect();
            let prefilling_prompts: Vec<&[u32]> =
                inputs.prefilling.iter().map(|p| p.request.prompt.as_slice()).collect();
            let admit_ids =
                self.policy.rank_admission(&items, &prefilling_prompts, inputs.free_slots);
            // Per-tenant admission + bypass accounting.
            if !admit_ids.is_empty() {
                let admitted: std::collections::BTreeSet<u64> =
                    admit_ids.iter().copied().collect();
                let last_admitted_pos = items
                    .iter()
                    .enumerate()
                    .filter(|(_, it)| admitted.contains(&it.id))
                    .map(|(i, _)| i)
                    .max()
                    .unwrap_or(0);
                for (i, it) in items.iter().enumerate() {
                    if admitted.contains(&it.id) {
                        self.tenant_mut(it.tenant).admitted += 1;
                    } else if i < last_admitted_pos {
                        // Passed over by a later arrival this step.
                        self.tenant_mut(it.tenant).deferred += 1;
                    }
                }
                for id in &admit_ids {
                    self.first_seen.remove(id);
                }
            }
            admit_ids
        };

        // --- Budget split: decode first, then eviction, prefill last ---------
        let batch = inputs.active.len();
        // Prefill can actually consume budget this step only if a prompt
        // is mid-prefill or one was just admitted; a full queue behind a
        // saturated batch must NOT shrink decode for budget nothing can
        // spend.
        let prefill_has_work = !inputs.prefilling.is_empty() || !admit_ids.is_empty();
        let (decode_take, decode_skip) = match inputs.step_budget {
            None => (batch, Vec::new()),
            Some(budget) => {
                // Keep a sliver of budget for prefill whenever prompts
                // can advance, so a full decode batch cannot starve
                // prefill forever under `budget <= batch`
                // misconfigurations.
                let decode_cap = if prefill_has_work {
                    budget - (budget / 4).max(1).min(budget)
                } else {
                    budget
                };
                let mut take = batch.min(decode_cap);
                // Never let decode consume the entire budget while the
                // retainer is over its chunk budget: eviction credit must
                // grow on every over-budget step or a sustained full
                // batch (budget <= max_batch misconfigurations) would
                // hold evicted-pending memory forever.
                if inputs.retainer_over_budget && take == budget {
                    take -= 1;
                }
                let skip = self.rotate_decode(inputs.active, take);
                (take, skip)
            }
        };

        // --- Eviction allowance ----------------------------------------------
        let after_decode = inputs.step_budget.map(|b| b - decode_take);
        let evict_tokens = if !inputs.retainer_over_budget {
            0
        } else {
            match after_decode {
                None => usize::MAX,
                // `.max(1)` guards an evict_step_tokens: 0 misconfig:
                // eviction credit must grow on over-budget steps or
                // maintenance could never converge.
                Some(rem) => self.cfg.evict_step_tokens.max(1).min(rem),
            }
        };

        let prefill_budget = match after_decode {
            None => usize::MAX,
            Some(rem) => rem - if evict_tokens == usize::MAX { 0 } else { evict_tokens },
        };

        StepPlan { admit_ids, decode_skip, decode_take, evict_tokens, prefill_budget }
    }

    /// Select which active sequences sit out (batch - take of them),
    /// highest accumulated lag decoding first so the rotation bounds any
    /// sequence's lag at `ceil(batch / take) - 1` consecutive skips.
    /// Updates the lag map.
    fn rotate_decode(&mut self, active: &[ActiveSeq], take: usize) -> Vec<u64> {
        let live: std::collections::BTreeSet<u64> =
            active.iter().map(|s| s.request.id).collect();
        self.decode_lag.retain(|id, _| live.contains(id));
        if take >= active.len() {
            for s in active {
                self.decode_lag.insert(s.request.id, 0);
            }
            return Vec::new();
        }
        // Stable order: by (lag desc, batch position asc) — deterministic
        // for a given history, independent of map iteration quirks.
        let mut ranked: Vec<(u64, usize, u64)> = active
            .iter()
            .enumerate()
            .map(|(pos, s)| {
                let lag = self.decode_lag.get(&s.request.id).copied().unwrap_or(0);
                (lag, pos, s.request.id)
            })
            .collect();
        ranked.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut skip = Vec::with_capacity(active.len() - take);
        for (i, &(lag, _, id)) in ranked.iter().enumerate() {
            if i < take {
                self.decode_lag.insert(id, 0);
            } else {
                let new_lag = lag + 1;
                self.max_lag_observed = self.max_lag_observed.max(new_lag);
                self.decode_lag.insert(id, new_lag);
                skip.push(id);
            }
        }
        skip
    }
}

/// Rank a queue with the plain prefix-greedy policy — the seam
/// [`Scheduler::admit_prefilling`] delegates to so its historical
/// behavior and the planner's `prefix-greedy` policy cannot drift apart.
///
/// [`Scheduler::admit_prefilling`]: super::scheduler::Scheduler::admit_prefilling
pub fn rank_prefix_greedy(
    queue: &[QueueItem<'_>],
    prefilling: &[&[u32]],
    slots: usize,
) -> Vec<u64> {
    PrefixGreedy.rank_admission(queue, prefilling, slots)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pbt;
    use crate::util::rng::Pcg64;

    fn item(id: u64, tenant: usize, prompt: &[u32], cached: usize, waited: u64) -> QueueItem<'_> {
        QueueItem { id, tenant, prompt, cached, waited_steps: waited }
    }

    #[test]
    fn parse_and_label_round_trip() {
        for kind in [SchedPolicyKind::PrefixGreedy, SchedPolicyKind::Drr, SchedPolicyKind::Aging] {
            assert_eq!(SchedPolicyKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(SchedPolicyKind::parse("fifo"), None);
    }

    /// Literal copy of the pre-planner `Scheduler::admit_prefilling`
    /// selection loop, kept as the bit-compatibility oracle: seed scores
    /// from (cached match, prefilling affinity), then repeatedly take the
    /// strict argmax (FCFS tiebreak) and fold the winner's prompt into
    /// the survivors' scores.
    fn reference_admission_order(
        prompts: &[Vec<u32>],
        cached: &[usize],
        prefilling: &[Vec<u32>],
        slots: usize,
    ) -> Vec<usize> {
        let mut queue: Vec<usize> = (0..prompts.len()).collect();
        let mut scores: Vec<usize> = queue
            .iter()
            .map(|&i| {
                let mut s = cached[i];
                for p in prefilling {
                    s = s.max(common_prefix(p, &prompts[i]));
                }
                s
            })
            .collect();
        let mut order = Vec::new();
        while order.len() < slots && !queue.is_empty() {
            let mut best = 0usize;
            let mut best_score = 0usize;
            for (i, &s) in scores.iter().enumerate() {
                if s > best_score {
                    best = i;
                    best_score = s;
                }
            }
            scores.remove(best);
            let picked = queue.remove(best);
            order.push(picked);
            for (s, &i) in scores.iter_mut().zip(queue.iter()) {
                *s = (*s).max(common_prefix(&prompts[picked], &prompts[i]));
            }
        }
        order
    }

    #[test]
    fn prefix_greedy_is_bit_compatible_with_the_pre_planner_algorithm() {
        // Random queues of tenant-structured prompts vs the literal copy
        // of the old loop: the admission order must match element-wise for
        // every slot count.
        pbt::check(
            "prefix-greedy-bit-compat",
            0x96EED,
            pbt::default_cases(),
            |rng: &mut Pcg64| {
                let n = rng.range(1, 12);
                let prompts: Vec<Vec<u32>> = (0..n)
                    .map(|_| {
                        let tenant = rng.below(3) as u32;
                        let shared = rng.range(0, 12);
                        let mut p: Vec<u32> = (0..shared as u32).map(|i| tenant * 100 + i).collect();
                        p.extend((0..rng.range(1, 4)).map(|_| 900 + rng.below(40) as u32));
                        p
                    })
                    .collect();
                let cached: Vec<usize> =
                    prompts.iter().map(|p| rng.range(0, p.len().min(6))).collect();
                let prefilling: Vec<Vec<u32>> = (0..rng.range(0, 2))
                    .map(|_| (0..rng.range(1, 10) as u32).collect())
                    .collect();
                let slots = rng.range(1, n + 2);
                (prompts, cached, prefilling, slots)
            },
            |(prompts, cached, prefilling, slots)| {
                let expect = reference_admission_order(prompts, cached, prefilling, *slots);
                let items: Vec<QueueItem<'_>> = prompts
                    .iter()
                    .enumerate()
                    .map(|(i, p)| item(i as u64, 0, p, cached[i], 0))
                    .collect();
                let pf: Vec<&[u32]> = prefilling.iter().map(|p| p.as_slice()).collect();
                let got = rank_prefix_greedy(&items, &pf, *slots);
                let got_idx: Vec<usize> = got.iter().map(|&id| id as usize).collect();
                if got_idx != expect {
                    return Err(format!("planner order {got_idx:?} != reference {expect:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn aging_boost_overcomes_any_prefix_score() {
        // A cold request that has waited long enough must outrank a fresh
        // sharer with a large cached prefix.
        let sharer: Vec<u32> = (0..64).collect();
        let cold: Vec<u32> = (500..540).collect();
        let mut aging = Aging { boost_tokens: 8 };
        // waited 0: reuse wins.
        let q = [item(0, 0, &sharer, 64, 0), item(1, 9, &cold, 0, 0)];
        assert_eq!(aging.rank_admission(&q, &[], 1), vec![0]);
        // waited 8 steps * 8 tokens = 64: ties at 64, sharer still first
        // (strict argmax keeps FCFS on ties). One more step wins.
        let q = [item(0, 0, &sharer, 64, 0), item(1, 9, &cold, 0, 9)];
        assert_eq!(aging.rank_admission(&q, &[], 1), vec![1], "aged cold request outranks");
    }

    #[test]
    fn drr_shares_slots_across_tenants_by_weight() {
        // Tenant 0 floods; tenant 1 trickles. Equal weights: admissions
        // alternate regardless of arrival counts.
        let p0: Vec<Vec<u32>> = (0..6).map(|i| vec![i as u32; 10]).collect();
        let p1: Vec<u32> = vec![77; 10];
        let mut items: Vec<QueueItem<'_>> = Vec::new();
        for (i, p) in p0.iter().enumerate() {
            items.push(item(i as u64, 0, p, 0, 0));
        }
        items.push(item(100, 1, &p1, 0, 0));
        let mut drr = Drr::new(64, &[]);
        let order = drr.rank_admission(&items, &[], 4);
        assert_eq!(order.len(), 4);
        assert!(
            order.contains(&100),
            "the minority tenant must get a slot within one round: {order:?}"
        );
        // FCFS within tenant 0.
        let t0: Vec<u64> = order.iter().copied().filter(|&id| id < 100).collect();
        let mut sorted = t0.clone();
        sorted.sort_unstable();
        assert_eq!(t0, sorted, "DRR keeps FCFS within a tenant");
    }

    #[test]
    fn drr_weights_skew_admission_rates() {
        // Tenant 0 at weight 3 vs tenant 1 at weight 1, equal-length
        // prompts: tenant 0 should take ~3x the slots over a long run.
        let prompts: Vec<Vec<u32>> = (0..40).map(|i| vec![i as u32; 16]).collect();
        let mut items: Vec<QueueItem<'_>> = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            items.push(item(i as u64, i % 2, p, 0, 0));
        }
        let mut drr = Drr::new(8, &[(0, 3)]);
        let order = drr.rank_admission(&items, &[], 16);
        let t0 = order.iter().filter(|&&id| id % 2 == 0).count();
        let t1 = order.len() - t0;
        assert!(
            t0 >= 2 * t1,
            "weight 3 tenant got {t0} slots vs {t1} for weight 1: {order:?}"
        );
    }

    #[test]
    fn drr_deficit_does_not_hoard_across_empty_queues() {
        let p: Vec<u32> = vec![1; 8];
        let mut drr = Drr::new(4, &[]);
        // Tenant 0 alone, needs 2 visits of quantum 4 for an 8-token prompt.
        let items = [item(0, 0, &p, 0, 0)];
        assert_eq!(drr.rank_admission(&items, &[], 1), vec![0]);
        // Queue empties; deficits forfeit. A later request pays full price
        // again (still admits — rank loops credit rounds — but the
        // deficit map holds nothing stale for tenant 0).
        assert!(drr.rank_admission(&[], &[], 1).is_empty());
        assert!(drr.deficits.is_empty(), "deficits forfeit when a tenant's queue drains");
    }

    #[test]
    fn planner_budget_split_conserves_the_step_budget() {
        use crate::workload::Request;
        let mk_active = |n: usize| -> Vec<ActiveSeq> {
            (0..n)
                .map(|i| ActiveSeq {
                    request: Request {
                        id: i as u64,
                        arrival_s: 0.0,
                        tenant: 0,
                        prompt: vec![1, 2, 3],
                        shared_tokens: 0,
                        max_new_tokens: 10,
                    },
                    generated: 0,
                    admitted_at: 0.0,
                })
                .collect()
        };
        let queue = std::collections::VecDeque::new();
        let prefilling = std::collections::VecDeque::new();
        let cached = |_: &Request| 0usize;
        for (batch, budget, over) in
            [(4usize, 24usize, false), (8, 8, true), (3, 4, true), (1, 2, false), (16, 8, false)]
        {
            let active = mk_active(batch);
            let mut planner = StepPlanner::new(PlannerConfig::default());
            let plan = planner.plan(&PlanInputs {
                queue: &queue,
                prefilling: &prefilling,
                active: &active,
                free_slots: 0,
                step_budget: Some(budget),
                retainer_over_budget: over,
                cached_match: &cached,
            });
            let evict = if plan.evict_tokens == usize::MAX { 0 } else { plan.evict_tokens };
            assert!(
                plan.decode_take + plan.prefill_budget.min(budget) + evict <= budget,
                "batch {batch} budget {budget}: take {} prefill {} evict {evict}",
                plan.decode_take,
                plan.prefill_budget
            );
            assert_eq!(plan.decode_skip.len(), batch - plan.decode_take);
            assert!(plan.decode_take >= 1.min(batch), "decode must make progress");
        }
    }

    #[test]
    fn decode_rotation_bounds_per_sequence_lag() {
        use crate::workload::Request;
        let active: Vec<ActiveSeq> = (0..6)
            .map(|i| ActiveSeq {
                request: Request {
                    id: i as u64,
                    arrival_s: 0.0,
                    tenant: 0,
                    prompt: vec![1],
                    shared_tokens: 0,
                    max_new_tokens: 100,
                },
                generated: 0,
                admitted_at: 0.0,
            })
            .collect();
        let mut planner = StepPlanner::new(PlannerConfig::default());
        // take=2 of batch=6 per step: every sequence must decode at least
        // once every ceil(6/2)=3 steps, so lag never exceeds 2.
        for _ in 0..30 {
            let skip = planner.rotate_decode(&active, 2);
            assert_eq!(skip.len(), 4);
        }
        assert!(
            planner.max_decode_lag() <= 2,
            "lag bound ceil(batch/take)-1 violated: {}",
            planner.max_decode_lag()
        );
    }

    #[test]
    fn tenant_counters_bound_cardinality() {
        let mut planner = StepPlanner::new(PlannerConfig {
            tenant_metrics_cap: 2,
            ..PlannerConfig::default()
        });
        for tenant in 0..10 {
            planner.note_decode_token(tenant);
        }
        let (tenants, overflow) = planner.tenant_counters();
        assert_eq!(tenants.len(), 2, "cardinality capped");
        assert_eq!(overflow.decode_tokens, 8, "excess tenants aggregate");
    }
}
