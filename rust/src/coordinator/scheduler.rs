//! Iteration-based (continuous) batching scheduler (§2.2).
//!
//! FCFS admission with a max-batch cap: new sequences join at iteration
//! boundaries, completed sequences leave immediately, so the decode batch
//! is re-formed every iteration — the Orca/vLLM discipline the paper
//! assumes ("ChunkAttention ... assumes that iteration-based batching is
//! enabled to form batches for its kernel to run efficiently").

use std::collections::VecDeque;

use crate::workload::Request;

/// A sequence currently being decoded.
#[derive(Debug, Clone)]
pub struct ActiveSeq {
    pub request: Request,
    /// Tokens generated so far.
    pub generated: usize,
    /// Virtual or wall time the request was admitted (prefill start).
    pub admitted_at: f64,
}

impl ActiveSeq {
    pub fn done(&self) -> bool {
        self.generated >= self.request.max_new_tokens
    }

    /// Current context length (prompt + generated).
    pub fn context_len(&self) -> usize {
        self.request.prompt.len() + self.generated
    }
}

/// A request that finished decoding, with its timing.
#[derive(Debug, Clone)]
pub struct FinishedSeq {
    pub request: Request,
    pub admitted_at: f64,
    pub finished_at: f64,
    /// End-to-end latency including queueing (finish - arrival).
    pub e2e_latency_s: f64,
}

impl FinishedSeq {
    /// The paper's normalized latency: end-to-end latency divided by
    /// completion tokens (ms/token).
    pub fn normalized_latency_ms_per_tok(&self) -> f64 {
        self.e2e_latency_s * 1e3 / self.request.max_new_tokens.max(1) as f64
    }
}

/// Where a removed (cancelled) sequence was found.
#[derive(Debug, Clone)]
pub enum Removed {
    /// Still waiting in the admission queue; never prefilled.
    Queued(Request),
    /// Mid-flight: was decoding when removed.
    Active(ActiveSeq),
}

/// FCFS continuous-batching scheduler.
pub struct Scheduler {
    queue: VecDeque<Request>,
    active: Vec<ActiveSeq>,
    finished: Vec<FinishedSeq>,
    max_batch: usize,
    peak_batch: usize,
    /// Admission-queue capacity; `None` = unbounded (offline traces).
    queue_limit: Option<usize>,
    /// Cap on the retained `finished` history; `None` = keep everything
    /// (offline traces and tests). The long-running gateway sets a bound so
    /// completed requests (with their cloned prompts) don't accumulate.
    finished_history_limit: Option<usize>,
    finished_total: u64,
    admission_rejections: u64,
}

impl Scheduler {
    pub fn new(max_batch: usize) -> Self {
        assert!(max_batch > 0);
        Scheduler {
            queue: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            max_batch,
            peak_batch: 0,
            queue_limit: None,
            finished_history_limit: None,
            finished_total: 0,
            admission_rejections: 0,
        }
    }

    /// Cap the admission queue; `try_submit` rejects beyond it.
    pub fn set_queue_limit(&mut self, limit: Option<usize>) {
        self.queue_limit = limit;
    }

    /// Bound the retained `finished` history (oldest entries are dropped).
    /// `finished_total` keeps the lifetime count either way.
    pub fn set_finished_history_limit(&mut self, limit: Option<usize>) {
        self.finished_history_limit = limit;
    }

    /// Lifetime count of retired sequences, independent of the history cap.
    pub fn finished_total(&self) -> u64 {
        self.finished_total
    }

    /// Enqueue a request that has arrived.
    pub fn submit(&mut self, request: Request) {
        self.queue.push_back(request);
    }

    /// Enqueue with admission control: rejects (and counts the rejection)
    /// when the queue is at its configured capacity. The serving gateway
    /// maps a rejection to HTTP 429 backpressure.
    pub fn try_submit(&mut self, request: Request) -> bool {
        if let Some(limit) = self.queue_limit {
            if self.queue.len() >= limit {
                self.admission_rejections += 1;
                return false;
            }
        }
        self.queue.push_back(request);
        true
    }

    /// Remove a sequence mid-flight (client cancellation), wherever it is.
    /// The removal never touches `finished` or `peak_batch`: a cancelled
    /// sequence is neither completed nor does it shrink the high-water
    /// mark. Returns `None` if the id is unknown (already finished).
    /// Cancellation accounting lives in one place — the engine's
    /// `MetricsRecorder::cancelled` — not here.
    pub fn remove(&mut self, id: u64) -> Option<Removed> {
        if let Some(pos) = self.queue.iter().position(|r| r.id == id) {
            return self.queue.remove(pos).map(Removed::Queued);
        }
        if let Some(pos) = self.active.iter().position(|s| s.request.id == id) {
            return Some(Removed::Active(self.active.remove(pos)));
        }
        None
    }

    /// Requests rejected by admission control (`try_submit`) so far.
    pub fn admission_rejections(&self) -> u64 {
        self.admission_rejections
    }

    /// Admit queued requests into free batch slots at time `now`; returns
    /// the newly admitted sequences (the engine must prefill them).
    pub fn admit(&mut self, now: f64) -> Vec<ActiveSeq> {
        let mut admitted = Vec::new();
        while self.active.len() + admitted.len() < self.max_batch {
            let Some(req) = self.queue.pop_front() else { break };
            admitted.push(ActiveSeq { request: req, generated: 0, admitted_at: now });
        }
        self.active.extend(admitted.iter().cloned());
        self.peak_batch = self.peak_batch.max(self.active.len());
        admitted
    }

    /// Credit `n` already-generated tokens to a sequence (the prefill step
    /// emits the first completion token before any decode iteration).
    pub fn credit_tokens(&mut self, id: u64, n: usize) {
        if let Some(s) = self.active.iter_mut().find(|s| s.request.id == id) {
            s.generated += n;
        }
    }

    /// Record one decoded token for every active sequence; retire the ones
    /// that reached their completion budget. Returns retired sequences.
    pub fn step_decode(&mut self, now: f64) -> Vec<FinishedSeq> {
        for s in &mut self.active {
            s.generated += 1;
        }
        self.retire_finished(now)
    }

    /// Retire sequences whose budget is already met (used after prefill
    /// crediting and by `step_decode`).
    pub fn retire_finished(&mut self, now: f64) -> Vec<FinishedSeq> {
        let mut retired = Vec::new();
        self.active.retain(|s| {
            if s.done() {
                retired.push(FinishedSeq {
                    e2e_latency_s: now - s.request.arrival_s,
                    admitted_at: s.admitted_at,
                    finished_at: now,
                    request: s.request.clone(),
                });
                false
            } else {
                true
            }
        });
        self.finished_total += retired.len() as u64;
        self.finished.extend(retired.iter().cloned());
        if let Some(limit) = self.finished_history_limit {
            // Amortized O(1): let the history reach 2x before trimming.
            if self.finished.len() >= 2 * limit.max(1) {
                let excess = self.finished.len() - limit.max(1);
                self.finished.drain(..excess);
            }
        }
        retired
    }

    pub fn active(&self) -> &[ActiveSeq] {
        &self.active
    }

    pub fn batch_size(&self) -> usize {
        self.active.len()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn peak_batch(&self) -> usize {
        self.peak_batch
    }

    pub fn finished(&self) -> &[FinishedSeq] {
        &self.finished
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64, prompt_len: usize, completion: usize) -> Request {
        Request {
            id,
            arrival_s: arrival,
            tenant: 0,
            prompt: (0..prompt_len as u32).collect(),
            shared_tokens: 0,
            max_new_tokens: completion,
        }
    }

    #[test]
    fn admits_up_to_max_batch() {
        let mut s = Scheduler::new(2);
        for i in 0..5 {
            s.submit(req(i, 0.0, 8, 4));
        }
        let admitted = s.admit(0.0);
        assert_eq!(admitted.len(), 2);
        assert_eq!(s.batch_size(), 2);
        assert_eq!(s.queued(), 3);
    }

    #[test]
    fn continuous_batching_joins_mid_flight() {
        let mut s = Scheduler::new(2);
        s.submit(req(0, 0.0, 8, 1));
        s.submit(req(1, 0.0, 8, 3));
        s.submit(req(2, 0.0, 8, 2));
        s.admit(0.0);
        // Iteration 1: request 0 finishes, slot opens.
        let retired = s.step_decode(0.1);
        assert_eq!(retired.len(), 1);
        assert_eq!(retired[0].request.id, 0);
        let admitted = s.admit(0.1);
        assert_eq!(admitted.len(), 1);
        assert_eq!(admitted[0].request.id, 2);
        assert_eq!(s.batch_size(), 2);
    }

    #[test]
    fn normalized_latency_counts_queueing() {
        let mut s = Scheduler::new(1);
        s.submit(req(0, 0.0, 4, 2));
        s.submit(req(1, 0.0, 4, 2)); // queued behind
        s.admit(0.0);
        s.step_decode(1.0);
        let done = s.step_decode(2.0);
        assert_eq!(done.len(), 1);
        assert!((done[0].e2e_latency_s - 2.0).abs() < 1e-9);
        assert!((done[0].normalized_latency_ms_per_tok() - 1000.0).abs() < 1e-6);
        s.admit(2.0);
        s.step_decode(3.0);
        let done = s.step_decode(4.0);
        // Request 1 waited 2s in queue: e2e = 4s over 2 tokens.
        assert!((done[0].normalized_latency_ms_per_tok() - 2000.0).abs() < 1e-6);
    }

    #[test]
    fn finished_history_is_bounded_when_capped() {
        let mut s = Scheduler::new(4);
        s.set_finished_history_limit(Some(2));
        for i in 0..6 {
            s.submit(req(i, 0.0, 4, 1));
        }
        while !s.is_idle() {
            s.admit(0.0);
            s.step_decode(0.1);
        }
        assert_eq!(s.finished_total(), 6, "lifetime count survives the cap");
        assert_eq!(s.finished().len(), 2, "history bounded");
        assert_eq!(s.finished()[1].request.id, 5, "newest entries retained");
    }

    #[test]
    fn queue_limit_rejects_and_counts() {
        let mut s = Scheduler::new(1);
        s.set_queue_limit(Some(2));
        assert!(s.try_submit(req(0, 0.0, 4, 8)));
        assert!(s.try_submit(req(1, 0.0, 4, 8)));
        assert!(!s.try_submit(req(2, 0.0, 4, 8)), "third submit exceeds the cap");
        assert_eq!(s.admission_rejections(), 1);
        assert_eq!(s.queued(), 2);
        // Admission drains the queue; capacity frees up again.
        s.admit(0.0);
        assert!(s.try_submit(req(3, 0.0, 4, 8)));
        assert_eq!(s.admission_rejections(), 1);
    }

    #[test]
    fn remove_queued_and_active_without_finishing_them() {
        let mut s = Scheduler::new(2);
        for i in 0..4 {
            s.submit(req(i, 0.0, 4, 8));
        }
        s.admit(0.0); // 0,1 active; 2,3 queued
        assert_eq!(s.peak_batch(), 2);
        match s.remove(2) {
            Some(Removed::Queued(r)) => assert_eq!(r.id, 2),
            other => panic!("expected queued removal, got {other:?}"),
        }
        match s.remove(0) {
            Some(Removed::Active(a)) => assert_eq!(a.request.id, 0),
            other => panic!("expected active removal, got {other:?}"),
        }
        assert!(s.remove(0).is_none(), "double-cancel is a no-op");
        assert_eq!(s.batch_size(), 1);
        // The freed slot admits the remaining queued request.
        let admitted = s.admit(0.1);
        assert_eq!(admitted.len(), 1);
        assert_eq!(admitted[0].request.id, 3);
        // Run everything to completion: cancelled ids never reach finished.
        for _ in 0..8 {
            s.step_decode(0.2);
        }
        let done: Vec<u64> = s.finished().iter().map(|f| f.request.id).collect();
        assert_eq!(done, vec![1, 3]);
        assert_eq!(s.peak_batch(), 2, "cancellation must not corrupt the high-water mark");
        assert!(s.is_idle());
    }

    #[test]
    fn peak_batch_tracked() {
        let mut s = Scheduler::new(8);
        for i in 0..5 {
            s.submit(req(i, 0.0, 4, 1));
        }
        s.admit(0.0);
        assert_eq!(s.peak_batch(), 5);
        s.step_decode(0.1);
        assert_eq!(s.batch_size(), 0);
        assert_eq!(s.peak_batch(), 5);
        assert!(s.is_idle());
    }
}
