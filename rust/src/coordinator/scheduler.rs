//! Iteration-based (continuous) batching scheduler (§2.2) with chunked,
//! prefix-aware prefill.
//!
//! Admission with a max-batch cap: new sequences join at iteration
//! boundaries, completed sequences leave immediately, so the decode batch
//! is re-formed every iteration — the Orca/vLLM discipline the paper
//! assumes ("ChunkAttention ... assumes that iteration-based batching is
//! enabled to form batches for its kernel to run efficiently").
//!
//! Two refinements over plain FCFS admission:
//!
//! - **Chunked prefill.** An admitted request does not prefill its whole
//!   unmatched prompt suffix inline; it sits in a *prefill queue*
//!   ([`PrefillingSeq`]) and the engine advances it in chunk-sized slices
//!   under a per-step token budget ([`Scheduler::set_chunked_prefill`]),
//!   so one 4096-token cold prompt can no longer stall every in-flight
//!   decoder (head-of-line blocking, §3.2 regime).
//! - **Prefix-aware admission.** Free batch slots go to the queued
//!   requests sharing the longest prefix with content already resident —
//!   cached in the tree or mid-prefill — so sibling prefills become pure
//!   reuse instead of repeated work (the Prompt Cache observation).

use std::collections::VecDeque;

use super::planner::{rank_prefix_greedy, QueueItem};
use crate::workload::Request;

/// A sequence currently being decoded.
#[derive(Debug, Clone)]
pub struct ActiveSeq {
    pub request: Request,
    /// Tokens generated so far.
    pub generated: usize,
    /// Virtual or wall time the request was admitted (prefill start).
    pub admitted_at: f64,
}

impl ActiveSeq {
    pub fn done(&self) -> bool {
        self.generated >= self.request.max_new_tokens
    }

    /// Current context length (prompt + generated).
    pub fn context_len(&self) -> usize {
        self.request.prompt.len() + self.generated
    }
}

/// A request admitted into the batch whose prompt is still prefilling.
/// Once its first slice lands it is a first-class resident of the prefix
/// tree: later arrivals match against its partial content.
#[derive(Debug, Clone)]
pub struct PrefillingSeq {
    pub request: Request,
    pub admitted_at: f64,
    /// Prompt tokens already resident in the tree (reused + computed).
    pub filled: usize,
    /// Prompt tokens served from the prefix tree at the first slice.
    pub reused: usize,
    /// Whether this request has (ever) deferred its first slice to an
    /// in-progress leader — tracked so the deferral counter counts
    /// requests, not polling iterations.
    pub deferred: bool,
}

impl PrefillingSeq {
    /// Prompt tokens not yet resident.
    pub fn remaining(&self) -> usize {
        self.request.prompt.len() - self.filled
    }
}

/// A request that finished decoding, with its timing.
#[derive(Debug, Clone)]
pub struct FinishedSeq {
    pub request: Request,
    pub admitted_at: f64,
    pub finished_at: f64,
    /// End-to-end latency including queueing (finish - arrival).
    pub e2e_latency_s: f64,
    /// Completion tokens actually generated. Usually equals
    /// `request.max_new_tokens`, but early-finished sequences (stop
    /// conditions, multi-token crediting) can retire with a different
    /// count — latency must be normalized by what was really produced.
    pub generated: usize,
}

impl FinishedSeq {
    /// The paper's normalized latency: end-to-end latency divided by the
    /// completion tokens actually generated (ms/token) — not the request's
    /// budget, which would understate the cost of early-finished requests.
    pub fn normalized_latency_ms_per_tok(&self) -> f64 {
        self.e2e_latency_s * 1e3 / self.generated.max(1) as f64
    }
}

/// Where a removed (cancelled) sequence was found.
#[derive(Debug, Clone)]
pub enum Removed {
    /// Still waiting in the admission queue; never prefilled.
    Queued(Request),
    /// Admitted but mid-prefill: holds tree residency iff `filled > 0`.
    Prefilling(PrefillingSeq),
    /// Mid-flight: was decoding when removed.
    Active(ActiveSeq),
}

/// Continuous-batching scheduler (FCFS queue, prefix-aware admission).
pub struct Scheduler {
    queue: VecDeque<Request>,
    /// Admitted requests whose prompts are still prefilling, in admission
    /// order (the engine round-robins budget slices across them).
    prefilling: VecDeque<PrefillingSeq>,
    active: Vec<ActiveSeq>,
    finished: Vec<FinishedSeq>,
    max_batch: usize,
    peak_batch: usize,
    /// Admission-queue capacity; `None` = unbounded (offline traces).
    queue_limit: Option<usize>,
    /// Cap on the retained `finished` history; `None` = keep everything
    /// (offline traces and tests). The long-running gateway sets a bound so
    /// completed requests (with their cloned prompts) don't accumulate.
    finished_history_limit: Option<usize>,
    finished_total: u64,
    admission_rejections: u64,
    /// Prefill slice granularity in tokens (`usize::MAX` = monolithic).
    prefill_chunk_tokens: usize,
    /// Per-step token budget across prefill slices and decode tokens;
    /// `None` = unbounded (monolithic prefill behavior).
    step_token_budget: Option<usize>,
}

impl Scheduler {
    pub fn new(max_batch: usize) -> Self {
        assert!(max_batch > 0);
        Scheduler {
            queue: VecDeque::new(),
            prefilling: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            max_batch,
            peak_batch: 0,
            queue_limit: None,
            finished_history_limit: None,
            finished_total: 0,
            admission_rejections: 0,
            prefill_chunk_tokens: usize::MAX,
            step_token_budget: None,
        }
    }

    /// Cap the admission queue; `try_submit` rejects beyond it.
    pub fn set_queue_limit(&mut self, limit: Option<usize>) {
        self.queue_limit = limit;
    }

    /// Configure chunked prefill: unmatched prompt suffixes advance in
    /// `chunk_tokens`-sized slices, and each engine step spends at most
    /// `step_budget` tokens across prefill slices and decode tokens.
    /// Either knob set to 0 disables it (monolithic prefill / no budget).
    pub fn set_chunked_prefill(&mut self, chunk_tokens: usize, step_budget: usize) {
        self.prefill_chunk_tokens = if chunk_tokens == 0 { usize::MAX } else { chunk_tokens };
        // A budget of 1 could never complete any prompt: the final slice
        // must fit one computed token plus the reserved decode token, so
        // the engine would spin forever without progress. Clamp to the
        // minimum viable budget.
        let step_budget = if step_budget == 1 { 2 } else { step_budget };
        self.step_token_budget = if step_budget == 0 { None } else { Some(step_budget) };
        if let Some(b) = self.step_token_budget {
            if b <= self.max_batch {
                log::warn!(
                    "step token budget {b} <= max batch {}: a full decode batch leaves no \
                     headroom for prefill progress",
                    self.max_batch
                );
            }
        }
    }

    pub fn step_token_budget(&self) -> Option<usize> {
        self.step_token_budget
    }

    pub fn prefill_chunk_tokens(&self) -> usize {
        self.prefill_chunk_tokens
    }

    /// Bound the retained `finished` history (oldest entries are dropped).
    /// `finished_total` keeps the lifetime count either way.
    pub fn set_finished_history_limit(&mut self, limit: Option<usize>) {
        self.finished_history_limit = limit;
    }

    /// Lifetime count of retired sequences, independent of the history cap.
    pub fn finished_total(&self) -> u64 {
        self.finished_total
    }

    /// Enqueue a request that has arrived.
    pub fn submit(&mut self, request: Request) {
        self.queue.push_back(request);
    }

    /// Enqueue with admission control: rejects (and counts the rejection)
    /// when the queue is at its configured capacity. The serving gateway
    /// maps a rejection to HTTP 429 backpressure.
    pub fn try_submit(&mut self, request: Request) -> bool {
        if let Some(limit) = self.queue_limit {
            if self.queue.len() >= limit {
                self.admission_rejections += 1;
                return false;
            }
        }
        self.queue.push_back(request);
        true
    }

    /// Remove a sequence mid-flight (client cancellation), wherever it is.
    /// The removal never touches `finished` or `peak_batch`: a cancelled
    /// sequence is neither completed nor does it shrink the high-water
    /// mark. Returns `None` if the id is unknown (already finished).
    /// Cancellation accounting lives in one place — the engine's
    /// `MetricsRecorder::cancelled` — not here.
    pub fn remove(&mut self, id: u64) -> Option<Removed> {
        if let Some(pos) = self.queue.iter().position(|r| r.id == id) {
            return self.queue.remove(pos).map(Removed::Queued);
        }
        if let Some(pos) = self.prefilling.iter().position(|p| p.request.id == id) {
            return self.prefilling.remove(pos).map(Removed::Prefilling);
        }
        if let Some(pos) = self.active.iter().position(|s| s.request.id == id) {
            return Some(Removed::Active(self.active.remove(pos)));
        }
        None
    }

    /// Requests rejected by admission control (`try_submit`) so far.
    pub fn admission_rejections(&self) -> u64 {
        self.admission_rejections
    }

    /// Admit queued requests straight into decode slots at time `now`,
    /// FCFS; returns the newly admitted sequences (the caller prefills
    /// them inline). Used by the virtual-time simulator, which models
    /// prefill cost itself; the engine admits via
    /// [`Scheduler::admit_prefilling`] instead.
    pub fn admit(&mut self, now: f64) -> Vec<ActiveSeq> {
        let mut admitted = Vec::new();
        while self.active.len() + admitted.len() < self.max_batch {
            let Some(req) = self.queue.pop_front() else { break };
            admitted.push(ActiveSeq { request: req, generated: 0, admitted_at: now });
        }
        self.active.extend(admitted.iter().cloned());
        self.peak_batch = self.peak_batch.max(self.active.len());
        admitted
    }

    /// Free slots available for admission into the prefill queue.
    pub fn free_slots(&self) -> usize {
        self.max_batch.saturating_sub(self.active.len() + self.prefilling.len())
    }

    /// The admission queue, in arrival order (planner input).
    pub fn queue(&self) -> &VecDeque<Request> {
        &self.queue
    }

    /// Admit queued requests into free batch slots as *prefilling*
    /// residents at time `now`. Prefix-aware: each free slot goes to the
    /// queued request sharing the longest prefix with resident content —
    /// `cached_match` scores against the prefix tree, and requests already
    /// prefilling contribute their (future) prompt content — with FCFS
    /// order breaking ties. Grouping prefix-sharing requests this way
    /// turns sibling prefills into cache hits. Returns how many admitted.
    ///
    /// This is the historical entry point; it delegates to the planner's
    /// `prefix-greedy` ranking ([`rank_prefix_greedy`]) so the two cannot
    /// drift apart. The engine plans admission itself (any policy) and
    /// applies it with [`Scheduler::admit_prefilling_ids`].
    pub fn admit_prefilling<F: Fn(&Request) -> usize>(&mut self, now: f64, cached_match: F) -> usize {
        let slots = self.free_slots();
        if slots == 0 || self.queue.is_empty() {
            return 0;
        }
        let items: Vec<QueueItem<'_>> = self
            .queue
            .iter()
            .map(|r| QueueItem {
                id: r.id,
                tenant: r.tenant,
                prompt: &r.prompt,
                cached: cached_match(r),
                waited_steps: 0,
            })
            .collect();
        let prefilling: Vec<&[u32]> =
            self.prefilling.iter().map(|p| p.request.prompt.as_slice()).collect();
        let ids = rank_prefix_greedy(&items, &prefilling, slots);
        drop(items);
        drop(prefilling);
        self.admit_prefilling_ids(&ids, now)
    }

    /// Admit specific queued requests (by id, in the given order) into the
    /// prefill queue — the planner's admission plan applied. Ids not found
    /// in the queue are skipped (cancelled between plan and apply);
    /// admission stops when the batch is full. Returns how many admitted.
    pub fn admit_prefilling_ids(&mut self, ids: &[u64], now: f64) -> usize {
        let mut admitted = 0usize;
        for &id in ids {
            if self.free_slots() == 0 {
                break;
            }
            let Some(pos) = self.queue.iter().position(|r| r.id == id) else { continue };
            let req = self.queue.remove(pos).expect("position just found");
            self.prefilling.push_back(PrefillingSeq {
                request: req,
                admitted_at: now,
                filled: 0,
                reused: 0,
                deferred: false,
            });
            admitted += 1;
        }
        admitted
    }

    /// Admit specific queued requests (by id, in order) straight into
    /// decode slots — the virtual-time simulator's policy-ranked variant
    /// of [`Scheduler::admit`] (prefill cost is modeled by the caller).
    pub fn admit_ids(&mut self, ids: &[u64], now: f64) -> Vec<ActiveSeq> {
        let mut admitted = Vec::new();
        for &id in ids {
            if self.active.len() + admitted.len() >= self.max_batch {
                break;
            }
            let Some(pos) = self.queue.iter().position(|r| r.id == id) else { continue };
            let req = self.queue.remove(pos).expect("position just found");
            admitted.push(ActiveSeq { request: req, generated: 0, admitted_at: now });
        }
        self.active.extend(admitted.iter().cloned());
        self.peak_batch = self.peak_batch.max(self.active.len());
        admitted
    }

    /// Detach the prefill queue so the engine can advance slices without
    /// borrowing the scheduler; pair with [`Scheduler::put_back_prefilling`].
    pub fn take_prefilling(&mut self) -> VecDeque<PrefillingSeq> {
        std::mem::take(&mut self.prefilling)
    }

    /// Restore the (possibly shrunk) prefill queue after a prefill phase.
    pub fn put_back_prefilling(&mut self, pending: VecDeque<PrefillingSeq>) {
        debug_assert!(self.prefilling.is_empty(), "prefill queue restored twice");
        self.prefilling = pending;
    }

    /// Promote a fully prefilled request into the decode batch.
    pub fn activate(&mut self, pf: PrefillingSeq) {
        debug_assert_eq!(pf.remaining(), 0, "activating a partially prefilled prompt");
        self.active.push(ActiveSeq {
            request: pf.request,
            generated: 0,
            admitted_at: pf.admitted_at,
        });
        self.peak_batch = self.peak_batch.max(self.active.len());
    }

    /// Requests admitted but still prefilling (the prefill queue depth).
    pub fn prefill_depth(&self) -> usize {
        self.prefilling.len()
    }

    pub fn prefilling(&self) -> &VecDeque<PrefillingSeq> {
        &self.prefilling
    }

    /// Whether `id` is admitted and still prefilling (a partial resident).
    pub fn is_prefilling(&self, id: u64) -> bool {
        self.prefilling.iter().any(|p| p.request.id == id)
    }

    /// Credit `n` already-generated tokens to a sequence (the prefill step
    /// emits the first completion token before any decode iteration).
    pub fn credit_tokens(&mut self, id: u64, n: usize) {
        if let Some(s) = self.active.iter_mut().find(|s| s.request.id == id) {
            s.generated += n;
        }
    }

    /// Record one decoded token for every active sequence; retire the ones
    /// that reached their completion budget. Returns retired sequences.
    pub fn step_decode(&mut self, now: f64) -> Vec<FinishedSeq> {
        self.step_decode_skipping(&[], now)
    }

    /// Like [`Scheduler::step_decode`], but sequences named in `skip`
    /// sat this decode step out (budget-aware partial decode batches) and
    /// generate nothing.
    pub fn step_decode_skipping(&mut self, skip: &[u64], now: f64) -> Vec<FinishedSeq> {
        for s in &mut self.active {
            if !skip.contains(&s.request.id) {
                s.generated += 1;
            }
        }
        self.retire_finished(now)
    }

    /// Retire sequences whose budget is already met (used after prefill
    /// crediting and by `step_decode`).
    pub fn retire_finished(&mut self, now: f64) -> Vec<FinishedSeq> {
        let mut retired = Vec::new();
        self.active.retain(|s| {
            if s.done() {
                retired.push(FinishedSeq {
                    e2e_latency_s: now - s.request.arrival_s,
                    admitted_at: s.admitted_at,
                    finished_at: now,
                    generated: s.generated,
                    request: s.request.clone(),
                });
                false
            } else {
                true
            }
        });
        self.finished_total += retired.len() as u64;
        self.finished.extend(retired.iter().cloned());
        if let Some(limit) = self.finished_history_limit {
            // Amortized O(1): let the history reach 2x before trimming.
            if self.finished.len() >= 2 * limit.max(1) {
                let excess = self.finished.len() - limit.max(1);
                self.finished.drain(..excess);
            }
        }
        retired
    }

    pub fn active(&self) -> &[ActiveSeq] {
        &self.active
    }

    pub fn batch_size(&self) -> usize {
        self.active.len()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn peak_batch(&self) -> usize {
        self.peak_batch
    }

    pub fn finished(&self) -> &[FinishedSeq] {
        &self.finished
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.prefilling.is_empty() && self.active.is_empty()
    }

    /// Drop every queued, prefilling, and active entry, returning their
    /// request ids. Crash recovery's last-resort full-reset path: caps,
    /// counters, policy, and finished history all survive so the rebuilt
    /// engine keeps serving with the same configuration.
    pub fn clear_inflight(&mut self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.queue.drain(..).map(|r| r.id).collect();
        ids.extend(self.prefilling.drain(..).map(|p| p.request.id));
        ids.extend(self.active.drain(..).map(|s| s.request.id));
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::tree::common_prefix;

    fn req(id: u64, arrival: f64, prompt_len: usize, completion: usize) -> Request {
        Request {
            id,
            arrival_s: arrival,
            tenant: 0,
            prompt: (0..prompt_len as u32).collect(),
            shared_tokens: 0,
            max_new_tokens: completion,
        }
    }

    #[test]
    fn admits_up_to_max_batch() {
        let mut s = Scheduler::new(2);
        for i in 0..5 {
            s.submit(req(i, 0.0, 8, 4));
        }
        let admitted = s.admit(0.0);
        assert_eq!(admitted.len(), 2);
        assert_eq!(s.batch_size(), 2);
        assert_eq!(s.queued(), 3);
    }

    #[test]
    fn continuous_batching_joins_mid_flight() {
        let mut s = Scheduler::new(2);
        s.submit(req(0, 0.0, 8, 1));
        s.submit(req(1, 0.0, 8, 3));
        s.submit(req(2, 0.0, 8, 2));
        s.admit(0.0);
        // Iteration 1: request 0 finishes, slot opens.
        let retired = s.step_decode(0.1);
        assert_eq!(retired.len(), 1);
        assert_eq!(retired[0].request.id, 0);
        let admitted = s.admit(0.1);
        assert_eq!(admitted.len(), 1);
        assert_eq!(admitted[0].request.id, 2);
        assert_eq!(s.batch_size(), 2);
    }

    #[test]
    fn normalized_latency_counts_queueing() {
        let mut s = Scheduler::new(1);
        s.submit(req(0, 0.0, 4, 2));
        s.submit(req(1, 0.0, 4, 2)); // queued behind
        s.admit(0.0);
        s.step_decode(1.0);
        let done = s.step_decode(2.0);
        assert_eq!(done.len(), 1);
        assert!((done[0].e2e_latency_s - 2.0).abs() < 1e-9);
        assert!((done[0].normalized_latency_ms_per_tok() - 1000.0).abs() < 1e-6);
        s.admit(2.0);
        s.step_decode(3.0);
        let done = s.step_decode(4.0);
        // Request 1 waited 2s in queue: e2e = 4s over 2 tokens.
        assert!((done[0].normalized_latency_ms_per_tok() - 2000.0).abs() < 1e-6);
    }

    #[test]
    fn normalized_latency_divides_by_actual_completion_length() {
        // Regression: the old implementation divided by
        // `request.max_new_tokens`, so a sequence retiring with a different
        // generated count (multi-token crediting today; stop tokens /
        // cancellation paths tomorrow) reported the wrong per-token cost.
        let mut s = Scheduler::new(1);
        s.submit(req(0, 0.0, 4, 10));
        s.admit(0.0);
        // A runner that credits several tokens at once (prefill emits one,
        // speculative decoding emits more) retires past the budget.
        s.credit_tokens(0, 12);
        let done = s.retire_finished(2.0);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].generated, 12, "actual completion length recorded");
        let want = 2.0 * 1e3 / 12.0;
        assert!(
            (done[0].normalized_latency_ms_per_tok() - want).abs() < 1e-9,
            "normalized latency must divide by generated tokens (12), not the budget (10): {}",
            done[0].normalized_latency_ms_per_tok()
        );
    }

    #[test]
    fn finished_history_is_bounded_when_capped() {
        let mut s = Scheduler::new(4);
        s.set_finished_history_limit(Some(2));
        for i in 0..6 {
            s.submit(req(i, 0.0, 4, 1));
        }
        while !s.is_idle() {
            s.admit(0.0);
            s.step_decode(0.1);
        }
        assert_eq!(s.finished_total(), 6, "lifetime count survives the cap");
        assert_eq!(s.finished().len(), 2, "history bounded");
        assert_eq!(s.finished()[1].request.id, 5, "newest entries retained");
    }

    #[test]
    fn queue_limit_rejects_and_counts() {
        let mut s = Scheduler::new(1);
        s.set_queue_limit(Some(2));
        assert!(s.try_submit(req(0, 0.0, 4, 8)));
        assert!(s.try_submit(req(1, 0.0, 4, 8)));
        assert!(!s.try_submit(req(2, 0.0, 4, 8)), "third submit exceeds the cap");
        assert_eq!(s.admission_rejections(), 1);
        assert_eq!(s.queued(), 2);
        // Admission drains the queue; capacity frees up again.
        s.admit(0.0);
        assert!(s.try_submit(req(3, 0.0, 4, 8)));
        assert_eq!(s.admission_rejections(), 1);
    }

    #[test]
    fn remove_queued_and_active_without_finishing_them() {
        let mut s = Scheduler::new(2);
        for i in 0..4 {
            s.submit(req(i, 0.0, 4, 8));
        }
        s.admit(0.0); // 0,1 active; 2,3 queued
        assert_eq!(s.peak_batch(), 2);
        match s.remove(2) {
            Some(Removed::Queued(r)) => assert_eq!(r.id, 2),
            other => panic!("expected queued removal, got {other:?}"),
        }
        match s.remove(0) {
            Some(Removed::Active(a)) => assert_eq!(a.request.id, 0),
            other => panic!("expected active removal, got {other:?}"),
        }
        assert!(s.remove(0).is_none(), "double-cancel is a no-op");
        assert_eq!(s.batch_size(), 1);
        // The freed slot admits the remaining queued request.
        let admitted = s.admit(0.1);
        assert_eq!(admitted.len(), 1);
        assert_eq!(admitted[0].request.id, 3);
        // Run everything to completion: cancelled ids never reach finished.
        for _ in 0..8 {
            s.step_decode(0.2);
        }
        let done: Vec<u64> = s.finished().iter().map(|f| f.request.id).collect();
        assert_eq!(done, vec![1, 3]);
        assert_eq!(s.peak_batch(), 2, "cancellation must not corrupt the high-water mark");
        assert!(s.is_idle());
    }

    #[test]
    fn remove_prefilling_sequence() {
        let mut s = Scheduler::new(2);
        s.submit(req(0, 0.0, 64, 4));
        s.admit_prefilling(0.0, |_| 0);
        assert_eq!(s.prefill_depth(), 1);
        assert!(s.is_prefilling(0));
        assert!(!s.is_idle());
        match s.remove(0) {
            Some(Removed::Prefilling(p)) => {
                assert_eq!(p.request.id, 0);
                assert_eq!(p.filled, 0);
            }
            other => panic!("expected prefilling removal, got {other:?}"),
        }
        assert_eq!(s.prefill_depth(), 0);
        assert!(s.is_idle());
    }

    #[test]
    fn prefix_aware_admission_groups_sharers() {
        // Queue: [cold A, sharer B of resident prefix, sharer C of B].
        // One slot frees at a time; B (longest resident match) must admit
        // before A despite FCFS order, and C groups with B.
        let mut s = Scheduler::new(1);
        let cold = Request { prompt: (500..540).collect(), ..req(10, 0.0, 0, 4) };
        let sharer_b = Request { prompt: (0..40).collect(), ..req(11, 0.0, 0, 4) };
        let sharer_c = Request { prompt: (0..48).collect(), ..req(12, 0.0, 0, 4) };
        s.submit(cold);
        s.submit(sharer_b);
        s.submit(sharer_c);
        // Pretend the tree holds a 32-token cached prefix of B/C's prompt.
        let cached = |r: &Request| common_prefix(&r.prompt, &(0..32).collect::<Vec<u32>>());
        assert_eq!(s.admit_prefilling(0.0, cached), 1);
        assert_eq!(s.prefilling()[0].request.id, 11, "longest cached match first");
        // B is mid-prefill: C now scores by its shared prefix with B (40)
        // and still beats the cold request.
        let mut pf = s.take_prefilling();
        pf[0].filled = 8;
        s.put_back_prefilling(pf);
        // Free the slot math by raising the cap.
        s.max_batch = 2;
        assert_eq!(s.admit_prefilling(0.1, cached), 1);
        assert_eq!(s.prefilling()[1].request.id, 12, "sibling groups with the in-progress leader");
        assert_eq!(s.queued(), 1, "cold request waits");
    }

    #[test]
    fn activate_promotes_prefilled_requests_into_the_batch() {
        let mut s = Scheduler::new(2);
        s.submit(req(0, 0.0, 16, 3));
        s.admit_prefilling(0.0, |_| 0);
        let mut pending = s.take_prefilling();
        let mut pf = pending.pop_front().unwrap();
        pf.filled = pf.request.prompt.len();
        s.put_back_prefilling(pending);
        s.activate(pf);
        assert_eq!(s.batch_size(), 1);
        assert_eq!(s.prefill_depth(), 0);
        assert_eq!(s.peak_batch(), 1);
        s.credit_tokens(0, 1);
        s.step_decode(0.1);
        let done = s.step_decode(0.2);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].generated, 3);
    }

    #[test]
    fn peak_batch_tracked() {
        let mut s = Scheduler::new(8);
        for i in 0..5 {
            s.submit(req(i, 0.0, 4, 1));
        }
        s.admit(0.0);
        assert_eq!(s.peak_batch(), 5);
        s.step_decode(0.1);
        assert_eq!(s.batch_size(), 0);
        assert_eq!(s.peak_batch(), 5);
        assert!(s.is_idle());
    }
}
