//! Iteration-based (continuous) batching scheduler (§2.2).
//!
//! FCFS admission with a max-batch cap: new sequences join at iteration
//! boundaries, completed sequences leave immediately, so the decode batch
//! is re-formed every iteration — the Orca/vLLM discipline the paper
//! assumes ("ChunkAttention ... assumes that iteration-based batching is
//! enabled to form batches for its kernel to run efficiently").

use std::collections::VecDeque;

use crate::workload::Request;

/// A sequence currently being decoded.
#[derive(Debug, Clone)]
pub struct ActiveSeq {
    pub request: Request,
    /// Tokens generated so far.
    pub generated: usize,
    /// Virtual or wall time the request was admitted (prefill start).
    pub admitted_at: f64,
}

impl ActiveSeq {
    pub fn done(&self) -> bool {
        self.generated >= self.request.max_new_tokens
    }

    /// Current context length (prompt + generated).
    pub fn context_len(&self) -> usize {
        self.request.prompt.len() + self.generated
    }
}

/// A request that finished decoding, with its timing.
#[derive(Debug, Clone)]
pub struct FinishedSeq {
    pub request: Request,
    pub admitted_at: f64,
    pub finished_at: f64,
    /// End-to-end latency including queueing (finish - arrival).
    pub e2e_latency_s: f64,
}

impl FinishedSeq {
    /// The paper's normalized latency: end-to-end latency divided by
    /// completion tokens (ms/token).
    pub fn normalized_latency_ms_per_tok(&self) -> f64 {
        self.e2e_latency_s * 1e3 / self.request.max_new_tokens.max(1) as f64
    }
}

/// FCFS continuous-batching scheduler.
pub struct Scheduler {
    queue: VecDeque<Request>,
    active: Vec<ActiveSeq>,
    finished: Vec<FinishedSeq>,
    max_batch: usize,
    peak_batch: usize,
}

impl Scheduler {
    pub fn new(max_batch: usize) -> Self {
        assert!(max_batch > 0);
        Scheduler {
            queue: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            max_batch,
            peak_batch: 0,
        }
    }

    /// Enqueue a request that has arrived.
    pub fn submit(&mut self, request: Request) {
        self.queue.push_back(request);
    }

    /// Admit queued requests into free batch slots at time `now`; returns
    /// the newly admitted sequences (the engine must prefill them).
    pub fn admit(&mut self, now: f64) -> Vec<ActiveSeq> {
        let mut admitted = Vec::new();
        while self.active.len() + admitted.len() < self.max_batch {
            let Some(req) = self.queue.pop_front() else { break };
            admitted.push(ActiveSeq { request: req, generated: 0, admitted_at: now });
        }
        self.active.extend(admitted.iter().cloned());
        self.peak_batch = self.peak_batch.max(self.active.len());
        admitted
    }

    /// Credit `n` already-generated tokens to a sequence (the prefill step
    /// emits the first completion token before any decode iteration).
    pub fn credit_tokens(&mut self, id: u64, n: usize) {
        if let Some(s) = self.active.iter_mut().find(|s| s.request.id == id) {
            s.generated += n;
        }
    }

    /// Record one decoded token for every active sequence; retire the ones
    /// that reached their completion budget. Returns retired sequences.
    pub fn step_decode(&mut self, now: f64) -> Vec<FinishedSeq> {
        for s in &mut self.active {
            s.generated += 1;
        }
        self.retire_finished(now)
    }

    /// Retire sequences whose budget is already met (used after prefill
    /// crediting and by `step_decode`).
    pub fn retire_finished(&mut self, now: f64) -> Vec<FinishedSeq> {
        let mut retired = Vec::new();
        self.active.retain(|s| {
            if s.done() {
                retired.push(FinishedSeq {
                    e2e_latency_s: now - s.request.arrival_s,
                    admitted_at: s.admitted_at,
                    finished_at: now,
                    request: s.request.clone(),
                });
                false
            } else {
                true
            }
        });
        self.finished.extend(retired.iter().cloned());
        retired
    }

    pub fn active(&self) -> &[ActiveSeq] {
        &self.active
    }

    pub fn batch_size(&self) -> usize {
        self.active.len()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn peak_batch(&self) -> usize {
        self.peak_batch
    }

    pub fn finished(&self) -> &[FinishedSeq] {
        &self.finished
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64, prompt_len: usize, completion: usize) -> Request {
        Request {
            id,
            arrival_s: arrival,
            tenant: 0,
            prompt: (0..prompt_len as u32).collect(),
            shared_tokens: 0,
            max_new_tokens: completion,
        }
    }

    #[test]
    fn admits_up_to_max_batch() {
        let mut s = Scheduler::new(2);
        for i in 0..5 {
            s.submit(req(i, 0.0, 8, 4));
        }
        let admitted = s.admit(0.0);
        assert_eq!(admitted.len(), 2);
        assert_eq!(s.batch_size(), 2);
        assert_eq!(s.queued(), 3);
    }

    #[test]
    fn continuous_batching_joins_mid_flight() {
        let mut s = Scheduler::new(2);
        s.submit(req(0, 0.0, 8, 1));
        s.submit(req(1, 0.0, 8, 3));
        s.submit(req(2, 0.0, 8, 2));
        s.admit(0.0);
        // Iteration 1: request 0 finishes, slot opens.
        let retired = s.step_decode(0.1);
        assert_eq!(retired.len(), 1);
        assert_eq!(retired[0].request.id, 0);
        let admitted = s.admit(0.1);
        assert_eq!(admitted.len(), 1);
        assert_eq!(admitted[0].request.id, 2);
        assert_eq!(s.batch_size(), 2);
    }

    #[test]
    fn normalized_latency_counts_queueing() {
        let mut s = Scheduler::new(1);
        s.submit(req(0, 0.0, 4, 2));
        s.submit(req(1, 0.0, 4, 2)); // queued behind
        s.admit(0.0);
        s.step_decode(1.0);
        let done = s.step_decode(2.0);
        assert_eq!(done.len(), 1);
        assert!((done[0].e2e_latency_s - 2.0).abs() < 1e-9);
        assert!((done[0].normalized_latency_ms_per_tok() - 1000.0).abs() < 1e-6);
        s.admit(2.0);
        s.step_decode(3.0);
        let done = s.step_decode(4.0);
        // Request 1 waited 2s in queue: e2e = 4s over 2 tokens.
        assert!((done[0].normalized_latency_ms_per_tok() - 2000.0).abs() < 1e-6);
    }

    #[test]
    fn peak_batch_tracked() {
        let mut s = Scheduler::new(8);
        for i in 0..5 {
            s.submit(req(i, 0.0, 4, 1));
        }
        s.admit(0.0);
        assert_eq!(s.peak_batch(), 5);
        s.step_decode(0.1);
        assert_eq!(s.batch_size(), 0);
        assert_eq!(s.peak_batch(), 5);
        assert!(s.is_idle());
    }
}
