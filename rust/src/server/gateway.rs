//! The serving gateway: an HTTP/1.1 + SSE frontend routing over N engine
//! shards.
//!
//! Threading model (see DESIGN.md "The shard seam" for the full note):
//!
//! - one **accept thread** owns the `TcpListener` and spawns a short-lived
//!   **handler thread** per connection (`Connection: close` discipline);
//! - N **shard workers** ([`super::shard`]), each a stepper thread owning
//!   its own [`Engine`] exclusively — engines are never shared or locked;
//! - handler threads pick a shard through the [`Router`]'s consistent-hash
//!   ring (keyed on the longest chunk-aligned prompt prefix, so tenants
//!   sharing a system prompt land on the shard already holding its KV
//!   chunks) and talk to it over the typed [`WorkerMsg`] protocol; each
//!   submitted request carries its own event channel on which the shard
//!   streams per-token events back.
//!
//! Backpressure is per-shard admission control: a `Submit` beyond a
//! shard's queue cap is answered with a `Rejected` event, which the
//! handler maps to HTTP 429 carrying the shard id. A client disconnect
//! surfaces as a failed SSE write in the handler, which sends `Cancel` to
//! the same shard; the stepper then removes the sequence mid-decode.
//! `POST /admin/drain?shard=N` takes a shard out of the ring without
//! touching its stepper (in-flight requests finish; new traffic reroutes)
//! and `POST /admin/join?shard=N` puts it back, moving only the affected
//! key range. Graceful shutdown stops the accept loop and drains every
//! shard before joining its threads.

use super::http;
use super::router::{aggregate_expositions, routing_key, Router};
use super::shard::{spawn_shard, EngineHandle, ShardRuntime, WorkerMsg};
pub use super::shard::TokenEvent;
use crate::coordinator::{Engine, ModelRunner, SchedPolicyKind};
use crate::util::json::Json;
use crate::workload::{Request, Tokenizer};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

/// Gateway tuning knobs. The engines themselves (runner, chunk size, max
/// batch) are constructed by the caller and handed to [`Gateway::start`]
/// (one engine) or [`Gateway::start_sharded`] (a factory, one per shard).
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Bind address; port 0 picks an ephemeral port (tests).
    pub addr: String,
    /// Engine shards; each is a thread owning its own engine, scheduler,
    /// and retainer. 1 keeps the historical single-engine behavior
    /// (`/metrics` byte-compatible, no `shard` labels).
    pub shards: usize,
    /// Per-shard admission-queue capacity; submissions beyond it get 429.
    pub queue_cap: usize,
    /// Hard cap on a request's `max_new_tokens`.
    pub max_new_tokens_cap: usize,
    /// Sleep between decode iterations. Zero = step at full speed; tests
    /// and demos use a small pacing interval to emulate model latency so
    /// streaming/cancellation are observable.
    pub decode_interval: Duration,
    /// Prefix for every `/metrics` series.
    pub metrics_prefix: String,
    /// Per-shard prefix-retention chunk budget; 0 disables retention.
    pub retain_chunks: usize,
    /// Retention tiering: demote a pinned prefix to the int8-in-memory
    /// tier after this many retainer LRU ticks without a hit; 0 disables
    /// demotion. Requires `retain_chunks > 0`.
    pub retain_demote_after: u64,
    /// Retention tiering: spill an int8 pinned prefix to a file under
    /// `kv_spill_dir` after this many ticks without a hit; 0 disables
    /// spilling.
    pub retain_spill_after: u64,
    /// Spill-file directory (`--kv-spill-dir`); each shard writes under
    /// its own subdirectory. Required for `retain_spill_after` to act.
    pub kv_spill_dir: Option<PathBuf>,
    /// Per-connection socket read/write timeout.
    pub io_timeout: Duration,
    /// Retained per-request history window (scheduler finished entries +
    /// metrics records); keeps a long-running server's memory O(window)
    /// instead of O(total requests served).
    pub history_limit: usize,
    /// Chunked prefill slice granularity in tokens; 0 = monolithic
    /// prefill (a whole unmatched prompt suffix per admission).
    pub prefill_chunk_tokens: usize,
    /// Per-engine-step token budget across prefill slices, decode
    /// tokens, and eviction grants; 0 = unbounded. Budgets at or below
    /// `max_batch` force partial decode batches (the planner rotates the
    /// batch with bounded lag and keeps a prefill/eviction sliver), so
    /// the budget should comfortably exceed `max_batch` unless decode
    /// throttling is intended.
    pub step_token_budget: usize,
    /// Admission-scheduling policy (`--sched-policy`): `prefix-greedy`
    /// (historical behavior), `drr` (per-tenant deficit round-robin), or
    /// `aging` (starvation-free wait boost).
    pub sched_policy: SchedPolicyKind,
    /// DRR per-tenant weights (`--tenant-weights 0=4,3=2`); unlisted
    /// tenants weigh 1. Ignored by the other policies.
    pub tenant_weights: Vec<(usize, u32)>,
    /// Watchdog stall bound: if a shard's stepper completes no loop pass
    /// within this window, `/healthz` flips to 503-degraded until it
    /// recovers. `Duration::ZERO` disables the watchdog threads.
    pub watchdog_stall: Duration,
    /// Transient engine-step errors are retried this many times (with
    /// backoff) before the supervisor fails the implicated request(s).
    pub step_retry_max: usize,
    /// Base backoff between step retries (multiplied by the attempt number).
    pub step_retry_backoff: Duration,
    /// `Retry-After` seconds advertised on 429/503 responses.
    pub retry_after_secs: u64,
    /// When set, arm the span recorder and write a Chrome `trace_event`
    /// JSON file here (rewritten periodically and on stepper exit). Load
    /// it in `chrome://tracing` / Perfetto: tid N is shard N's stepper
    /// (step and kernel-phase spans), one track per request id for
    /// lifecycle events. Shard 0 owns the file.
    pub trace_path: Option<PathBuf>,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            addr: "127.0.0.1:0".to_string(),
            shards: 1,
            queue_cap: 64,
            max_new_tokens_cap: 4096,
            decode_interval: Duration::ZERO,
            metrics_prefix: "chunk_gateway".to_string(),
            retain_chunks: 0,
            retain_demote_after: 0,
            retain_spill_after: 0,
            kv_spill_dir: None,
            io_timeout: Duration::from_secs(30),
            history_limit: 4096,
            prefill_chunk_tokens: 0,
            step_token_budget: 0,
            sched_policy: SchedPolicyKind::PrefixGreedy,
            tenant_weights: Vec::new(),
            watchdog_stall: Duration::from_secs(5),
            step_retry_max: 3,
            step_retry_backoff: Duration::from_millis(10),
            retry_after_secs: 1,
            trace_path: None,
        }
    }
}

/// A running gateway; dropping it does NOT stop the threads — call
/// [`Gateway::shutdown`] for a clean exit.
pub struct Gateway {
    addr: SocketAddr,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    accept_thread: thread::JoinHandle<()>,
    shards: Vec<ShardRuntime>,
}

impl Gateway {
    /// Bind, then move `engine` onto a single shard worker and start
    /// serving. The single-shard fast path: routing is trivial and
    /// `/metrics` stays byte-compatible with the pre-sharding gateway.
    pub fn start<R: ModelRunner + Send + 'static>(
        engine: Engine<R>,
        mut cfg: GatewayConfig,
    ) -> anyhow::Result<Gateway> {
        cfg.shards = 1;
        let mut slot = Some(engine);
        Gateway::start_sharded(move |_| slot.take().expect("single-shard factory called once"), cfg)
    }

    /// Bind, build `cfg.shards` engines through `factory` (called with the
    /// shard id), spawn one shard worker per engine, and start routing.
    pub fn start_sharded<R, F>(mut factory: F, cfg: GatewayConfig) -> anyhow::Result<Gateway>
    where
        R: ModelRunner + Send + 'static,
        F: FnMut(usize) -> Engine<R>,
    {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let n = cfg.shards.max(1);
        let mut shards = Vec::with_capacity(n);
        // The routing key is chunk-aligned, so the router needs the chunk
        // size; every shard must use the same tree shape for affinity to
        // mean anything, so shard 0's is taken as canonical.
        let mut chunk_size = 1usize;
        for i in 0..n {
            let engine = factory(i);
            if i == 0 {
                chunk_size = engine.tree().shape().chunk_size.max(1);
            }
            shards.push(spawn_shard(i, engine, &cfg, stop.clone())?);
        }
        let handles: Vec<Arc<EngineHandle>> = shards.iter().map(|s| s.handle.clone()).collect();
        let router = Arc::new(Router::new(handles, chunk_size));

        // Built up front so the first connection doesn't pay BPE training.
        let tokenizer = Arc::new(Tokenizer::default_english());
        let accept_router = router.clone();
        let accept_stop = stop.clone();
        let accept_cfg = cfg.clone();
        let accept_thread = thread::Builder::new().name("gateway-accept".to_string()).spawn(
            move || accept_loop(listener, accept_router, accept_stop, accept_cfg, tokenizer),
        )?;

        log::info!("gateway listening on {addr} ({n} shard{})", if n == 1 { "" } else { "s" });
        // Record which kernel path and pool placement this process runs —
        // bench logs must say what they measured.
        let placement = crate::util::threadpool::placement();
        log::info!(
            "kernel simd isa: {} (PALLAS_SIMD={}); pool affinity: {} ({} workers, {} pinned)",
            crate::util::simd::active().label(),
            crate::util::simd::env_request(),
            crate::util::threadpool::affinity_mode(),
            placement.workers,
            placement.pinned,
        );
        Ok(Gateway { addr, router, stop, accept_thread, shards })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful shutdown: stop accepting connections, reject further
    /// submissions on every shard, drain active sequences, and join every
    /// worker thread.
    pub fn shutdown(self) -> anyhow::Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        for handle in self.router.handles() {
            let _ = handle.send(WorkerMsg::Drain);
        }
        self.accept_thread
            .join()
            .map_err(|_| anyhow::anyhow!("gateway accept thread panicked"))?;
        for shard in self.shards {
            shard.join()?;
        }
        Ok(())
    }
}

fn accept_loop(
    listener: TcpListener,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    cfg: GatewayConfig,
    tokenizer: Arc<Tokenizer>,
) {
    // Request ids are gateway-assigned (global across shards),
    // monotonically increasing, and well below the retainer's pin range.
    let next_id = Arc::new(AtomicU64::new(0));
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let conn_router = router.clone();
        let ids = next_id.clone();
        let tok = tokenizer.clone();
        let conn_cfg = cfg.clone();
        let spawned = thread::Builder::new().name("gateway-conn".to_string()).spawn(move || {
            if let Err(e) = handle_connection(stream, &conn_router, ids, tok, &conn_cfg) {
                log::debug!("connection handler: {e}");
            }
        });
        if let Err(e) = spawned {
            log::warn!("failed to spawn connection handler: {e}");
        }
    }
}

fn err_json(msg: &str) -> Json {
    let mut j = Json::obj();
    j.set("error", msg);
    j
}

/// How long a handler waits for a shard's one-shot reply (metrics or a
/// debug snapshot) before answering 503.
const SHARD_REPLY_TIMEOUT: Duration = Duration::from_secs(10);

/// Ask every shard for a rendered document over one-shot reply channels.
/// All requests are sent before any reply is awaited so shards render in
/// parallel. `None` means some shard is gone or wedged (maps to 503).
fn all_shards_query(
    router: &Router,
    make: impl Fn(mpsc::Sender<String>) -> WorkerMsg,
) -> Option<Vec<String>> {
    let mut replies = Vec::with_capacity(router.shard_count());
    for handle in router.handles() {
        let (reply_tx, reply_rx) = mpsc::channel();
        if !handle.send(make(reply_tx)) {
            return None;
        }
        replies.push(reply_rx);
    }
    let mut docs = Vec::with_capacity(replies.len());
    for rx in replies {
        docs.push(rx.recv_timeout(SHARD_REPLY_TIMEOUT).ok()?);
    }
    Some(docs)
}

/// Serve a per-shard-rendered document: `/metrics` documents are merged by
/// [`aggregate_expositions`]; debug JSON documents are wrapped in a
/// `{"shards": [...]}` envelope. One shard passes through untouched.
fn serve_shard_docs(
    writer: &mut TcpStream,
    router: &Router,
    retry_after: &str,
    content_type: &str,
    metrics: bool,
    make: impl Fn(mpsc::Sender<String>) -> WorkerMsg,
) -> std::io::Result<()> {
    let Some(docs) = all_shards_query(router, make) else {
        return http::write_json_with(
            writer,
            503,
            &[("Retry-After", retry_after)],
            &err_json("shard unavailable"),
        );
    };
    let mut text = if metrics {
        aggregate_expositions(&docs)
    } else if docs.len() == 1 {
        docs.into_iter().next().expect("one doc")
    } else {
        let per_shard: Vec<Json> = docs
            .iter()
            .enumerate()
            .map(|(i, doc)| {
                let mut o = Json::obj();
                o.set("shard", i);
                match Json::parse(doc) {
                    Ok(j) => o.set("state", j),
                    Err(_) => o.set("raw", doc.as_str()),
                };
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("shards", per_shard);
        j.pretty()
    };
    if !text.ends_with('\n') {
        text.push('\n');
    }
    http::write_response(writer, 200, content_type, text.as_bytes())
}

/// `?shard=N` lookup in a raw query string.
fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        (k == key).then_some(v)
    })
}

/// `/healthz` body. Single shard keeps the historical shape; with N the
/// gateway is degraded iff any shard is, and a per-shard array names the
/// culprit.
fn handle_healthz(writer: &mut TcpStream, router: &Router, retry_after: &str) -> std::io::Result<()> {
    let handles = router.handles();
    if handles.len() == 1 {
        let shared = &handles[0].shared;
        if shared.stalled.load(Ordering::SeqCst) {
            // Degraded: the stepper missed its watchdog bound. Detail
            // helps operators tell a wedged step from a dead process.
            let mut j = Json::obj();
            j.set("status", "degraded")
                .set("reason", "stepper stalled")
                .set("heartbeat_age_ms", shared.heartbeat_age_ms())
                .set("engine_panics_total", shared.engine_panics.load(Ordering::SeqCst));
            return http::write_json_with(writer, 503, &[("Retry-After", retry_after)], &j);
        }
        let mut j = Json::obj();
        j.set("status", "ok");
        return http::write_json(writer, 200, &j);
    }
    let mut any_stalled = false;
    let per_shard: Vec<Json> = handles
        .iter()
        .map(|h| {
            let stalled = h.shared.stalled.load(Ordering::SeqCst);
            any_stalled |= stalled;
            let mut o = Json::obj();
            o.set("shard", h.id)
                .set("status", if stalled { "degraded" } else { "ok" })
                .set("draining", router.is_draining(h.id))
                .set("heartbeat_age_ms", h.shared.heartbeat_age_ms())
                .set("engine_panics_total", h.shared.engine_panics.load(Ordering::SeqCst));
            o
        })
        .collect();
    let mut j = Json::obj();
    if any_stalled {
        j.set("status", "degraded").set("reason", "shard stalled").set("shards", per_shard);
        http::write_json_with(writer, 503, &[("Retry-After", retry_after)], &j)
    } else {
        j.set("status", "ok").set("shards", per_shard);
        http::write_json(writer, 200, &j)
    }
}

/// `POST /admin/drain?shard=N` / `POST /admin/join?shard=N`: live ring
/// membership changes for rolling restarts. Drain stops routing new
/// admissions to the shard without touching its stepper (in-flight
/// requests finish and stream to completion); join re-inserts its ring
/// points, moving back only the key range it originally owned.
fn handle_admin_membership(
    writer: &mut TcpStream,
    router: &Router,
    query: &str,
    join: bool,
) -> std::io::Result<()> {
    let Some(shard) = query_param(query, "shard").and_then(|s| s.parse::<usize>().ok()) else {
        return http::write_json(writer, 400, &err_json("missing or invalid ?shard=N"));
    };
    let result = if join { router.join(shard) } else { router.drain(shard) };
    match result {
        Ok(members) => {
            let verb = if join { "joined" } else { "draining" };
            log::info!("admin: shard {shard} {verb}; ring members now {members:?}");
            let mut j = Json::obj();
            j.set("shard", shard)
                .set("state", if join { "active" } else { "draining" })
                .set("ring_members", members.into_iter().map(Json::from).collect::<Vec<Json>>());
            http::write_json(writer, 200, &j)
        }
        Err(msg) => http::write_json(writer, 404, &err_json(&msg)),
    }
}

/// `GET /admin/shards`: the routing table — every shard's draining/stalled
/// state and current ring membership.
fn handle_admin_shards(writer: &mut TcpStream, router: &Router) -> std::io::Result<()> {
    let members = router.members();
    let per_shard: Vec<Json> = router
        .handles()
        .iter()
        .map(|h| {
            let mut o = Json::obj();
            o.set("shard", h.id)
                .set("draining", router.is_draining(h.id))
                .set("stalled", h.shared.stalled.load(Ordering::SeqCst))
                .set("in_ring", members.contains(&h.id));
            o
        })
        .collect();
    let mut j = Json::obj();
    j.set("shards", per_shard)
        .set("ring_members", members.into_iter().map(Json::from).collect::<Vec<Json>>());
    http::write_json(writer, 200, &j)
}

fn handle_connection(
    stream: TcpStream,
    router: &Router,
    ids: Arc<AtomicU64>,
    tokenizer: Arc<Tokenizer>,
    cfg: &GatewayConfig,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(cfg.io_timeout))?;
    stream.set_write_timeout(Some(cfg.io_timeout))?;
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let Some(req) = http::read_request(&mut reader)? else {
        return Ok(());
    };
    let retry_after = cfg.retry_after_secs.to_string();
    let (path, query) = match req.path.split_once('?') {
        Some((p, q)) => (p, q),
        None => (req.path.as_str(), ""),
    };
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => handle_healthz(&mut writer, router, &retry_after),
        ("GET", "/metrics") => serve_shard_docs(
            &mut writer,
            router,
            &retry_after,
            // The exposition content type scrapers expect (format 0.0.4).
            "text/plain; version=0.0.4; charset=utf-8",
            true,
            |reply| WorkerMsg::Scrape { reply },
        ),
        ("GET", "/debug/steps") => serve_shard_docs(
            &mut writer,
            router,
            &retry_after,
            "application/json",
            false,
            |reply| WorkerMsg::DebugSteps { reply },
        ),
        ("GET", "/debug/tree") => serve_shard_docs(
            &mut writer,
            router,
            &retry_after,
            "application/json",
            false,
            |reply| WorkerMsg::DebugTree { reply },
        ),
        ("GET", "/admin/shards") => handle_admin_shards(&mut writer, router),
        ("POST", "/admin/drain") => handle_admin_membership(&mut writer, router, query, false),
        ("POST", "/admin/join") => handle_admin_membership(&mut writer, router, query, true),
        ("POST", "/v1/generate") => handle_generate(&req, writer, router, ids, &tokenizer, cfg),
        ("GET" | "POST", _) => http::write_json(&mut writer, 404, &err_json("not found")),
        _ => http::write_json(&mut writer, 405, &err_json("method not allowed")),
    }
}

/// Parsed `/v1/generate` body.
struct GenerateParams {
    tokens: Vec<u32>,
    tenant: usize,
    shared_tokens: usize,
    max_new_tokens: usize,
    /// Wall-clock budget for the whole request; absent/0 = none. Enforced
    /// in the stepper loop: expiry releases residency and sends the
    /// terminal `timeout` SSE event.
    deadline_ms: Option<u64>,
}

fn parse_generate(
    req: &http::HttpRequest,
    tokenizer: &Tokenizer,
    cfg: &GatewayConfig,
) -> Result<GenerateParams, String> {
    let body = req.body_utf8()?;
    let j = Json::parse(body).map_err(|e| format!("invalid JSON body: {e}"))?;
    let mut tokens: Vec<u32> = Vec::new();
    if let Some(arr) = j.get("tokens").and_then(|t| t.as_arr()) {
        tokens.reserve(arr.len());
        for x in arr {
            let f = x.as_f64().ok_or_else(|| "\"tokens\" must be an array of numbers".to_string())?;
            if !(0.0..=u32::MAX as f64).contains(&f) {
                return Err(format!("token id {f} out of range"));
            }
            tokens.push(f as u32);
        }
    } else if let Some(text) = j.get("text").and_then(|t| t.as_str()) {
        tokens = tokenizer.encode(text);
    }
    if tokens.is_empty() {
        return Err("request needs a non-empty \"tokens\" array or a \"text\" string".to_string());
    }
    let num = |key: &str, default: usize| {
        j.get(key).and_then(|v| v.as_f64()).map(|f| f.max(0.0) as usize).unwrap_or(default)
    };
    let deadline_ms = match num("deadline_ms", 0) {
        0 => None,
        ms => Some(ms as u64),
    };
    Ok(GenerateParams {
        shared_tokens: num("shared_tokens", 0).min(tokens.len()),
        tenant: num("tenant", 0),
        // `.max(1)` on the cap guards a `--max-new-tokens-cap 0` misconfig:
        // clamp(1, 0) would panic the handler thread.
        max_new_tokens: num("max_new_tokens", 16).clamp(1, cfg.max_new_tokens_cap.max(1)),
        deadline_ms,
        tokens,
    })
}

/// A client-supplied `X-Request-Id`, sanitized for log/header echo:
/// printable ASCII only, bounded length. Empty after sanitizing = absent.
fn request_id(req: &http::HttpRequest) -> Option<String> {
    let rid: String =
        req.header("x-request-id")?.chars().filter(|c| c.is_ascii_graphic()).take(128).collect();
    if rid.is_empty() {
        None
    } else {
        Some(rid)
    }
}

/// Non-blocking liveness probe for a connection we are only writing to:
/// after the request is consumed a well-behaved client sends nothing, so a
/// successful 0-byte peek (orderly FIN) or a hard error means it is gone;
/// `WouldBlock` means it is still there.
fn client_gone(stream: &TcpStream) -> bool {
    let mut probe = [0u8; 1];
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let gone = match stream.peek(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = stream.set_nonblocking(false);
    gone
}

fn handle_generate(
    req: &http::HttpRequest,
    mut writer: TcpStream,
    router: &Router,
    ids: Arc<AtomicU64>,
    tokenizer: &Tokenizer,
    cfg: &GatewayConfig,
) -> std::io::Result<()> {
    let rid = request_id(req);
    // Echoed on every response to this request, streaming or not, so the
    // client can correlate its logs with the gateway's and the shard's.
    let mut echo: Vec<(&str, &str)> = Vec::new();
    if let Some(r) = rid.as_deref() {
        echo.push(("X-Request-Id", r));
    }
    let params = match parse_generate(req, tokenizer, cfg) {
        Ok(p) => p,
        Err(msg) => return http::write_json_with(&mut writer, 400, &echo, &err_json(&msg)),
    };
    let retry_after = cfg.retry_after_secs.to_string();
    let mut echo_retry: Vec<(&str, &str)> = vec![("Retry-After", &retry_after)];
    echo_retry.extend(echo.iter().copied());
    // Prefix-affinity routing: hash the longest chunk-aligned prefix so
    // requests sharing a system prompt land on the shard already holding
    // its chunks; prefix-less traffic spreads by full-prompt hash.
    let key = routing_key(&params.tokens, params.shared_tokens, router.chunk_size());
    let Some(handle) = router.route(key) else {
        return http::write_json_with(
            &mut writer,
            503,
            &echo_retry,
            &err_json("all shards draining"),
        );
    };
    let shard = handle.id;
    let id = ids.fetch_add(1, Ordering::SeqCst);
    match rid.as_deref() {
        Some(r) => log::debug!(
            "request {id} rid={r}: POST /v1/generate -> shard {shard} ({} prompt tokens, tenant {}, max_new {})",
            params.tokens.len(),
            params.tenant,
            params.max_new_tokens
        ),
        None => log::debug!(
            "request {id}: POST /v1/generate -> shard {shard} ({} prompt tokens, tenant {}, max_new {})",
            params.tokens.len(),
            params.tenant,
            params.max_new_tokens
        ),
    }
    let request = Request {
        id,
        arrival_s: 0.0, // stamped with the engine clock at submit
        tenant: params.tenant,
        prompt: params.tokens,
        shared_tokens: params.shared_tokens,
        max_new_tokens: params.max_new_tokens,
    };
    let deadline = params.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
    let (ev_tx, ev_rx) = mpsc::channel();
    if !handle.send(WorkerMsg::Submit { request, events: ev_tx, deadline, rid: rid.clone() }) {
        return http::write_json_with(
            &mut writer,
            503,
            &echo_retry,
            &err_json("gateway is shutting down"),
        );
    }
    // The first event decides the HTTP status: Rejected -> 429/503, Error
    // -> 500, Timeout -> 504 before any SSE bytes; a Token starts the
    // stream. A queued request may legitimately wait here until a batch
    // slot frees up, so poll the socket for liveness while waiting — a
    // client that gave up while queued must not hold its queue slot (or
    // later burn prefill work).
    let first = loop {
        match ev_rx.recv_timeout(Duration::from_millis(250)) {
            Ok(ev) => break ev,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return http::write_json_with(&mut writer, 500, &echo, &err_json("engine unavailable"));
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if client_gone(&writer) {
                    let _ = handle.send(WorkerMsg::Cancel { id });
                    return Ok(());
                }
            }
        }
    };
    match &first {
        TokenEvent::Rejected { queued, draining } => {
            if *draining {
                return http::write_json_with(
                    &mut writer,
                    503,
                    &echo_retry,
                    &err_json("gateway is shutting down"),
                );
            }
            // The shard id in the body tells a client (or bench) *which*
            // admission queue is full — under prefix routing a hot prefix
            // saturates its shard while others sit idle.
            let mut j = err_json("admission queue full");
            j.set("queued", *queued).set("shard", shard);
            return http::write_json_with(&mut writer, 429, &echo_retry, &j);
        }
        // Failures before any token: a plain HTTP error beats an SSE
        // stream whose first event is terminal.
        TokenEvent::Error { message } => {
            return http::write_json_with(&mut writer, 500, &echo, &err_json(message));
        }
        TokenEvent::Timeout => {
            return http::write_json_with(&mut writer, 504, &echo, &err_json("deadline exceeded"));
        }
        TokenEvent::Token { .. } | TokenEvent::Done { .. } => {}
    }
    http::start_sse_with(&mut writer, &echo)?;
    let mut pending = Some(first);
    loop {
        let event = match pending.take() {
            Some(ev) => ev,
            None => match ev_rx.recv() {
                Ok(ev) => ev,
                Err(_) => {
                    // Stepper went away mid-stream: still deliver a
                    // terminal event before closing (no silent EOF).
                    let _ = http::write_sse_event(
                        &mut writer,
                        &terminal_error_json(id, "engine unavailable").to_string(),
                    );
                    break;
                }
            },
        };
        match event {
            TokenEvent::Token { index, token } => {
                let mut j = Json::obj();
                j.set("index", index).set("token", token as u64);
                if http::write_sse_event(&mut writer, &j.to_string()).is_err() {
                    // Client disconnected: cancel so the sequence's private
                    // chunks return to the tree pool mid-decode.
                    let _ = handle.send(WorkerMsg::Cancel { id });
                    return Ok(());
                }
            }
            TokenEvent::Done { completion_tokens } => {
                let mut j = Json::obj();
                j.set("done", true).set("completion_tokens", completion_tokens).set("id", id);
                let _ = http::write_sse_event(&mut writer, &j.to_string());
                break;
            }
            TokenEvent::Error { message } => {
                let _ = http::write_sse_event(
                    &mut writer,
                    &terminal_error_json(id, &message).to_string(),
                );
                break;
            }
            TokenEvent::Timeout => {
                let mut j = Json::obj();
                j.set("timeout", true).set("id", id);
                let _ = http::write_sse_event(&mut writer, &j.to_string());
                break;
            }
            TokenEvent::Rejected { .. } => break, // unreachable after admission
        }
    }
    Ok(())
}

fn terminal_error_json(id: u64, message: &str) -> Json {
    let mut j = Json::obj();
    j.set("error", message).set("id", id);
    j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::testing::SyntheticRunner;
    use crate::server::client;

    fn small_engine() -> Engine<SyntheticRunner> {
        Engine::new(SyntheticRunner { heads_total: 2, head_dim: 4, vocab: 101 }, 8, 4)
    }

    #[test]
    fn healthz_and_shutdown() {
        let gw = Gateway::start(small_engine(), GatewayConfig::default()).unwrap();
        let addr = gw.addr().to_string();
        let resp = client::get(&addr, "/healthz", Duration::from_secs(5)).unwrap();
        assert_eq!(resp.status, 200);
        assert!(resp.body.contains("ok"), "{}", resp.body);
        let resp = client::get(&addr, "/nope", Duration::from_secs(5)).unwrap();
        assert_eq!(resp.status, 404);
        gw.shutdown().unwrap();
    }

    #[test]
    fn bad_body_is_a_400_not_a_hang() {
        let gw = Gateway::start(small_engine(), GatewayConfig::default()).unwrap();
        let addr = gw.addr().to_string();
        let mut s = client::generate(&addr, &Json::obj(), Duration::from_secs(5)).unwrap();
        assert_eq!(s.status(), 400);
        assert!(s.next_event().unwrap().is_none());
        gw.shutdown().unwrap();
    }

    #[test]
    fn text_prompts_are_tokenized_server_side() {
        let gw = Gateway::start(small_engine(), GatewayConfig::default()).unwrap();
        let addr = gw.addr().to_string();
        let mut body = Json::obj();
        body.set("text", "hello world, generate something").set("max_new_tokens", 3u64);
        let mut s = client::generate(&addr, &body, Duration::from_secs(10)).unwrap();
        assert_eq!(s.status(), 200);
        let mut tokens = 0;
        while let Some(ev) = s.next_event().unwrap() {
            match ev {
                client::StreamEvent::Token { .. } => tokens += 1,
                client::StreamEvent::Done { completion_tokens } => {
                    assert_eq!(completion_tokens, 3);
                    break;
                }
                other => panic!("unexpected terminal event: {other:?}"),
            }
        }
        assert_eq!(tokens, 3);
        gw.shutdown().unwrap();
    }
}
