//! One engine shard: a stepper thread owning an [`Engine`] exclusively,
//! driven over a typed worker-protocol channel ([`WorkerMsg`]).
//!
//! This is the seam the gateway's router speaks through. Each shard is a
//! thread that owns its own engine, scheduler, retainer, and failpoint/
//! trace context; the only way in is an [`EngineHandle`] carrying the
//! shard id and the command sender. Submit / cancel / scrape / debug /
//! drain all travel as [`WorkerMsg`] variants, and each submitted request
//! carries its own event channel on which the shard streams per-token
//! [`TokenEvent`]s back.
//!
//! The supervision ladder (retry → attribute-and-fail → panic recovery →
//! invariant verify → full rebuild), the watchdog heartbeat, the
//! `/debug/steps` ring, and the per-shard `/metrics` rendering all live
//! here — they are per-engine concerns, so a gateway with N shards gets N
//! independent failure domains.

use super::gateway::GatewayConfig;
use crate::coordinator::{Engine, FinishedSeq, ModelRunner};
use crate::metrics::{
    push_gauge, push_histogram, push_histogram_family, push_labeled_gauge, push_labeled_series,
    render_exposition, StepTiming,
};
use crate::util::failpoint;
use crate::util::json::Json;
use crate::util::trace;
use crate::workload::Request;
use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Per-token events a shard streams back to a request's handler.
#[derive(Debug, Clone)]
pub enum TokenEvent {
    /// Admission control refused the request. `draining` distinguishes a
    /// shutting-down gateway (HTTP 503) from a full queue (HTTP 429).
    Rejected { queued: usize, draining: bool },
    /// One freshly decoded completion token.
    Token { index: usize, token: u32 },
    /// The sequence finished; the stream is complete.
    Done { completion_tokens: usize },
    /// Terminal: the request failed server-side (panic quarantine,
    /// persistent runner error, or a full engine rebuild).
    Error { message: String },
    /// Terminal: the request exceeded its `deadline_ms`.
    Timeout,
}

/// The worker protocol: every way a handler (or the router) can drive a
/// shard. One enum so the seam is explicit and exhaustively matched.
pub(crate) enum WorkerMsg {
    Submit {
        request: Request,
        events: mpsc::Sender<TokenEvent>,
        deadline: Option<Instant>,
        /// Client-supplied `X-Request-Id`, for shard-side log correlation.
        rid: Option<String>,
    },
    Cancel {
        id: u64,
    },
    Scrape {
        reply: mpsc::Sender<String>,
    },
    /// `/debug/steps`: JSON dump of the shard's recent-step ring.
    DebugSteps {
        reply: mpsc::Sender<String>,
    },
    /// `/debug/tree`: JSON snapshot of prefix-tree residency and sharing.
    DebugTree {
        reply: mpsc::Sender<String>,
    },
    /// Shutdown drain: reject new submissions, finish in-flight, exit.
    /// (A *live* routing drain is a router-side ring change and never
    /// reaches the shard — its stepper keeps running.)
    Drain,
}

/// Liveness heartbeat and failure counters shared by a shard's stepper
/// thread, its watchdog, and connection handlers. All atomics: readable
/// from any thread, unpoisonable by a panicking one.
pub(crate) struct ShardShared {
    started: Instant,
    /// Milliseconds since `started` of the stepper's last completed loop
    /// pass (bumped on every pass, idle or busy, so staleness always
    /// means a wedged or very slow step).
    heartbeat_ms: AtomicU64,
    /// Set by the watchdog while the heartbeat is stale; drives 503 on
    /// `/healthz`.
    pub(crate) stalled: AtomicBool,
    pub(crate) watchdog_stalls: AtomicU64,
    pub(crate) engine_panics: AtomicU64,
    pub(crate) engine_rebuilds: AtomicU64,
    pub(crate) requests_timed_out: AtomicU64,
    pub(crate) step_retries: AtomicU64,
    /// `requests_failed_total` by reason.
    failed_panic: AtomicU64,
    failed_error: AtomicU64,
    failed_rebuild: AtomicU64,
}

impl ShardShared {
    fn new() -> Self {
        ShardShared {
            started: Instant::now(),
            heartbeat_ms: AtomicU64::new(0),
            stalled: AtomicBool::new(false),
            watchdog_stalls: AtomicU64::new(0),
            engine_panics: AtomicU64::new(0),
            engine_rebuilds: AtomicU64::new(0),
            requests_timed_out: AtomicU64::new(0),
            step_retries: AtomicU64::new(0),
            failed_panic: AtomicU64::new(0),
            failed_error: AtomicU64::new(0),
            failed_rebuild: AtomicU64::new(0),
        }
    }

    fn now_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    /// Stepper liveness beat, once per loop pass.
    fn beat(&self) {
        self.heartbeat_ms.store(self.now_ms(), Ordering::SeqCst);
    }

    pub(crate) fn heartbeat_age_ms(&self) -> u64 {
        self.now_ms().saturating_sub(self.heartbeat_ms.load(Ordering::SeqCst))
    }

    fn count_failure(&self, reason: FailReason) {
        match reason {
            FailReason::Panic => &self.failed_panic,
            FailReason::Error => &self.failed_error,
            FailReason::Rebuild => &self.failed_rebuild,
        }
        .fetch_add(1, Ordering::SeqCst);
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FailReason {
    /// Quarantined after a panic unwound out of `Engine::step`.
    Panic,
    /// Failed after transient-error retries were exhausted.
    Error,
    /// Dropped by a full engine rebuild (broken invariants).
    Rebuild,
}

/// A shard's public face: the id plus the command sender. Cloneable via
/// `Arc`; the sender sits behind a `Mutex` so the handle is `Sync` without
/// per-handler channel clones (the lock covers only the enqueue, never a
/// reply wait, so a slow scrape cannot block a submit for long).
pub(crate) struct EngineHandle {
    pub(crate) id: usize,
    tx: Mutex<mpsc::Sender<WorkerMsg>>,
    pub(crate) shared: Arc<ShardShared>,
}

impl EngineHandle {
    /// Enqueue one message; `false` means the shard's stepper is gone
    /// (shutdown), which handlers map to HTTP 503.
    pub(crate) fn send(&self, msg: WorkerMsg) -> bool {
        match self.tx.lock() {
            Ok(tx) => tx.send(msg).is_ok(),
            Err(_) => false,
        }
    }
}

/// A running shard: its handle plus the thread handles the gateway joins
/// on shutdown.
pub(crate) struct ShardRuntime {
    pub(crate) handle: Arc<EngineHandle>,
    stepper: thread::JoinHandle<()>,
    watchdog: Option<thread::JoinHandle<()>>,
}

impl ShardRuntime {
    pub(crate) fn join(self) -> anyhow::Result<()> {
        let id = self.handle.id;
        self.stepper
            .join()
            .map_err(|_| anyhow::anyhow!("shard {id} stepper thread panicked"))?;
        if let Some(wd) = self.watchdog {
            wd.join().map_err(|_| anyhow::anyhow!("shard {id} watchdog thread panicked"))?;
        }
        Ok(())
    }
}

/// Configure `engine` from the gateway knobs and spawn its stepper (and
/// watchdog) threads. This is the per-engine half of what `Gateway::start`
/// used to do inline; the gateway now calls it once per shard.
pub(crate) fn spawn_shard<R: ModelRunner + Send + 'static>(
    id: usize,
    mut engine: Engine<R>,
    cfg: &GatewayConfig,
    stop: Arc<AtomicBool>,
) -> anyhow::Result<ShardRuntime> {
    engine.set_queue_limit(Some(cfg.queue_cap));
    engine.set_history_limit(cfg.history_limit);
    engine.set_chunked_prefill(cfg.prefill_chunk_tokens, cfg.step_token_budget);
    engine.set_planner_config(crate::coordinator::PlannerConfig {
        policy: cfg.sched_policy,
        tenant_weights: cfg.tenant_weights.clone(),
        ..crate::coordinator::PlannerConfig::default()
    });
    if cfg.retain_chunks > 0 {
        engine.enable_prefix_retention(cfg.retain_chunks);
        if cfg.retain_demote_after > 0 || cfg.retain_spill_after > 0 {
            engine.set_retention_tiering(crate::kvcache::TieringConfig {
                demote_after: cfg.retain_demote_after,
                spill_after: cfg.retain_spill_after,
                // Per-shard subdirectory: pin ids are only unique within
                // one retainer, so shards must not share spill filenames.
                spill_dir: cfg.kv_spill_dir.as_ref().map(|d| d.join(format!("shard-{id}"))),
            });
        }
    }
    // Arm failpoints from the environment (no-op when FAILPOINTS is
    // unset) so the chaos CI leg reaches gateways spawned anywhere. The
    // registry is process-global: every shard shares one fault profile.
    failpoint::arm_from_env();
    // Arm the span recorder only when a trace file was requested; the
    // disarmed path stays one relaxed atomic load per site.
    if cfg.trace_path.is_some() {
        trace::arm();
    }
    let (tx, rx) = mpsc::channel::<WorkerMsg>();
    let shared = Arc::new(ShardShared::new());
    shared.beat();

    let stepper_cfg = cfg.clone();
    let stepper_shared = shared.clone();
    let stepper = thread::Builder::new()
        .name(format!("gateway-stepper-{id}"))
        .spawn(move || stepper_loop(id, engine, rx, stepper_cfg, stepper_shared))?;

    let watchdog = if cfg.watchdog_stall > Duration::ZERO {
        let wd_shared = shared.clone();
        let stall = cfg.watchdog_stall;
        Some(
            thread::Builder::new()
                .name(format!("gateway-watchdog-{id}"))
                .spawn(move || watchdog_loop(id, wd_shared, stop, stall))?,
        )
    } else {
        None
    };

    Ok(ShardRuntime { handle: Arc::new(EngineHandle { id, tx: Mutex::new(tx), shared }), stepper, watchdog })
}

/// Stream bookkeeping the stepper keeps per live request.
struct StreamState {
    events: mpsc::Sender<TokenEvent>,
    /// Completion tokens already pushed to the event channel.
    sent: usize,
    /// Absolute deadline derived from the request's `deadline_ms`.
    deadline: Option<Instant>,
    /// When the previous completion token was streamed; feeds the
    /// `inter_token_seconds` histogram.
    last_token_at: Option<Instant>,
}

/// One completed engine step, kept in a bounded ring for `/debug/steps`.
#[derive(Clone, Copy)]
struct StepRecord {
    /// Monotone step ordinal (the step-duration histogram's count).
    seq: u64,
    /// Milliseconds since shard start when the step was observed.
    ts_ms: u64,
    timing: StepTiming,
}

/// `/debug/steps` ring capacity.
const STEP_RING_CAP: usize = 256;

/// Stepper passes between periodic trace-file rewrites when `--trace-out`
/// is set (the file is also written on stepper exit).
const TRACE_FLUSH_PASSES: u64 = 1024;

/// Watchdog thread: flips the shard's `stalled` flag while the stepper's
/// heartbeat is stale. The stepper beats on every loop pass (including
/// idle parking), so staleness always means a wedged or pathologically
/// slow step — the flag drives `/healthz` 503-degraded (the gateway is
/// degraded iff any shard is).
fn watchdog_loop(shard: usize, shared: Arc<ShardShared>, stop: Arc<AtomicBool>, stall: Duration) {
    let stall_ms = stall.as_millis().max(1) as u64;
    let poll = (stall / 4).clamp(Duration::from_millis(5), Duration::from_millis(250));
    while !stop.load(Ordering::SeqCst) {
        thread::sleep(poll);
        if shared.heartbeat_age_ms() > stall_ms {
            if !shared.stalled.swap(true, Ordering::SeqCst) {
                shared.watchdog_stalls.fetch_add(1, Ordering::SeqCst);
                log::warn!(
                    "watchdog: shard {shard} made no stepper pass in {}ms (bound {}ms); /healthz degraded",
                    shared.heartbeat_age_ms(),
                    stall_ms
                );
            }
        } else if shared.stalled.swap(false, Ordering::SeqCst) {
            log::info!("watchdog: shard {shard} stepper recovered; /healthz healthy");
        }
    }
}

fn stepper_loop<R: ModelRunner>(
    shard: usize,
    mut engine: Engine<R>,
    cmd_rx: mpsc::Receiver<WorkerMsg>,
    cfg: GatewayConfig,
    shared: Arc<ShardShared>,
) {
    let mut streams: BTreeMap<u64, StreamState> = BTreeMap::new();
    let mut draining = false;
    let mut step_retries = 0usize;
    // `/debug/steps` ring + the ordinal of the last step pushed into it
    // (the step-duration histogram count doubles as a step sequence
    // number, so failed/retried passes never duplicate stale records).
    let mut step_ring: VecDeque<StepRecord> = VecDeque::with_capacity(STEP_RING_CAP);
    let mut steps_seen: u64 = 0;
    // Accumulated trace events when `--trace-out` is set. The span ring is
    // process-global, so exactly one shard (0) drains it and rewrites the
    // Chrome JSON file — two writers would each produce a file missing the
    // other's events.
    let trace_owner = cfg.trace_path.is_some() && shard == 0;
    let mut trace_events: Vec<trace::TraceEvent> = Vec::new();
    let mut passes: u64 = 0;
    loop {
        shared.beat();
        passes += 1;
        if trace_owner && passes % TRACE_FLUSH_PASSES == 0 {
            flush_trace(cfg.trace_path.as_deref(), &mut trace_events);
        }
        // Pull every pending command; commands are cheap, steps are not.
        let mut disconnected = false;
        loop {
            match cmd_rx.try_recv() {
                Ok(cmd) => handle_cmd(
                    shard,
                    cmd,
                    &mut engine,
                    &mut streams,
                    &mut draining,
                    &cfg,
                    &shared,
                    &step_ring,
                ),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        // Deadlines are enforced on every pass (idle included) so a
        // request expiring while *queued* times out promptly too.
        enforce_deadlines(&mut engine, &mut streams, &shared);
        if engine.is_idle() {
            if draining || disconnected {
                break;
            }
            // Idle maintenance: keep spending the amortized eviction
            // allowance while pinned prefixes sit over the retention
            // budget, so the last request's pins drain between requests.
            // Supervised like the busy path: an injected panic or error
            // during maintenance must not kill the stepper either.
            if engine.needs_maintenance() {
                let _ = run_step_supervised(
                    &mut engine,
                    &mut streams,
                    &shared,
                    &cfg,
                    &mut step_retries,
                );
                note_step(shard, &engine, &shared, &mut step_ring, &mut steps_seen);
            }
            // Park until work arrives, with a bounded wait so a Drain that
            // raced past the try_recv loop is still noticed promptly.
            match cmd_rx.recv_timeout(Duration::from_millis(20)) {
                Ok(cmd) => handle_cmd(
                    shard,
                    cmd,
                    &mut engine,
                    &mut streams,
                    &mut draining,
                    &cfg,
                    &shared,
                    &step_ring,
                ),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            continue;
        }
        let finished =
            run_step_supervised(&mut engine, &mut streams, &shared, &cfg, &mut step_retries);
        note_step(shard, &engine, &shared, &mut step_ring, &mut steps_seen);
        // Stream freshly decoded tokens. A send error means the handler is
        // gone without managing to send Cancel (it died); reap eagerly so
        // the sequence stops burning decode slots.
        let mut dead: Vec<u64> = Vec::new();
        let mut inter_token_gaps: Vec<f64> = Vec::new();
        for (&id, st) in streams.iter_mut() {
            let Some(completion) = engine.completion_of(id) else { continue };
            let total = completion.len();
            while st.sent < total {
                let token = completion[st.sent];
                if st.events.send(TokenEvent::Token { index: st.sent, token }).is_err() {
                    dead.push(id);
                    break;
                }
                st.sent += 1;
                let now = Instant::now();
                if let Some(prev) = st.last_token_at.replace(now) {
                    // Gap since this request's previous token (the first
                    // token's latency is the TTFT histogram's job).
                    inter_token_gaps.push(now.duration_since(prev).as_secs_f64());
                }
            }
        }
        for dt in inter_token_gaps {
            engine.metrics_mut().record_inter_token(dt);
        }
        for id in dead {
            streams.remove(&id);
            engine.cancel(id);
            engine.release(id);
            if trace::armed() {
                trace::instant("cancelled", "request", id, vec![("why", "disconnect".into())]);
            }
            log::debug!("request {id}: client gone mid-stream; shard {shard} residency released");
        }
        for f in finished {
            let id = f.request.id;
            let n = engine.completion_of(id).map(|c| c.len()).unwrap_or(0);
            if let Some(st) = streams.remove(&id) {
                let _ = st.events.send(TokenEvent::Done { completion_tokens: n });
            }
            engine.release(id);
            if trace::armed() {
                trace::instant(
                    "finished",
                    "request",
                    id,
                    vec![("completion_tokens", n.to_string())],
                );
            }
            log::debug!("request {id}: finished with {n} completion tokens on shard {shard}");
        }
        if cfg.decode_interval > Duration::ZERO {
            thread::sleep(cfg.decode_interval);
        }
    }
    if trace_owner {
        flush_trace(cfg.trace_path.as_deref(), &mut trace_events);
        log::info!(
            "wrote {} trace events to {}",
            trace_events.len(),
            cfg.trace_path.as_ref().unwrap().display()
        );
    }
    // Terminal-event guarantee on the stepper's own exit path: any stream
    // still open (e.g. the command channel disconnected mid-flight) gets
    // an explicit SSE error instead of a silent sender drop.
    for (_, st) in streams {
        let _ = st
            .events
            .send(TokenEvent::Error { message: "gateway stepper exiting".to_string() });
    }
}

/// Record the most recent *completed* step into the `/debug/steps` ring and
/// (when tracing is armed) emit its Chrome spans. Keyed on the step-duration
/// histogram count so passes that failed or only pumped commands are skipped.
fn note_step<R: ModelRunner>(
    shard: usize,
    engine: &Engine<R>,
    shared: &ShardShared,
    ring: &mut VecDeque<StepRecord>,
    steps_seen: &mut u64,
) {
    let n = engine.metrics().step_duration_seconds.total();
    if n == *steps_seen {
        return;
    }
    *steps_seen = n;
    let timing = engine.last_step_timing();
    if ring.len() == STEP_RING_CAP {
        ring.pop_front();
    }
    ring.push_back(StepRecord { seq: n, ts_ms: shared.now_ms(), timing });
    if trace::armed() {
        emit_step_spans(shard, n, &timing);
    }
}

/// Emit one "step" span plus its per-phase child spans on the shard's
/// stepper track (tid = shard id; single-shard gateways keep the historical
/// track 0). Phases are laid out back-to-back from the step's start; the
/// kernel's chunk-first/seq-first sub-phases ran inside the decode call, so
/// the layout is a readable approximation rather than exact wall intervals.
fn emit_step_spans(shard: usize, seq: u64, t: &StepTiming) {
    let tid = shard as u64;
    let end_us = trace::now_us();
    let total_us = (t.total_s * 1e6) as u64;
    let start = end_us.saturating_sub(total_us);
    trace::span(
        "step",
        "step",
        tid,
        start,
        total_us,
        vec![
            ("seq", seq.to_string()),
            ("decode_batch", t.decode_batch.to_string()),
            ("prefill_slices", t.prefill_slices.to_string()),
            ("admitted", t.admitted.to_string()),
            ("finished", t.finished.to_string()),
        ],
    );
    let mut cursor = start;
    for (name, secs) in t.phases() {
        let dur = (secs * 1e6) as u64;
        if dur == 0 {
            continue;
        }
        let cat = if matches!(name, "chunk_first" | "seq_first") { "kernel" } else { "step" };
        trace::span(name, cat, tid, cursor, dur, Vec::new());
        cursor = cursor.saturating_add(dur);
    }
}

/// Drain buffered span-recorder events into `events` and rewrite the Chrome
/// trace file. Quiet on success (called periodically); warns on I/O errors.
fn flush_trace(path: Option<&std::path::Path>, events: &mut Vec<trace::TraceEvent>) {
    let Some(path) = path else { return };
    events.extend(trace::drain());
    if let Err(e) = trace::write_chrome_trace_file(path, events) {
        log::warn!("failed to write trace file {}: {e}", path.display());
    }
}

/// One supervised engine iteration: `Engine::step` under `catch_unwind`,
/// with the degradation ladder on failure —
///
/// 1. transient `Err`: bounded retry with backoff (the restore-queue seam
///    makes whole-step retry safe for prefill errors);
/// 2. retries exhausted: fail only the attributed request (`[seq:<id>]` in
///    the error), or quarantine all in-flight when unattributed;
/// 3. panic: quarantine the implicated sequences, repair bookkeeping
///    (`recover_after_panic`), verify tree invariants;
/// 4. invariants broken: full engine rebuild — drop all residency, fail
///    every open stream, keep serving.
fn run_step_supervised<R: ModelRunner>(
    engine: &mut Engine<R>,
    streams: &mut BTreeMap<u64, StreamState>,
    shared: &ShardShared,
    cfg: &GatewayConfig,
    step_retries: &mut usize,
) -> Vec<FinishedSeq> {
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        // Chaos site: panic in the stepper thread itself, outside the
        // engine — proves supervision covers the whole closure.
        if let Some(msg) = failpoint::fire("gateway.stepper") {
            return Err(anyhow::anyhow!(msg));
        }
        engine.step()
    }));
    match outcome {
        Ok(Ok(finished)) => {
            *step_retries = 0;
            finished
        }
        Ok(Err(e)) => {
            let msg = e.to_string();
            if *step_retries < cfg.step_retry_max {
                *step_retries += 1;
                shared.step_retries.fetch_add(1, Ordering::SeqCst);
                if trace::armed() {
                    trace::instant(
                        "step_retry",
                        "fault",
                        0,
                        vec![("attempt", step_retries.to_string()), ("error", msg.clone())],
                    );
                }
                log::warn!(
                    "engine step failed (retry {}/{}): {msg}",
                    *step_retries,
                    cfg.step_retry_max
                );
                thread::sleep(cfg.step_retry_backoff * *step_retries as u32);
            } else {
                *step_retries = 0;
                if trace::armed() {
                    trace::instant("step_failed", "fault", 0, vec![("error", msg.clone())]);
                }
                log::error!("engine step failed after retries, quarantining: {msg}");
                let victims = match failpoint::seq_attribution(&msg) {
                    Some(id) => vec![id],
                    None => engine.inflight_ids(),
                };
                fail_requests(engine, streams, shared, &victims, FailReason::Error, &msg);
                verify_or_rebuild(engine, streams, shared);
            }
            Vec::new()
        }
        Err(payload) => {
            *step_retries = 0;
            shared.engine_panics.fetch_add(1, Ordering::SeqCst);
            let msg = panic_message(payload.as_ref());
            if trace::armed() {
                trace::instant("step_panic", "fault", 0, vec![("message", msg.clone())]);
            }
            log::error!("engine step panicked ({msg}); recovering");
            let (orphans, finished) = engine.recover_after_panic();
            let mut victims = orphans;
            match failpoint::seq_attribution(&msg) {
                Some(id) => {
                    if !victims.contains(&id) {
                        victims.push(id);
                    }
                }
                None => {
                    // Unattributed panic: quarantine conservatively —
                    // every in-flight sequence may have been implicated.
                    for id in engine.inflight_ids() {
                        if !victims.contains(&id) {
                            victims.push(id);
                        }
                    }
                }
            }
            fail_requests(engine, streams, shared, &victims, FailReason::Panic, &msg);
            verify_or_rebuild(engine, streams, shared);
            finished
        }
    }
}

/// Extract a readable message from a panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Quarantine `victims`: release their engine residency and send each open
/// stream a terminal SSE error.
fn fail_requests<R: ModelRunner>(
    engine: &mut Engine<R>,
    streams: &mut BTreeMap<u64, StreamState>,
    shared: &ShardShared,
    victims: &[u64],
    reason: FailReason,
    msg: &str,
) {
    for &id in victims {
        let cancelled = engine.cancel(id);
        let released = engine.release(id).is_some();
        let had_stream = match streams.remove(&id) {
            Some(st) => {
                let _ = st.events.send(TokenEvent::Error { message: msg.to_string() });
                true
            }
            None => false,
        };
        if cancelled || released || had_stream {
            shared.count_failure(reason);
        }
    }
}

/// Escalation: if the tree's invariants are broken after recovery, rebuild
/// the engine's residency from scratch (dropping every in-flight request)
/// and keep serving. The process never exits.
fn verify_or_rebuild<R: ModelRunner>(
    engine: &mut Engine<R>,
    streams: &mut BTreeMap<u64, StreamState>,
    shared: &ShardShared,
) {
    if let Err(e) = engine.tree().check_invariants() {
        log::error!("prefix-tree invariants broken after recovery ({e}); full engine rebuild");
        shared.engine_rebuilds.fetch_add(1, Ordering::SeqCst);
        let dropped = engine.hard_reset();
        for _ in &dropped {
            shared.count_failure(FailReason::Rebuild);
        }
        for (_, st) in std::mem::take(streams) {
            let _ = st.events.send(TokenEvent::Error {
                message: "engine rebuilt after broken invariants; request dropped".to_string(),
            });
        }
    }
}

/// Fail every stream whose deadline has passed: release engine residency
/// (private chunks return to the pool) and send the terminal timeout event.
fn enforce_deadlines<R: ModelRunner>(
    engine: &mut Engine<R>,
    streams: &mut BTreeMap<u64, StreamState>,
    shared: &ShardShared,
) {
    let now = Instant::now();
    let expired: Vec<u64> = streams
        .iter()
        .filter(|(_, st)| st.deadline.is_some_and(|d| now >= d))
        .map(|(&id, _)| id)
        .collect();
    for id in expired {
        engine.cancel(id);
        engine.release(id);
        if let Some(st) = streams.remove(&id) {
            let _ = st.events.send(TokenEvent::Timeout);
        }
        shared.requests_timed_out.fetch_add(1, Ordering::SeqCst);
        log::debug!("request {id} exceeded its deadline; residency released");
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_cmd<R: ModelRunner>(
    shard: usize,
    cmd: WorkerMsg,
    engine: &mut Engine<R>,
    streams: &mut BTreeMap<u64, StreamState>,
    draining: &mut bool,
    cfg: &GatewayConfig,
    shared: &ShardShared,
    step_ring: &VecDeque<StepRecord>,
) {
    match cmd {
        WorkerMsg::Submit { mut request, events, deadline, rid } => {
            if *draining {
                let queued = engine.scheduler().queued();
                let _ = events.send(TokenEvent::Rejected { queued, draining: true });
                return;
            }
            request.arrival_s = engine.clock();
            let id = request.id;
            let prompt_tokens = request.prompt.len();
            if engine.try_submit(request) {
                streams.insert(id, StreamState { events, sent: 0, deadline, last_token_at: None });
                if trace::armed() {
                    trace::instant(
                        "queued",
                        "request",
                        id,
                        vec![("prompt_tokens", prompt_tokens.to_string())],
                    );
                }
                match &rid {
                    Some(r) => log::debug!(
                        "request {id} rid={r}: queued on shard {shard} ({prompt_tokens} prompt tokens)"
                    ),
                    None => log::debug!(
                        "request {id}: queued on shard {shard} ({prompt_tokens} prompt tokens)"
                    ),
                }
            } else {
                let queued = engine.scheduler().queued();
                let _ = events.send(TokenEvent::Rejected { queued, draining: false });
                log::debug!(
                    "request {id}: rejected, shard {shard} admission queue full ({queued} queued)"
                );
            }
        }
        WorkerMsg::Cancel { id } => {
            streams.remove(&id);
            engine.cancel(id);
            engine.release(id);
            if trace::armed() {
                trace::instant("cancelled", "request", id, vec![("why", "client".into())]);
            }
            log::debug!("request {id}: cancelled by client; residency released");
        }
        WorkerMsg::Scrape { reply } => {
            let _ = reply.send(render_metrics(engine, streams.len(), &cfg.metrics_prefix, shared));
        }
        WorkerMsg::DebugSteps { reply } => {
            let _ = reply.send(debug_steps_json(step_ring).pretty());
        }
        WorkerMsg::DebugTree { reply } => {
            let _ = reply.send(debug_tree_json(engine).pretty());
        }
        WorkerMsg::Drain => *draining = true,
    }
}

/// `/debug/steps` body: the ring of recent engine steps, newest last, with
/// per-phase wall times in seconds.
fn debug_steps_json(ring: &VecDeque<StepRecord>) -> Json {
    let steps: Vec<Json> = ring
        .iter()
        .map(|r| {
            let mut s = Json::obj();
            s.set("seq", r.seq).set("ts_ms", r.ts_ms).set("total_s", r.timing.total_s);
            let mut phases = Json::obj();
            for (name, secs) in r.timing.phases() {
                phases.set(name, secs);
            }
            s.set("phases", phases)
                .set("decode_batch", r.timing.decode_batch)
                .set("prefill_slices", r.timing.prefill_slices)
                .set("admitted", r.timing.admitted)
                .set("finished", r.timing.finished);
            s
        })
        .collect();
    let mut j = Json::obj();
    j.set("count", steps.len()).set("capacity", STEP_RING_CAP).set("steps", steps);
    j
}

/// `/debug/tree` body: a residency snapshot of the prefix tree — sharing
/// ratios, shared-vs-private split of the live decode context, context-cache
/// hit rate, pool occupancy, and per-pin retention residency.
fn debug_tree_json<R: ModelRunner>(engine: &Engine<R>) -> Json {
    let tree = engine.tree();
    let stats = tree.sharing_stats();
    let (rebuilds, hits) = tree.context_stats();
    let pool = tree.pool();
    let chunk_size = tree.shape().chunk_size.max(1);

    let mut j = Json::obj();
    j.set("sequences", tree.num_sequences())
        .set("epoch", tree.epoch())
        .set("generation", tree.generation());

    let mut tokens = Json::obj();
    tokens
        .set("logical", stats.logical_tokens)
        .set("physical", stats.physical_tokens)
        .set("sharing_ratio", stats.sharing_ratio());
    j.set("tokens", tokens);

    let mut chunks = Json::obj();
    chunks
        .set("nodes", stats.chunks)
        .set("in_use", pool.in_use())
        .set("allocated", pool.allocated())
        .set("in_use_bytes", pool.in_use_bytes())
        .set("resident_bytes", pool.resident_bytes());
    j.set("chunks", chunks);

    // Deepest sequence in chunk hops — how long the phase-1 chunk-first
    // walk is for the worst-case sequence.
    let max_depth = tree
        .sequence_ids()
        .into_iter()
        .filter_map(|s| tree.sequence_len(s))
        .map(|len| len.div_ceil(chunk_size))
        .max()
        .unwrap_or(0);
    j.set("max_chunk_depth", max_depth);

    // Shared vs private split of the *current decode context*: a chunk is
    // shared when its row interval covers more than one sequence (phase-1
    // chunk-first work), private otherwise (phase-2 seq-first work).
    let ctx = tree.context_fresh();
    let mut shared_chunks = 0usize;
    let mut private_chunks = 0usize;
    let mut shared_tokens = 0usize;
    let mut private_tokens = 0usize;
    for e in ctx.shared() {
        shared_chunks += 1;
        shared_tokens += pool.get(e.chunk).len();
    }
    for e in ctx.private() {
        private_chunks += 1;
        private_tokens += pool.get(e.chunk).len();
    }
    let mut context = Json::obj();
    context
        .set("shared_chunks", shared_chunks)
        .set("private_chunks", private_chunks)
        .set("shared_tokens", shared_tokens)
        .set("private_tokens", private_tokens)
        .set("cache_rebuilds", rebuilds)
        .set("cache_hits", hits)
        .set("cache_hit_rate", if rebuilds + hits > 0 {
            hits as f64 / (rebuilds + hits) as f64
        } else {
            0.0
        });
    j.set("context", context);

    let mut retain = Json::obj();
    match engine.retainer() {
        Some(r) => {
            retain
                .set("enabled", true)
                .set("budget_chunks", r.budget_chunks())
                .set("pinned_count", r.pinned_count())
                .set("pinned_tokens", r.pinned_tokens())
                .set("evicted_pins_total", r.evicted_pins_total())
                .set("evicted_chunks_total", r.evicted_chunks_total());
            let (hot, int8, spilled) = r.tier_counts();
            retain
                .set("tier_hot", hot)
                .set("tier_int8", int8)
                .set("tier_spilled", spilled)
                .set("promotions_total", r.promotions_total())
                .set("demotions_total", r.demotions_total());
            let pins: Vec<Json> = r
                .pin_residency()
                .into_iter()
                .map(|(prefix_tokens, tokens, lru_age, tier)| {
                    let mut p = Json::obj();
                    p.set("prefix_tokens", prefix_tokens)
                        .set("tokens", tokens)
                        .set("lru_age", lru_age)
                        .set("tier", tier);
                    p
                })
                .collect();
            retain.set("pins", pins);
        }
        None => {
            retain.set("enabled", false);
        }
    }
    j.set("retain", retain);
    j
}

/// The per-shard `/metrics` document: the engine's request/step series plus
/// shard liveness gauges (queue depth, admission rejections, chunk
/// occupancy) and the supervisor's failure-domain counters. With N > 1
/// shards the router aggregates N of these documents (cluster rollups plus
/// `shard="N"` series); with one shard this document passes through
/// byte-for-byte.
fn render_metrics<R: ModelRunner>(
    engine: &Engine<R>,
    live_streams: usize,
    prefix: &str,
    shared: &ShardShared,
) -> String {
    let mut out = render_exposition(engine.metrics(), prefix);
    // True Prometheus histograms (cumulative `le` buckets + _sum/_count):
    // request latency distributions and per-phase step timing, so p50/p99
    // are computable server-side instead of from client-side sampling.
    let m = engine.metrics();
    push_histogram(
        &mut out,
        prefix,
        "ttft_seconds",
        "time to first token (seconds), per finished request",
        &m.ttft_seconds,
    );
    push_histogram(
        &mut out,
        prefix,
        "inter_token_seconds",
        "gap between consecutive streamed tokens of one request (seconds)",
        &m.inter_token_seconds,
    );
    push_histogram(
        &mut out,
        prefix,
        "step_duration_seconds",
        "wall time of one engine step (seconds)",
        &m.step_duration_seconds,
    );
    let phase_children: Vec<(Vec<(&str, String)>, &crate::util::stats::LogHistogram)> = m
        .step_phases()
        .map(|(phase, h)| (vec![("phase", phase.to_string())], h))
        .collect();
    push_histogram_family(
        &mut out,
        prefix,
        "step_phase_seconds",
        "wall time per engine-step phase (seconds); chunk_first/seq_first are the kernel's two partition phases",
        &phase_children,
    );
    // Failure-domain observability: panic/rebuild/timeout/stall counters
    // plus a live invariant probe, so chaos tests (and dashboards) can
    // verify recovery from the outside.
    push_gauge(
        &mut out,
        prefix,
        "engine_panics_total",
        "engine steps that panicked and were recovered by the supervisor",
        shared.engine_panics.load(Ordering::SeqCst) as f64,
    );
    push_gauge(
        &mut out,
        prefix,
        "engine_rebuilds_total",
        "full engine rebuilds after broken tree invariants",
        shared.engine_rebuilds.load(Ordering::SeqCst) as f64,
    );
    push_gauge(
        &mut out,
        prefix,
        "requests_timed_out_total",
        "requests terminated by their deadline_ms",
        shared.requests_timed_out.load(Ordering::SeqCst) as f64,
    );
    push_gauge(
        &mut out,
        prefix,
        "watchdog_stalls_total",
        "stepper stalls detected by the watchdog",
        shared.watchdog_stalls.load(Ordering::SeqCst) as f64,
    );
    push_gauge(
        &mut out,
        prefix,
        "step_retries_total",
        "engine step retries after transient errors",
        shared.step_retries.load(Ordering::SeqCst) as f64,
    );
    let failed_rows: Vec<(Vec<(&str, String)>, f64)> = [
        ("panic", shared.failed_panic.load(Ordering::SeqCst)),
        ("error", shared.failed_error.load(Ordering::SeqCst)),
        ("rebuild", shared.failed_rebuild.load(Ordering::SeqCst)),
    ]
    .iter()
    .map(|(reason, n)| (vec![("reason", reason.to_string())], *n as f64))
    .collect();
    push_labeled_series(
        &mut out,
        prefix,
        "requests_failed_total",
        "requests terminated by the supervisor, by reason",
        &failed_rows,
    );
    push_gauge(
        &mut out,
        prefix,
        "tree_invariants_ok",
        "1 while PrefixTree::check_invariants passes (0 = structural damage)",
        if engine.tree().check_invariants().is_ok() { 1.0 } else { 0.0 },
    );
    let sched = engine.scheduler();
    push_gauge(&mut out, prefix, "queue_depth", "requests waiting for admission", sched.queued() as f64);
    push_gauge(
        &mut out,
        prefix,
        "active_sequences",
        "sequences in the decode batch",
        sched.batch_size() as f64,
    );
    push_gauge(
        &mut out,
        prefix,
        "admission_rejections_total",
        "requests rejected by admission control (HTTP 429)",
        sched.admission_rejections() as f64,
    );
    push_gauge(&mut out, prefix, "live_streams", "connected SSE token streams", live_streams as f64);
    // Chunked-prefill liveness: queue depth, slice throughput, and the
    // configured per-step budget, so a dashboard can see interleaving
    // (prefill_chunks_total advancing while decode_steps_total advances)
    // and spot a starved prefill queue.
    let stats = engine.stats();
    push_gauge(
        &mut out,
        prefix,
        "prefill_queue_depth",
        "admitted requests whose prompts are still prefilling",
        sched.prefill_depth() as f64,
    );
    push_gauge(
        &mut out,
        prefix,
        "prefill_chunks_total",
        "prefill slices executed (one per prompt when monolithic)",
        stats.prefill_chunks_total as f64,
    );
    push_gauge(
        &mut out,
        prefix,
        "prefill_deferrals_total",
        "requests whose first slice deferred to an in-progress prefix-sharing leader",
        stats.prefill_deferrals as f64,
    );
    push_gauge(
        &mut out,
        prefix,
        "decode_steps_total",
        "batched decode steps executed",
        stats.decode_steps as f64,
    );
    push_gauge(
        &mut out,
        prefix,
        "step_token_budget",
        "configured per-step token budget (0 = unbounded)",
        sched.step_token_budget().unwrap_or(0) as f64,
    );
    push_gauge(
        &mut out,
        prefix,
        "prefill_chunk_tokens",
        "configured prefill slice granularity in tokens (0 = monolithic)",
        if sched.prefill_chunk_tokens() == usize::MAX {
            0.0
        } else {
            sched.prefill_chunk_tokens() as f64
        },
    );
    push_gauge(
        &mut out,
        prefix,
        "chunks_in_use",
        "KV chunks currently referenced by live sequences or pins",
        engine.tree().pool().in_use() as f64,
    );
    push_gauge(
        &mut out,
        prefix,
        "chunks_allocated",
        "KV chunks ever allocated by the pool",
        engine.tree().pool().allocated() as f64,
    );
    // Byte-level KV accounting at the *actual* storage dtype (f16 halves
    // these relative to f32), plus the dtype itself as an info gauge so
    // dashboards can group byte series by format.
    let pool = engine.tree().pool();
    push_gauge(
        &mut out,
        prefix,
        "kv_bytes_in_use",
        "KV bytes referenced by live sequences or pins, at the storage dtype",
        pool.in_use_bytes() as f64,
    );
    push_gauge(
        &mut out,
        prefix,
        "kv_bytes_resident",
        "KV bytes ever allocated by the pool, at the storage dtype",
        pool.resident_bytes() as f64,
    );
    push_labeled_gauge(
        &mut out,
        prefix,
        "kv_dtype_info",
        "active KV storage dtype (value is always 1)",
        &[("dtype", engine.tree().shape().dtype.label())],
        1.0,
    );
    // Kernel-path observability: which SIMD ISA the attention kernels
    // dispatch to and how the thread pool is placed — bench runs grab
    // these so recorded numbers say what they measured.
    push_labeled_gauge(
        &mut out,
        prefix,
        "simd_isa_info",
        "active attention-kernel SIMD ISA path (value is always 1)",
        &[("isa", crate::util::simd::active().label())],
        1.0,
    );
    let placement = crate::util::threadpool::placement();
    push_labeled_gauge(
        &mut out,
        prefix,
        "pool_affinity_info",
        "thread-pool core-affinity policy (value is always 1)",
        &[("mode", crate::util::threadpool::affinity_mode())],
        1.0,
    );
    push_gauge(
        &mut out,
        prefix,
        "pool_workers",
        "live thread-pool workers across the process",
        placement.workers as f64,
    );
    push_gauge(
        &mut out,
        prefix,
        "pool_workers_pinned",
        "live thread-pool workers successfully pinned to a core",
        placement.pinned as f64,
    );
    // Scheduling-policy observability: the active policy as an info
    // gauge, bounded-cardinality per-tenant fairness counters, and the
    // amortized pin-eviction spend.
    let planner = engine.planner();
    push_labeled_gauge(
        &mut out,
        prefix,
        "sched_policy_info",
        "active admission-scheduling policy (value is always 1)",
        &[("policy", planner.policy_kind().label())],
        1.0,
    );
    let (tenants, overflow) = planner.tenant_counters();
    let tenant_rows = |pick: fn(&crate::coordinator::TenantCounters) -> u64| {
        let mut rows: Vec<(Vec<(&str, String)>, f64)> = tenants
            .iter()
            .map(|(t, c)| (vec![("tenant", t.to_string())], pick(c) as f64))
            .collect();
        let o = pick(overflow);
        if o > 0 {
            rows.push((vec![("tenant", "other".to_string())], o as f64));
        }
        rows
    };
    push_labeled_series(
        &mut out,
        prefix,
        "tenant_admitted_total",
        "requests admitted into the prefill queue, per tenant (bounded cardinality)",
        &tenant_rows(|c| c.admitted),
    );
    push_labeled_series(
        &mut out,
        prefix,
        "tenant_deferred_total",
        "steps a tenant's queued request was passed over by a later arrival, per tenant",
        &tenant_rows(|c| c.deferred),
    );
    push_labeled_series(
        &mut out,
        prefix,
        "tenant_decode_tokens_total",
        "decode tokens produced per tenant (bounded cardinality)",
        &tenant_rows(|c| c.decode_tokens),
    );
    push_gauge(
        &mut out,
        prefix,
        "decode_lag_max",
        "highest consecutive decode-steps any sequence sat out under partial decode batches",
        planner.max_decode_lag() as f64,
    );
    if let Some(retainer) = engine.retainer() {
        push_gauge(
            &mut out,
            prefix,
            "eviction_tokens_total",
            "tokens charged for amortized pin eviction",
            retainer.eviction_tokens_total() as f64,
        );
        push_gauge(
            &mut out,
            prefix,
            "evicted_chunks_total",
            "KV chunks returned to the pool by pin eviction",
            retainer.evicted_chunks_total() as f64,
        );
        push_gauge(
            &mut out,
            prefix,
            "retained_pins",
            "prefixes currently pinned by the retainer",
            retainer.pinned_count() as f64,
        );
        // Tiered retention: bytes and pins per tier, promote/demote flow
        // counters, and the promote/demote latency distributions the
        // tiered bench scrapes for its p50/p99 headline.
        let tier_bytes: Vec<(Vec<(&str, String)>, f64)> = retainer
            .tier_bytes(engine.tree())
            .iter()
            .map(|&(tier, bytes)| (vec![("tier", tier.to_string())], bytes as f64))
            .collect();
        push_labeled_series(
            &mut out,
            prefix,
            "kv_tier_bytes",
            "KV bytes retained per tier (hot = tree-resident, int8 = demoted in memory, spilled = on disk)",
            &tier_bytes,
        );
        let (hot, int8, spilled) = retainer.tier_counts();
        let tier_pins: Vec<(Vec<(&str, String)>, f64)> = [
            ("hot", hot),
            ("int8", int8),
            ("spilled", spilled),
        ]
        .iter()
        .map(|&(tier, n)| (vec![("tier", tier.to_string())], n as f64))
        .collect();
        push_labeled_series(
            &mut out,
            prefix,
            "kv_tier_pins",
            "retained pins per tier",
            &tier_pins,
        );
        push_gauge(
            &mut out,
            prefix,
            "kv_promotions_total",
            "demoted/spilled prefixes promoted back into the tree",
            retainer.promotions_total() as f64,
        );
        push_gauge(
            &mut out,
            prefix,
            "kv_demotions_total",
            "hot pinned prefixes demoted to the int8 tier",
            retainer.demotions_total() as f64,
        );
        push_gauge(
            &mut out,
            prefix,
            "kv_spills_total",
            "int8 pinned prefixes spilled to disk",
            retainer.spills_total() as f64,
        );
        push_gauge(
            &mut out,
            prefix,
            "kv_spill_load_failures_total",
            "promotions that found the spill file missing or corrupt (degraded to a cache miss)",
            retainer.spill_load_failures_total() as f64,
        );
        push_histogram(
            &mut out,
            prefix,
            "kv_promote_seconds",
            "latency of promoting one prefix back into the tree (includes spill-file load)",
            retainer.promote_hist(),
        );
        push_histogram(
            &mut out,
            prefix,
            "kv_demote_seconds",
            "latency of demoting one prefix (quantize; includes spill-file write)",
            retainer.demote_hist(),
        );
    }
    out
}
