//! Minimal blocking HTTP client for the gateway's endpoints.
//!
//! Used by the `bench-http` load generator and the socket-level
//! integration tests, so the gateway's wire format is exercised from both
//! ends without any external HTTP dependency.

use crate::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// A complete (non-streaming) HTTP response.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub body: String,
    /// Parsed `Retry-After` header (seconds), when the server sent one
    /// (429 backpressure, 503 draining/degraded).
    pub retry_after: Option<u64>,
}

fn connect(addr: &str, timeout: Duration) -> anyhow::Result<TcpStream> {
    let sock = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow::anyhow!("address {addr:?} resolved to nothing"))?;
    let stream = TcpStream::connect_timeout(&sock, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let _ = stream.set_nodelay(true);
    Ok(stream)
}

fn read_head<R: BufRead>(reader: &mut R) -> anyhow::Result<(u16, Option<u64>)> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        anyhow::bail!("server closed the connection before responding");
    }
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow::anyhow!("malformed status line {line:?}"))?
        .parse()?;
    // Consume headers up to the blank line; `Connection: close` framing
    // means the body simply runs to EOF. `Retry-After` is the one header
    // the retry helper cares about.
    let mut retry_after = None;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            anyhow::bail!("EOF inside response headers");
        }
        let trimmed = h.trim_end();
        if trimmed.is_empty() {
            return Ok((status, retry_after));
        }
        if let Some((name, value)) = trimmed.split_once(':') {
            if name.trim().eq_ignore_ascii_case("retry-after") {
                retry_after = value.trim().parse().ok();
            }
        }
    }
}

/// Blocking GET returning the whole body (used for `/healthz`, `/metrics`).
pub fn get(addr: &str, path: &str, timeout: Duration) -> anyhow::Result<Response> {
    let mut stream = connect(addr, timeout)?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let (status, retry_after) = read_head(&mut reader)?;
    let mut body = String::new();
    reader.read_to_string(&mut body)?;
    Ok(Response { status, body, retry_after })
}

/// Extract a gauge's value from a Prometheus exposition document by series
/// name suffix (prefix-agnostic).
pub fn gauge_value(exposition: &str, name: &str) -> Option<f64> {
    let suffix = format!("_{name}");
    for line in exposition.lines() {
        if line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(series), Some(value)) = (parts.next(), parts.next()) else { continue };
        if series.ends_with(&suffix) {
            return value.parse().ok();
        }
    }
    None
}

/// Extract a labeled gauge sample from an exposition document: the series
/// whose name ends with `_{name}` and whose label set contains
/// `label="value"` (e.g. `tenant_admitted_total` with `tenant`/`"1"`).
pub fn labeled_gauge_value(
    exposition: &str,
    name: &str,
    label: &str,
    value: &str,
) -> Option<f64> {
    let suffix = format!("_{name}");
    let pair = format!("{label}=\"{value}\"");
    for line in exposition.lines() {
        if line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(series), Some(sample)) = (parts.next(), parts.next()) else { continue };
        let Some(brace) = series.find('{') else { continue };
        if series[..brace].ends_with(&suffix) && series[brace..].contains(&pair) {
            return sample.parse().ok();
        }
    }
    None
}

/// One event of a `/v1/generate` SSE stream. `Done`, `Error`, and
/// `Timeout` are terminal: the gateway guarantees every stream ends with
/// exactly one of them (no client ever hangs to its socket timeout).
#[derive(Debug, Clone, PartialEq)]
pub enum StreamEvent {
    Token { index: usize, token: u32 },
    Done { completion_tokens: usize },
    /// The request failed server-side (engine panic quarantine, persistent
    /// runner error, full engine rebuild).
    Error { message: String },
    /// The request exceeded its `deadline_ms`.
    Timeout,
}

/// An open `/v1/generate` call: status plus, on 200, the live SSE stream.
pub struct GenerateStream {
    status: u16,
    reader: Option<BufReader<TcpStream>>,
    /// Response body for non-200 statuses (429 backpressure, 400, ...).
    pub error_body: String,
    /// Parsed `Retry-After` header (seconds), when present.
    pub retry_after: Option<u64>,
}

impl GenerateStream {
    pub fn status(&self) -> u16 {
        self.status
    }

    /// Next SSE event; `None` once the server closes the stream (or for
    /// non-200 responses).
    pub fn next_event(&mut self) -> anyhow::Result<Option<StreamEvent>> {
        let Some(reader) = self.reader.as_mut() else {
            return Ok(None);
        };
        loop {
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                return Ok(None);
            }
            let trimmed = line.trim_end();
            let Some(data) = trimmed.strip_prefix("data: ") else { continue };
            let j = Json::parse(data).map_err(|e| anyhow::anyhow!("bad SSE payload: {e}"))?;
            if j.get("done").and_then(|d| d.as_bool()).unwrap_or(false) {
                let n =
                    j.get("completion_tokens").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize;
                return Ok(Some(StreamEvent::Done { completion_tokens: n }));
            }
            if j.get("timeout").and_then(|t| t.as_bool()).unwrap_or(false) {
                return Ok(Some(StreamEvent::Timeout));
            }
            if let Some(message) = j.get("error").and_then(|e| e.as_str()) {
                return Ok(Some(StreamEvent::Error { message: message.to_string() }));
            }
            let index = j.get("index").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize;
            let token = j.get("token").and_then(|v| v.as_f64()).unwrap_or(0.0) as u32;
            return Ok(Some(StreamEvent::Token { index, token }));
        }
    }

    /// Drop the connection without reading the remaining tokens —
    /// exercises server-side disconnect cancellation.
    pub fn abandon(self) {}
}

/// POST `/v1/generate`; returns once the response head arrived. For a 200
/// the stream is live: pull tokens with [`GenerateStream::next_event`].
pub fn generate(addr: &str, body: &Json, timeout: Duration) -> anyhow::Result<GenerateStream> {
    let mut stream = connect(addr, timeout)?;
    let payload = body.to_string();
    write!(
        stream,
        "POST /v1/generate HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        payload.len()
    )?;
    stream.write_all(payload.as_bytes())?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let (status, retry_after) = read_head(&mut reader)?;
    if status != 200 {
        let mut error_body = String::new();
        let _ = reader.read_to_string(&mut error_body);
        return Ok(GenerateStream { status, reader: None, error_body, retry_after });
    }
    Ok(GenerateStream { status, reader: Some(reader), error_body: String::new(), retry_after })
}

/// [`generate`] with one bounded retry: a 429/503 response (or a failed
/// connect) is retried once after honoring the server's `Retry-After`
/// (capped at `max_backoff`; defaulting to 100ms when absent). Returns the
/// final stream plus how many retries were spent (0 or 1), so load
/// generators can report retried vs. failed counts separately.
pub fn generate_with_retry(
    addr: &str,
    body: &Json,
    timeout: Duration,
    max_backoff: Duration,
) -> anyhow::Result<(GenerateStream, usize)> {
    let backoff = match generate(addr, body, timeout) {
        Ok(stream) if stream.status != 429 && stream.status != 503 => return Ok((stream, 0)),
        Ok(stream) => stream
            .retry_after
            .map(Duration::from_secs)
            .unwrap_or_else(|| Duration::from_millis(100)),
        Err(_) => Duration::from_millis(100),
    };
    std::thread::sleep(backoff.min(max_backoff));
    let stream = generate(addr, body, timeout)?;
    Ok((stream, 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauge_value_parses_exposition() {
        let doc = "# HELP g_x help\n# TYPE g_x gauge\ng_x 3.5\ng_queue_depth 7\n";
        assert_eq!(gauge_value(doc, "x"), Some(3.5));
        assert_eq!(gauge_value(doc, "queue_depth"), Some(7.0));
        assert_eq!(gauge_value(doc, "missing"), None);
    }

    #[test]
    fn labeled_gauge_value_matches_label_pairs() {
        let doc = "# HELP g_tenant_admitted_total h\n# TYPE g_tenant_admitted_total gauge\n\
                   g_tenant_admitted_total{tenant=\"0\"} 4\n\
                   g_tenant_admitted_total{tenant=\"1\"} 1.5\n\
                   g_sched_policy_info{policy=\"aging\"} 1\n";
        assert_eq!(labeled_gauge_value(doc, "tenant_admitted_total", "tenant", "1"), Some(1.5));
        assert_eq!(labeled_gauge_value(doc, "tenant_admitted_total", "tenant", "9"), None);
        assert_eq!(labeled_gauge_value(doc, "sched_policy_info", "policy", "aging"), Some(1.0));
    }
}
