//! Minimal blocking HTTP client for the gateway's endpoints.
//!
//! Used by the `bench-http` load generator and the socket-level
//! integration tests, so the gateway's wire format is exercised from both
//! ends without any external HTTP dependency.

use crate::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// A complete (non-streaming) HTTP response.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub body: String,
    /// Parsed `Retry-After` header (seconds), when the server sent one
    /// (429 backpressure, 503 draining/degraded).
    pub retry_after: Option<u64>,
    /// Echoed `X-Request-Id` header, when the server sent one.
    pub request_id: Option<String>,
}

fn connect(addr: &str, timeout: Duration) -> anyhow::Result<TcpStream> {
    let sock = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow::anyhow!("address {addr:?} resolved to nothing"))?;
    let stream = TcpStream::connect_timeout(&sock, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let _ = stream.set_nodelay(true);
    Ok(stream)
}

fn read_head<R: BufRead>(reader: &mut R) -> anyhow::Result<(u16, Option<u64>, Option<String>)> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        anyhow::bail!("server closed the connection before responding");
    }
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow::anyhow!("malformed status line {line:?}"))?
        .parse()?;
    // Consume headers up to the blank line; `Connection: close` framing
    // means the body simply runs to EOF. `Retry-After` (backpressure) and
    // `X-Request-Id` (correlation echo) are the headers callers care about.
    let mut retry_after = None;
    let mut request_id = None;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            anyhow::bail!("EOF inside response headers");
        }
        let trimmed = h.trim_end();
        if trimmed.is_empty() {
            return Ok((status, retry_after, request_id));
        }
        if let Some((name, value)) = trimmed.split_once(':') {
            if name.trim().eq_ignore_ascii_case("retry-after") {
                retry_after = value.trim().parse().ok();
            } else if name.trim().eq_ignore_ascii_case("x-request-id") {
                request_id = Some(value.trim().to_string());
            }
        }
    }
}

/// Blocking GET returning the whole body (used for `/healthz`, `/metrics`).
pub fn get(addr: &str, path: &str, timeout: Duration) -> anyhow::Result<Response> {
    let mut stream = connect(addr, timeout)?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let (status, retry_after, request_id) = read_head(&mut reader)?;
    let mut body = String::new();
    reader.read_to_string(&mut body)?;
    Ok(Response { status, body, retry_after, request_id })
}

/// Blocking POST with an empty body (admin endpoints: `/admin/drain`,
/// `/admin/join`).
pub fn post(addr: &str, path: &str, timeout: Duration) -> anyhow::Result<Response> {
    let mut stream = connect(addr, timeout)?;
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: 0\r\nConnection: close\r\n\r\n"
    )?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let (status, retry_after, request_id) = read_head(&mut reader)?;
    let mut body = String::new();
    reader.read_to_string(&mut body)?;
    Ok(Response { status, body, retry_after, request_id })
}

/// Extract a gauge's value from a Prometheus exposition document by series
/// name suffix (prefix-agnostic).
pub fn gauge_value(exposition: &str, name: &str) -> Option<f64> {
    let suffix = format!("_{name}");
    for line in exposition.lines() {
        if line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(series), Some(value)) = (parts.next(), parts.next()) else { continue };
        if series.ends_with(&suffix) {
            return value.parse().ok();
        }
    }
    None
}

/// Extract a labeled gauge sample from an exposition document: the series
/// whose name ends with `_{name}` and whose label set contains
/// `label="value"` (e.g. `tenant_admitted_total` with `tenant`/`"1"`).
pub fn labeled_gauge_value(
    exposition: &str,
    name: &str,
    label: &str,
    value: &str,
) -> Option<f64> {
    let suffix = format!("_{name}");
    let pair = format!("{label}=\"{value}\"");
    for line in exposition.lines() {
        if line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(series), Some(sample)) = (parts.next(), parts.next()) else { continue };
        let Some(brace) = series.find('{') else { continue };
        if series[..brace].ends_with(&suffix) && series[brace..].contains(&pair) {
            return sample.parse().ok();
        }
    }
    None
}

/// Split a sample's series into `(name, label-body)`; the label body is
/// the text between the braces ("" when unlabeled).
fn split_series(series: &str) -> (&str, &str) {
    match series.split_once('{') {
        Some((name, rest)) => (name, rest.strip_suffix('}').unwrap_or(rest)),
        None => (series, ""),
    }
}

/// A server-side histogram parsed from `_bucket`/`_sum`/`_count` lines of
/// an exposition document.
#[derive(Debug, Clone)]
pub struct HistogramSnapshot {
    /// `(le upper bound, cumulative count)` in document order; the last
    /// entry is the `+Inf` bucket.
    pub buckets: Vec<(f64, f64)>,
    pub sum: f64,
    pub count: u64,
}

impl HistogramSnapshot {
    /// Estimate the q-th quantile (q in [0,1]) by linear interpolation
    /// inside the first bucket whose cumulative count reaches the rank —
    /// the same estimate `histogram_quantile()` makes in PromQL. Returns
    /// the highest finite bound when the rank lands in `+Inf`, and NaN
    /// for an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 || self.buckets.is_empty() {
            return f64::NAN;
        }
        let rank = q.clamp(0.0, 1.0) * self.count as f64;
        let mut lower = 0.0f64;
        let mut prev_cum = 0.0f64;
        for &(le, cum) in &self.buckets {
            if cum >= rank {
                if le.is_infinite() {
                    return lower;
                }
                let in_bucket = cum - prev_cum;
                if in_bucket <= 0.0 {
                    return le;
                }
                let frac = ((rank - prev_cum) / in_bucket).clamp(0.0, 1.0);
                return lower + (le - lower) * frac;
            }
            if le.is_finite() {
                lower = le;
            }
            prev_cum = cum;
        }
        lower
    }
}

/// Parse one histogram child from an exposition document by series name
/// suffix (prefix-agnostic, like [`gauge_value`]). `label` selects a child
/// of a labeled family (e.g. `("phase", "chunk_first")`); `None` selects
/// the *unlabeled* child. Matching is on the exact label set minus `le`,
/// so in an aggregated document the unlabeled cluster rollup and its
/// per-shard `shard="N"` children are distinct, non-mixing snapshots.
pub fn histogram_snapshot(
    exposition: &str,
    name: &str,
    label: Option<(&str, &str)>,
) -> Option<HistogramSnapshot> {
    let bucket_suffix = format!("_{name}_bucket");
    let sum_suffix = format!("_{name}_sum");
    let count_suffix = format!("_{name}_count");
    let want = label.map(|(k, v)| format!("{k}=\"{v}\""));
    let mut buckets: Vec<(f64, f64)> = Vec::new();
    let mut sum: Option<f64> = None;
    let mut count: Option<u64> = None;
    for line in exposition.lines() {
        if line.starts_with('#') {
            continue;
        }
        let Some((series, value)) = line.rsplit_once(' ') else { continue };
        let (sname, labels) = split_series(series);
        let child: Vec<&str> = labels
            .split(',')
            .filter(|p| !p.is_empty() && !p.starts_with("le=\""))
            .collect();
        let label_ok = match &want {
            Some(w) => child.len() == 1 && child[0] == w.as_str(),
            None => child.is_empty(),
        };
        if !label_ok {
            continue;
        }
        if sname.ends_with(&bucket_suffix) {
            let Some(bound) = labels
                .split(',')
                .find_map(|p| p.strip_prefix("le=\"").and_then(|r| r.strip_suffix('"')))
            else {
                continue;
            };
            let le = if bound == "+Inf" { f64::INFINITY } else { bound.parse().ok()? };
            let cum: f64 = value.parse().ok()?;
            buckets.push((le, cum));
        } else if sname.ends_with(&sum_suffix) {
            sum = value.parse().ok();
        } else if sname.ends_with(&count_suffix) {
            count = value.parse().ok();
        }
    }
    if buckets.is_empty() {
        return None;
    }
    Some(HistogramSnapshot { buckets, sum: sum?, count: count? })
}

/// Convenience: the q-th quantile of a named (unlabeled) server-side
/// histogram; NaN when the document has no such family.
pub fn histogram_quantile(exposition: &str, name: &str, q: f64) -> f64 {
    histogram_snapshot(exposition, name, None).map(|h| h.quantile(q)).unwrap_or(f64::NAN)
}

/// Promtool-style exposition lint: returns one message per violation
/// (empty = clean). Checks that every sample's family has HELP and TYPE
/// metadata (at most once each), that no series repeats, and that each
/// histogram child has strictly increasing `le` bounds, monotone
/// cumulative counts, a terminal `+Inf` bucket agreeing with `_count`,
/// and a `_sum` sample.
pub fn lint_exposition(doc: &str) -> Vec<String> {
    use std::collections::{BTreeMap, BTreeSet};
    let mut violations: Vec<String> = Vec::new();
    if !doc.ends_with('\n') {
        violations.push("exposition must end with a trailing newline".to_string());
    }
    let mut help: BTreeSet<String> = BTreeSet::new();
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    for line in doc.lines() {
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap_or("").to_string();
            if !help.insert(name.clone()) {
                violations.push(format!("duplicate HELP for {name}"));
            }
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap_or("").to_string();
            let ty = it.next().unwrap_or("").to_string();
            if !matches!(ty.as_str(), "gauge" | "counter" | "histogram" | "summary" | "untyped") {
                violations.push(format!("invalid TYPE {ty:?} for {name}"));
            }
            if types.insert(name.clone(), ty).is_some() {
                violations.push(format!("duplicate TYPE for {name}"));
            }
        }
    }
    // Resolve a sample's family: `_bucket`/`_sum`/`_count` fold into their
    // base name only when the base is declared a histogram.
    let family_of = |sname: &str| -> String {
        for suffix in ["_bucket", "_sum", "_count"] {
            if let Some(base) = sname.strip_suffix(suffix) {
                if types.get(base).is_some_and(|t| t == "histogram") {
                    return base.to_string();
                }
            }
        }
        sname.to_string()
    };
    let mut seen: BTreeSet<String> = BTreeSet::new();
    // Histogram children keyed by (family, labels-without-le).
    let mut buckets: BTreeMap<(String, String), Vec<(f64, f64)>> = BTreeMap::new();
    let mut counts: BTreeMap<(String, String), f64> = BTreeMap::new();
    let mut sums: BTreeSet<(String, String)> = BTreeSet::new();
    for line in doc.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let Some((series, value)) = line.rsplit_once(' ') else {
            violations.push(format!("malformed sample line {line:?}"));
            continue;
        };
        let parsed: Option<f64> = value.parse().ok();
        if parsed.is_none() {
            violations.push(format!("non-numeric value in {line:?}"));
        }
        if !seen.insert(series.to_string()) {
            violations.push(format!("duplicate series {series}"));
        }
        let (sname, labels) = split_series(series);
        let family = family_of(sname);
        if !help.contains(&family) {
            violations.push(format!("series {series}: no HELP for family {family}"));
        }
        if !types.contains_key(&family) {
            violations.push(format!("series {series}: no TYPE for family {family}"));
        }
        if types.get(&family).is_some_and(|t| t == "histogram") {
            let mut le: Option<String> = None;
            let child: Vec<&str> = labels
                .split(',')
                .filter(|p| !p.is_empty())
                .filter(|p| {
                    match p.strip_prefix("le=\"").and_then(|r| r.strip_suffix('"')) {
                        Some(bound) => {
                            le = Some(bound.to_string());
                            false
                        }
                        None => true,
                    }
                })
                .collect();
            let key = (family.clone(), child.join(","));
            if sname.ends_with("_bucket") {
                let Some(bound) = le else {
                    violations.push(format!("bucket without le label: {series}"));
                    continue;
                };
                let b = if bound == "+Inf" {
                    f64::INFINITY
                } else {
                    match bound.parse::<f64>() {
                        Ok(x) => x,
                        Err(_) => {
                            violations.push(format!("unparseable le bound in {series}"));
                            continue;
                        }
                    }
                };
                buckets.entry(key).or_default().push((b, parsed.unwrap_or(f64::NAN)));
            } else if sname.ends_with("_count") {
                counts.insert(key, parsed.unwrap_or(f64::NAN));
            } else if sname.ends_with("_sum") {
                sums.insert(key);
            }
        }
    }
    for (key, bs) in &buckets {
        let label = |k: &(String, String)| {
            if k.1.is_empty() { k.0.clone() } else { format!("{}{{{}}}", k.0, k.1) }
        };
        let mut prev_le = f64::NEG_INFINITY;
        let mut prev_cum = -1.0f64;
        for &(le, cum) in bs {
            if le <= prev_le {
                violations.push(format!("{}: le bounds not increasing at {le}", label(key)));
            }
            if cum < prev_cum {
                violations
                    .push(format!("{}: cumulative bucket counts decrease at le {le}", label(key)));
            }
            prev_le = le;
            prev_cum = cum;
        }
        match bs.last() {
            Some(&(le, cum)) if le.is_infinite() => match counts.get(key) {
                Some(&c) if (c - cum).abs() < 1e-9 => {}
                Some(&c) => violations
                    .push(format!("{}: +Inf bucket {cum} != _count {c}", label(key))),
                None => violations.push(format!("{}: missing _count", label(key))),
            },
            _ => violations.push(format!("{}: missing le=\"+Inf\" bucket", label(key))),
        }
        if !sums.contains(key) {
            violations.push(format!("{}: missing _sum", label(key)));
        }
    }
    violations
}

/// One event of a `/v1/generate` SSE stream. `Done`, `Error`, and
/// `Timeout` are terminal: the gateway guarantees every stream ends with
/// exactly one of them (no client ever hangs to its socket timeout).
#[derive(Debug, Clone, PartialEq)]
pub enum StreamEvent {
    Token { index: usize, token: u32 },
    Done { completion_tokens: usize },
    /// The request failed server-side (engine panic quarantine, persistent
    /// runner error, full engine rebuild).
    Error { message: String },
    /// The request exceeded its `deadline_ms`.
    Timeout,
}

/// An open `/v1/generate` call: status plus, on 200, the live SSE stream.
pub struct GenerateStream {
    status: u16,
    reader: Option<BufReader<TcpStream>>,
    /// Response body for non-200 statuses (429 backpressure, 400, ...).
    pub error_body: String,
    /// Parsed `Retry-After` header (seconds), when present.
    pub retry_after: Option<u64>,
    /// Echoed `X-Request-Id` header, when the request carried one.
    pub request_id: Option<String>,
}

impl GenerateStream {
    pub fn status(&self) -> u16 {
        self.status
    }

    /// Next SSE event; `None` once the server closes the stream (or for
    /// non-200 responses).
    pub fn next_event(&mut self) -> anyhow::Result<Option<StreamEvent>> {
        let Some(reader) = self.reader.as_mut() else {
            return Ok(None);
        };
        loop {
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                return Ok(None);
            }
            let trimmed = line.trim_end();
            let Some(data) = trimmed.strip_prefix("data: ") else { continue };
            let j = Json::parse(data).map_err(|e| anyhow::anyhow!("bad SSE payload: {e}"))?;
            if j.get("done").and_then(|d| d.as_bool()).unwrap_or(false) {
                let n =
                    j.get("completion_tokens").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize;
                return Ok(Some(StreamEvent::Done { completion_tokens: n }));
            }
            if j.get("timeout").and_then(|t| t.as_bool()).unwrap_or(false) {
                return Ok(Some(StreamEvent::Timeout));
            }
            if let Some(message) = j.get("error").and_then(|e| e.as_str()) {
                return Ok(Some(StreamEvent::Error { message: message.to_string() }));
            }
            let index = j.get("index").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize;
            let token = j.get("token").and_then(|v| v.as_f64()).unwrap_or(0.0) as u32;
            return Ok(Some(StreamEvent::Token { index, token }));
        }
    }

    /// Drop the connection without reading the remaining tokens —
    /// exercises server-side disconnect cancellation.
    pub fn abandon(self) {}
}

/// POST `/v1/generate`; returns once the response head arrived. For a 200
/// the stream is live: pull tokens with [`GenerateStream::next_event`].
pub fn generate(addr: &str, body: &Json, timeout: Duration) -> anyhow::Result<GenerateStream> {
    generate_with_request_id(addr, body, timeout, None)
}

/// [`generate`] sending a client-chosen `X-Request-Id` header; the gateway
/// echoes it on the response head (SSE included) and tags its logs with
/// it, so one id correlates client, gateway, and shard records.
pub fn generate_with_request_id(
    addr: &str,
    body: &Json,
    timeout: Duration,
    request_id: Option<&str>,
) -> anyhow::Result<GenerateStream> {
    let mut stream = connect(addr, timeout)?;
    let payload = body.to_string();
    write!(
        stream,
        "POST /v1/generate HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n",
        payload.len()
    )?;
    if let Some(rid) = request_id {
        write!(stream, "X-Request-Id: {rid}\r\n")?;
    }
    write!(stream, "\r\n")?;
    stream.write_all(payload.as_bytes())?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let (status, retry_after, request_id) = read_head(&mut reader)?;
    if status != 200 {
        let mut error_body = String::new();
        let _ = reader.read_to_string(&mut error_body);
        return Ok(GenerateStream { status, reader: None, error_body, retry_after, request_id });
    }
    Ok(GenerateStream {
        status,
        reader: Some(reader),
        error_body: String::new(),
        retry_after,
        request_id,
    })
}

/// [`generate`] with one bounded retry: a 429/503 response (or a failed
/// connect) is retried once after honoring the server's `Retry-After`
/// (capped at `max_backoff`; defaulting to 100ms when absent). Returns the
/// final stream plus how many retries were spent (0 or 1), so load
/// generators can report retried vs. failed counts separately.
pub fn generate_with_retry(
    addr: &str,
    body: &Json,
    timeout: Duration,
    max_backoff: Duration,
) -> anyhow::Result<(GenerateStream, usize)> {
    let backoff = match generate(addr, body, timeout) {
        Ok(stream) if stream.status != 429 && stream.status != 503 => return Ok((stream, 0)),
        Ok(stream) => stream
            .retry_after
            .map(Duration::from_secs)
            .unwrap_or_else(|| Duration::from_millis(100)),
        Err(_) => Duration::from_millis(100),
    };
    std::thread::sleep(backoff.min(max_backoff));
    let stream = generate(addr, body, timeout)?;
    Ok((stream, 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauge_value_parses_exposition() {
        let doc = "# HELP g_x help\n# TYPE g_x gauge\ng_x 3.5\ng_queue_depth 7\n";
        assert_eq!(gauge_value(doc, "x"), Some(3.5));
        assert_eq!(gauge_value(doc, "queue_depth"), Some(7.0));
        assert_eq!(gauge_value(doc, "missing"), None);
    }

    #[test]
    fn histogram_snapshot_parses_real_exporter_output() {
        use crate::metrics::{push_histogram, push_histogram_family};
        use crate::util::stats::LogHistogram;
        let mut h = LogHistogram::time_seconds();
        for x in [0.001, 0.002, 0.002, 0.01, 0.05, 0.5] {
            h.record(x);
        }
        let mut doc = String::new();
        push_histogram(&mut doc, "gw", "ttft_seconds", "ttft", &h);
        let snap = histogram_snapshot(&doc, "ttft_seconds", None).expect("parses");
        assert_eq!(snap.count, 6);
        assert!((snap.sum - h.sum()).abs() < 1e-9);
        assert!(snap.buckets.last().unwrap().0.is_infinite());
        let p50 = snap.quantile(0.5);
        let p99 = snap.quantile(0.99);
        assert!(p50 > 0.0 && p50 < 0.05, "p50 {p50} out of range");
        assert!(p99 >= p50, "p99 {p99} < p50 {p50}");
        assert!(histogram_quantile(&doc, "ttft_seconds", 0.5) > 0.0);
        assert!(histogram_quantile(&doc, "missing", 0.5).is_nan());

        // Labeled children are selectable individually.
        let mut a = LogHistogram::time_seconds();
        a.record(0.003);
        let mut fam = String::new();
        push_histogram_family(
            &mut fam,
            "gw",
            "step_phase_seconds",
            "phases",
            &[(vec![("phase", "chunk_first".to_string())], &a)],
        );
        let child =
            histogram_snapshot(&fam, "step_phase_seconds", Some(("phase", "chunk_first")))
                .expect("labeled child parses");
        assert_eq!(child.count, 1);
        assert!(
            histogram_snapshot(&fam, "step_phase_seconds", Some(("phase", "seq_first"))).is_none()
        );
    }

    #[test]
    fn lint_accepts_exporter_output_and_flags_violations() {
        use crate::metrics::push_histogram;
        use crate::util::stats::LogHistogram;
        let mut h = LogHistogram::time_seconds();
        h.record(0.004);
        let mut doc = String::new();
        doc.push_str("# HELP gw_depth queue depth\n# TYPE gw_depth gauge\ngw_depth 3\n");
        push_histogram(&mut doc, "gw", "ttft_seconds", "ttft", &h);
        assert_eq!(lint_exposition(&doc), Vec::<String>::new());

        // No trailing newline.
        assert!(lint_exposition("# HELP x h\n# TYPE x gauge\nx 1")
            .iter()
            .any(|v| v.contains("newline")));
        // Missing metadata.
        assert!(lint_exposition("x 1\n").iter().any(|v| v.contains("no HELP")));
        // Duplicate series.
        let dup = "# HELP x h\n# TYPE x gauge\nx 1\nx 2\n";
        assert!(lint_exposition(dup).iter().any(|v| v.contains("duplicate series")));
        // Non-monotone cumulative buckets.
        let bad = "# HELP h q\n# TYPE h histogram\n\
                   h_bucket{le=\"1\"} 5\nh_bucket{le=\"2\"} 3\nh_bucket{le=\"+Inf\"} 5\n\
                   h_sum 1\nh_count 5\n";
        assert!(lint_exposition(bad).iter().any(|v| v.contains("decrease")));
        // Missing +Inf bucket.
        let noinf = "# HELP h q\n# TYPE h histogram\n\
                     h_bucket{le=\"1\"} 5\nh_sum 1\nh_count 5\n";
        assert!(lint_exposition(noinf).iter().any(|v| v.contains("+Inf")));
    }

    #[test]
    fn labeled_gauge_value_matches_label_pairs() {
        let doc = "# HELP g_tenant_admitted_total h\n# TYPE g_tenant_admitted_total gauge\n\
                   g_tenant_admitted_total{tenant=\"0\"} 4\n\
                   g_tenant_admitted_total{tenant=\"1\"} 1.5\n\
                   g_sched_policy_info{policy=\"aging\"} 1\n";
        assert_eq!(labeled_gauge_value(doc, "tenant_admitted_total", "tenant", "1"), Some(1.5));
        assert_eq!(labeled_gauge_value(doc, "tenant_admitted_total", "tenant", "9"), None);
        assert_eq!(labeled_gauge_value(doc, "sched_policy_info", "policy", "aging"), Some(1.0));
    }
}
