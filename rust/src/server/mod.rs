//! Online serving frontend: a dependency-free (std::net) HTTP/1.1 gateway
//! over the continuous-batching engine, plus the client and load-generator
//! sides of the same wire protocol.
//!
//! The paper frames ChunkAttention as a *multi-tenant online serving*
//! optimisation (§2.2, §4.2): concurrent requests sharing system-prompt
//! prefixes arrive over the network and stream completions back. This
//! module supplies that missing layer:
//!
//! - [`gateway`] — `POST /v1/generate` with SSE token streaming,
//!   `GET /healthz`, `GET /metrics` (Prometheus text format 0.0.4 with
//!   true histograms), `GET /debug/steps` and `GET /debug/tree` (JSON
//!   introspection), `POST /admin/drain|join` (live shard membership);
//!   bounded per-shard admission (429 backpressure), disconnect
//!   cancellation, graceful drain, optional Chrome `trace_event` output
//!   (`--trace-out`). Threading model documented in DESIGN.md.
//! - [`shard`] — one engine worker: a stepper thread owning an `Engine`,
//!   driven over the typed `WorkerMsg` protocol (the EngineHandle seam).
//! - [`router`] — consistent-hash prefix-affinity routing over N shards,
//!   live drain/join, and cluster `/metrics` aggregation.
//! - [`http`] — minimal HTTP/1.1 framing shared by server and client.
//! - [`client`] — blocking client + SSE reader for tests and tooling.
//! - [`bench`] — closed-loop multi-tenant load generator
//!   (`chunk-serve bench-http`), including the `--shard-sweep` scaling
//!   harness.

pub mod bench;
pub mod client;
pub mod gateway;
pub mod http;
pub mod router;
pub(crate) mod shard;

pub use bench::{
    render_comparison, render_policy_comparison, render_shard_sweep, render_tiered, run_bench,
    run_chaos_bench, run_mixed_bench, run_policy_comparison, run_prefill_comparison,
    run_shard_sweep, run_tiered, shard_sweep_json, tiered_json, BenchConfig, BenchReport,
    ChaosBenchConfig, ChaosReport, ComparisonConfig, MixedBenchConfig, MixedReport,
    PolicyComparisonConfig, ShardSweepConfig, ShardSweepPoint, TierScrape, TieredBenchConfig,
    TieredReport,
};
pub use client::{
    gauge_value, generate_with_request_id, generate_with_retry, histogram_quantile,
    histogram_snapshot, labeled_gauge_value, lint_exposition, GenerateStream, HistogramSnapshot,
    Response, StreamEvent,
};
pub use gateway::{Gateway, GatewayConfig, TokenEvent};
pub use router::{aggregate_expositions, routing_key, HashRing, RING_SEED, RING_VNODES};
