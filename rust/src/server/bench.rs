//! Closed-loop HTTP load generator (`chunk-serve bench-http`).
//!
//! Replays a multi-tenant workload from [`Corpus`] against a running
//! gateway over real sockets: `clients` worker threads each hold one
//! request in flight (closed loop), drawing the next request from a shared
//! counter until `requests` have been issued. Reports client-observed
//! throughput, TTFT, and normalized latency, plus the server-side prefix
//! hit rate scraped from `/metrics` — the paper's §4.2 serving metrics
//! measured end to end over the wire.

use super::client::{self, StreamEvent};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats::Summary;
use crate::workload::{Corpus, Tokenizer};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Gateway address, e.g. `127.0.0.1:8080`.
    pub addr: String,
    /// Concurrent closed-loop workers.
    pub clients: usize,
    /// Total requests to issue.
    pub requests: usize,
    /// Tenants (distinct shared system prompts).
    pub tenants: usize,
    /// Target system-prompt tokens per tenant.
    pub system_tokens: usize,
    /// Per-request user-query tokens appended after the system prompt.
    pub query_tokens: usize,
    /// Completion budget per request.
    pub max_new_tokens: usize,
    pub seed: u64,
    /// Per-connection socket timeout.
    pub timeout: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            addr: "127.0.0.1:8080".to_string(),
            clients: 8,
            requests: 64,
            tenants: 4,
            system_tokens: 1024,
            query_tokens: 32,
            max_new_tokens: 64,
            seed: 7,
            timeout: Duration::from_secs(60),
        }
    }
}

/// Aggregated client-side results of one bench run.
#[derive(Debug)]
pub struct BenchReport {
    pub completed: usize,
    /// Requests answered 429 by admission control (not retried).
    pub rejected: usize,
    pub errors: usize,
    pub wall_s: f64,
    pub completion_tokens: u64,
    /// Client-observed time to first token (ms).
    pub ttft_ms: Summary,
    /// Client-observed end-to-end latency per completion token (ms/tok).
    pub normalized_latency_ms: Summary,
    /// Server-side fraction of prompt tokens served from the prefix tree,
    /// scraped from `/metrics` after the run (NaN if unavailable).
    pub prefix_hit_rate: f64,
}

impl BenchReport {
    /// Completion tokens per wall-clock second across all clients.
    pub fn decode_tps(&self) -> f64 {
        self.completion_tokens as f64 / self.wall_s.max(1e-9)
    }

    /// Human-readable multi-line summary for the CLI.
    pub fn render(&self) -> String {
        format!(
            "requests           {} completed, {} rejected (429), {} errors\n\
             wall time          {:.2}s ({:.1} completion tok/s)\n\
             ttft               mean {:.1} ms, p99 {:.1} ms\n\
             normalized latency mean {:.2} ms/tok, p99 {:.2} ms/tok\n\
             prefix hit rate    {:.1}% (server-side, from /metrics)",
            self.completed,
            self.rejected,
            self.errors,
            self.wall_s,
            self.decode_tps(),
            self.ttft_ms.mean(),
            self.ttft_ms.percentile(99.0),
            self.normalized_latency_ms.mean(),
            self.normalized_latency_ms.percentile(99.0),
            100.0 * self.prefix_hit_rate,
        )
    }
}

/// Run the closed-loop bench against a live gateway.
pub fn run_bench(cfg: &BenchConfig) -> anyhow::Result<BenchReport> {
    anyhow::ensure!(cfg.clients > 0 && cfg.requests > 0, "need at least one client and request");
    let tokenizer = Arc::new(Tokenizer::default_english());
    let corpus =
        Arc::new(Corpus::synthesize(&tokenizer, cfg.tenants.max(1), cfg.system_tokens, cfg.seed));
    let next = Arc::new(AtomicUsize::new(0));
    let completed = Arc::new(AtomicUsize::new(0));
    let rejected = Arc::new(AtomicUsize::new(0));
    let errors = Arc::new(AtomicUsize::new(0));
    let tokens_total = Arc::new(AtomicU64::new(0));
    let ttft = Arc::new(Mutex::new(Summary::new()));
    let norm = Arc::new(Mutex::new(Summary::new()));

    let t0 = Instant::now();
    let mut workers = Vec::with_capacity(cfg.clients);
    for worker in 0..cfg.clients {
        let cfg = cfg.clone();
        let tokenizer = tokenizer.clone();
        let corpus = corpus.clone();
        let next = next.clone();
        let completed = completed.clone();
        let rejected = rejected.clone();
        let errors = errors.clone();
        let tokens_total = tokens_total.clone();
        let ttft = ttft.clone();
        let norm = norm.clone();
        workers.push(std::thread::spawn(move || {
            let mut rng = Pcg64::new(cfg.seed, worker as u64 + 1);
            loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= cfg.requests {
                    break;
                }
                let tenant = i % cfg.tenants.max(1);
                let prompt =
                    corpus.make_request_tokens(&tokenizer, tenant, cfg.query_tokens, &mut rng);
                let shared = corpus.tenants[tenant].system_tokens.len().min(prompt.len());
                let mut body = Json::obj();
                body.set(
                    "tokens",
                    Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()),
                );
                body.set("shared_tokens", shared)
                    .set("tenant", tenant)
                    .set("max_new_tokens", cfg.max_new_tokens);
                let sent = Instant::now();
                let mut stream = match client::generate(&cfg.addr, &body, cfg.timeout) {
                    Ok(s) => s,
                    Err(_) => {
                        errors.fetch_add(1, Ordering::SeqCst);
                        continue;
                    }
                };
                if stream.status() == 429 {
                    rejected.fetch_add(1, Ordering::SeqCst);
                    continue;
                }
                if stream.status() != 200 {
                    errors.fetch_add(1, Ordering::SeqCst);
                    continue;
                }
                let mut first_token_s: Option<f64> = None;
                let mut got = 0u64;
                let mut done = false;
                loop {
                    match stream.next_event() {
                        Ok(Some(StreamEvent::Token { .. })) => {
                            if first_token_s.is_none() {
                                first_token_s = Some(sent.elapsed().as_secs_f64());
                            }
                            got += 1;
                        }
                        Ok(Some(StreamEvent::Done { .. })) => {
                            done = true;
                            break;
                        }
                        Ok(None) | Err(_) => break,
                    }
                }
                if done && got > 0 {
                    completed.fetch_add(1, Ordering::SeqCst);
                    tokens_total.fetch_add(got, Ordering::SeqCst);
                    let e2e = sent.elapsed().as_secs_f64();
                    ttft.lock().unwrap().add(first_token_s.unwrap_or(e2e) * 1e3);
                    norm.lock().unwrap().add(e2e * 1e3 / got as f64);
                } else {
                    errors.fetch_add(1, Ordering::SeqCst);
                }
            }
        }));
    }
    for w in workers {
        w.join().map_err(|_| anyhow::anyhow!("bench worker panicked"))?;
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let prefix_hit_rate = client::get(&cfg.addr, "/metrics", cfg.timeout)
        .ok()
        .and_then(|resp| client::gauge_value(&resp.body, "prefix_hit_rate"))
        .unwrap_or(f64::NAN);

    let ttft_ms = ttft.lock().unwrap().clone();
    let normalized_latency_ms = norm.lock().unwrap().clone();
    Ok(BenchReport {
        completed: completed.load(Ordering::SeqCst),
        rejected: rejected.load(Ordering::SeqCst),
        errors: errors.load(Ordering::SeqCst),
        wall_s,
        completion_tokens: tokens_total.load(Ordering::SeqCst),
        ttft_ms,
        normalized_latency_ms,
        prefix_hit_rate,
    })
}
