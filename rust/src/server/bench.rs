//! Closed-loop HTTP load generator (`chunk-serve bench-http`).
//!
//! Replays a multi-tenant workload from [`Corpus`] against a running
//! gateway over real sockets: `clients` worker threads each hold one
//! request in flight (closed loop), drawing the next request from a shared
//! counter until `requests` have been issued. Reports client-observed
//! throughput, TTFT, and normalized latency, plus the server-side prefix
//! hit rate scraped from `/metrics` — the paper's §4.2 serving metrics
//! measured end to end over the wire.

use super::client::{self, StreamEvent};
use super::gateway::{Gateway, GatewayConfig};
use crate::coordinator::engine::testing::{KernelRunner, PacedRunner};
use crate::coordinator::{Engine, SchedPolicyKind};
use crate::kvcache::KvDtype;
use crate::util::failpoint;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats::Summary;
use crate::workload::{Corpus, Tokenizer};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Lock a shared tally even if another bench worker panicked while holding
/// it: a `Summary` or `Tally` is valid after any sequence of `add` calls,
/// so a poisoned mutex only means some samples are missing — the report
/// must still come out rather than cascading the panic through every
/// worker thread.
fn tally_lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Gateway address, e.g. `127.0.0.1:8080`.
    pub addr: String,
    /// Concurrent closed-loop workers.
    pub clients: usize,
    /// Total requests to issue.
    pub requests: usize,
    /// Tenants (distinct shared system prompts).
    pub tenants: usize,
    /// Target system-prompt tokens per tenant.
    pub system_tokens: usize,
    /// Per-request user-query tokens appended after the system prompt.
    pub query_tokens: usize,
    /// Completion budget per request.
    pub max_new_tokens: usize,
    pub seed: u64,
    /// Per-connection socket timeout.
    pub timeout: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            addr: "127.0.0.1:8080".to_string(),
            clients: 8,
            requests: 64,
            tenants: 4,
            system_tokens: 1024,
            query_tokens: 32,
            max_new_tokens: 64,
            seed: 7,
            timeout: Duration::from_secs(60),
        }
    }
}

/// Aggregated client-side results of one bench run.
#[derive(Debug)]
pub struct BenchReport {
    pub completed: usize,
    /// Requests answered 429 by admission control (after the retry budget).
    pub rejected: usize,
    pub errors: usize,
    /// Requests that spent their one bounded retry (429/503 + `Retry-After`)
    /// before reaching their final outcome.
    pub retried: usize,
    pub wall_s: f64,
    pub completion_tokens: u64,
    /// Client-observed time to first token (ms).
    pub ttft_ms: Summary,
    /// Client-observed end-to-end latency per completion token (ms/tok).
    pub normalized_latency_ms: Summary,
    /// Server-side fraction of prompt tokens served from the prefix tree,
    /// scraped from `/metrics` after the run (NaN if unavailable).
    pub prefix_hit_rate: f64,
    /// Server-side TTFT quantiles `(p50, p99)` in ms, interpolated from the
    /// `ttft_seconds` histogram scraped off `/metrics` (NaN if unavailable).
    /// These measure queue-to-first-token inside the gateway, so the gap to
    /// the client-side `ttft_ms` above is wire + connection-handling time.
    pub server_ttft_ms: (f64, f64),
    /// Server-side inter-token latency quantiles `(p50, p99)` in ms, from
    /// the `inter_token_seconds` histogram (NaN if unavailable).
    pub server_itl_ms: (f64, f64),
}

impl BenchReport {
    /// Completion tokens per wall-clock second across all clients.
    pub fn decode_tps(&self) -> f64 {
        self.completion_tokens as f64 / self.wall_s.max(1e-9)
    }

    /// Human-readable multi-line summary for the CLI.
    pub fn render(&self) -> String {
        format!(
            "requests           {} completed, {} rejected (429), {} errors, {} retried\n\
             wall time          {:.2}s ({:.1} completion tok/s)\n\
             ttft               mean {:.1} ms, p99 {:.1} ms (client-side)\n\
             server ttft        p50 {:.1} ms, p99 {:.1} ms (from ttft_seconds histogram)\n\
             server inter-token p50 {:.2} ms, p99 {:.2} ms (from inter_token_seconds histogram)\n\
             normalized latency mean {:.2} ms/tok, p99 {:.2} ms/tok\n\
             prefix hit rate    {:.1}% (server-side, from /metrics)",
            self.completed,
            self.rejected,
            self.errors,
            self.retried,
            self.wall_s,
            self.decode_tps(),
            self.ttft_ms.mean(),
            self.ttft_ms.percentile(99.0),
            self.server_ttft_ms.0,
            self.server_ttft_ms.1,
            self.server_itl_ms.0,
            self.server_itl_ms.1,
            self.normalized_latency_ms.mean(),
            self.normalized_latency_ms.percentile(99.0),
            100.0 * self.prefix_hit_rate,
        )
    }
}

/// Run the closed-loop bench against a live gateway.
pub fn run_bench(cfg: &BenchConfig) -> anyhow::Result<BenchReport> {
    anyhow::ensure!(cfg.clients > 0 && cfg.requests > 0, "need at least one client and request");
    let tokenizer = Arc::new(Tokenizer::default_english());
    let corpus =
        Arc::new(Corpus::synthesize(&tokenizer, cfg.tenants.max(1), cfg.system_tokens, cfg.seed));
    let next = Arc::new(AtomicUsize::new(0));
    let completed = Arc::new(AtomicUsize::new(0));
    let rejected = Arc::new(AtomicUsize::new(0));
    let errors = Arc::new(AtomicUsize::new(0));
    let retried = Arc::new(AtomicUsize::new(0));
    let tokens_total = Arc::new(AtomicU64::new(0));
    let ttft = Arc::new(Mutex::new(Summary::new()));
    let norm = Arc::new(Mutex::new(Summary::new()));

    let t0 = Instant::now();
    let mut workers = Vec::with_capacity(cfg.clients);
    for worker in 0..cfg.clients {
        let cfg = cfg.clone();
        let tokenizer = tokenizer.clone();
        let corpus = corpus.clone();
        let next = next.clone();
        let completed = completed.clone();
        let rejected = rejected.clone();
        let errors = errors.clone();
        let retried = retried.clone();
        let tokens_total = tokens_total.clone();
        let ttft = ttft.clone();
        let norm = norm.clone();
        workers.push(std::thread::spawn(move || {
            let mut rng = Pcg64::new(cfg.seed, worker as u64 + 1);
            loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= cfg.requests {
                    break;
                }
                let tenant = i % cfg.tenants.max(1);
                let prompt =
                    corpus.make_request_tokens(&tokenizer, tenant, cfg.query_tokens, &mut rng);
                let shared = corpus.tenants[tenant].system_tokens.len().min(prompt.len());
                let mut body = Json::obj();
                body.set(
                    "tokens",
                    Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()),
                );
                body.set("shared_tokens", shared)
                    .set("tenant", tenant)
                    .set("max_new_tokens", cfg.max_new_tokens);
                let sent = Instant::now();
                let (mut stream, retries) = match client::generate_with_retry(
                    &cfg.addr,
                    &body,
                    cfg.timeout,
                    Duration::from_secs(2),
                ) {
                    Ok(pair) => pair,
                    Err(_) => {
                        errors.fetch_add(1, Ordering::SeqCst);
                        continue;
                    }
                };
                retried.fetch_add(retries, Ordering::SeqCst);
                if stream.status() == 429 || stream.status() == 503 {
                    rejected.fetch_add(1, Ordering::SeqCst);
                    continue;
                }
                if stream.status() != 200 {
                    errors.fetch_add(1, Ordering::SeqCst);
                    continue;
                }
                let mut first_token_s: Option<f64> = None;
                let mut got = 0u64;
                let mut done = false;
                loop {
                    match stream.next_event() {
                        Ok(Some(StreamEvent::Token { .. })) => {
                            if first_token_s.is_none() {
                                first_token_s = Some(sent.elapsed().as_secs_f64());
                            }
                            got += 1;
                        }
                        Ok(Some(StreamEvent::Done { .. })) => {
                            done = true;
                            break;
                        }
                        // Terminal failures (engine panic quarantine, deadline
                        // timeout) end the stream cleanly; counted as errors.
                        Ok(Some(StreamEvent::Error { .. })) | Ok(Some(StreamEvent::Timeout)) => {
                            break
                        }
                        Ok(None) | Err(_) => break,
                    }
                }
                if done && got > 0 {
                    completed.fetch_add(1, Ordering::SeqCst);
                    tokens_total.fetch_add(got, Ordering::SeqCst);
                    let e2e = sent.elapsed().as_secs_f64();
                    tally_lock(&ttft).add(first_token_s.unwrap_or(e2e) * 1e3);
                    tally_lock(&norm).add(e2e * 1e3 / got as f64);
                } else {
                    errors.fetch_add(1, Ordering::SeqCst);
                }
            }
        }));
    }
    for w in workers {
        w.join().map_err(|_| anyhow::anyhow!("bench worker panicked"))?;
    }
    let wall_s = t0.elapsed().as_secs_f64();

    // One post-run scrape feeds both the prefix-hit gauge and the
    // server-side latency histograms.
    let metrics_doc =
        client::get(&cfg.addr, "/metrics", cfg.timeout).map(|resp| resp.body).unwrap_or_default();
    let prefix_hit_rate =
        client::gauge_value(&metrics_doc, "prefix_hit_rate").unwrap_or(f64::NAN);
    let quantiles = |name: &str| {
        (
            client::histogram_quantile(&metrics_doc, name, 0.5) * 1e3,
            client::histogram_quantile(&metrics_doc, name, 0.99) * 1e3,
        )
    };

    let ttft_ms = tally_lock(&ttft).clone();
    let normalized_latency_ms = tally_lock(&norm).clone();
    Ok(BenchReport {
        completed: completed.load(Ordering::SeqCst),
        rejected: rejected.load(Ordering::SeqCst),
        errors: errors.load(Ordering::SeqCst),
        retried: retried.load(Ordering::SeqCst),
        wall_s,
        completion_tokens: tokens_total.load(Ordering::SeqCst),
        ttft_ms,
        normalized_latency_ms,
        prefix_hit_rate,
        server_ttft_ms: quantiles("ttft_seconds"),
        server_itl_ms: quantiles("inter_token_seconds"),
    })
}

/// Knobs for the `--shard-sweep` scaling harness: run the closed-loop
/// multi-tenant workload once per shard count against freshly spawned
/// in-process gateways and compare throughput. The decode interval paces
/// each shard's stepper, so with enough tenants the single-shard gateway
/// is stepper-bound and RPS should scale near-linearly with shards while
/// prefix affinity keeps every tenant's system prompt hot on one shard.
#[derive(Debug, Clone)]
pub struct ShardSweepConfig {
    /// The workload (its `addr` is overwritten per spawned gateway).
    pub bench: BenchConfig,
    /// Shard counts to sweep, e.g. `[1, 2, 4]`.
    pub shard_counts: Vec<usize>,
    pub max_batch: usize,
    pub chunk: usize,
    pub queue_cap: usize,
    /// Stepper pacing — the serialized per-shard cost that sharding
    /// parallelizes.
    pub decode_interval: Duration,
    pub prefill_us_per_token: u64,
    pub prefill_chunk_tokens: usize,
    pub step_token_budget: usize,
    pub kv_dtype: KvDtype,
}

impl Default for ShardSweepConfig {
    fn default() -> Self {
        ShardSweepConfig {
            bench: BenchConfig {
                // More tenants than the widest sweep point, so every shard
                // owns at least one hot prefix and stays busy.
                clients: 16,
                requests: 96,
                tenants: 8,
                system_tokens: 512,
                query_tokens: 16,
                max_new_tokens: 48,
                ..BenchConfig::default()
            },
            shard_counts: vec![1, 2, 4],
            max_batch: 16,
            chunk: 64,
            queue_cap: 64,
            decode_interval: Duration::from_micros(300),
            prefill_us_per_token: 20,
            prefill_chunk_tokens: 128,
            step_token_budget: 160,
            kv_dtype: KvDtype::F32,
        }
    }
}

/// One sweep point: the client-side report plus each shard's prefix hit
/// rate (affinity quality — a shard that keeps its tenants' prefixes
/// local should match the single-engine hit rate).
#[derive(Debug)]
pub struct ShardSweepPoint {
    pub shards: usize,
    pub report: BenchReport,
    /// Per-shard `prefix_hit_rate`, scraped from the aggregated `/metrics`
    /// (`shard="i"` series; the plain gauge for a single-shard run). NaN
    /// where unavailable.
    pub per_shard_hit_rate: Vec<f64>,
}

/// Run the closed-loop workload once per shard count against freshly
/// spawned in-process gateways; returns one point per count, in order.
pub fn run_shard_sweep(cfg: &ShardSweepConfig) -> anyhow::Result<Vec<ShardSweepPoint>> {
    anyhow::ensure!(!cfg.shard_counts.is_empty(), "need at least one shard count to sweep");
    let mut points = Vec::with_capacity(cfg.shard_counts.len());
    for &n in &cfg.shard_counts {
        anyhow::ensure!(n > 0, "shard counts must be positive");
        let gw = Gateway::start_sharded(
            |_| {
                let runner = PacedRunner {
                    inner: KernelRunner::new(16, 32, 32000),
                    prefill_us_per_token: cfg.prefill_us_per_token,
                };
                Engine::with_dtype(runner, cfg.chunk, cfg.max_batch, cfg.kv_dtype)
            },
            GatewayConfig {
                addr: "127.0.0.1:0".to_string(),
                shards: n,
                queue_cap: cfg.queue_cap,
                decode_interval: cfg.decode_interval,
                prefill_chunk_tokens: cfg.prefill_chunk_tokens,
                step_token_budget: cfg.step_token_budget,
                ..GatewayConfig::default()
            },
        )?;
        let mut bench = cfg.bench.clone();
        bench.addr = gw.addr().to_string();
        let report = run_bench(&bench)?;
        // The post-run scrape inside run_bench read the cluster rollup;
        // this one reads the per-shard affinity series.
        let doc = client::get(&bench.addr, "/metrics", cfg.bench.timeout)
            .map(|r| r.body)
            .unwrap_or_default();
        let per_shard_hit_rate: Vec<f64> = if n == 1 {
            vec![client::gauge_value(&doc, "prefix_hit_rate").unwrap_or(f64::NAN)]
        } else {
            (0..n)
                .map(|i| {
                    client::labeled_gauge_value(&doc, "prefix_hit_rate", "shard", &i.to_string())
                        .unwrap_or(f64::NAN)
                })
                .collect()
        };
        gw.shutdown()?;
        points.push(ShardSweepPoint { shards: n, report, per_shard_hit_rate });
    }
    Ok(points)
}

/// Machine-readable sweep results (`bench-http --shard-sweep --out
/// BENCH_shards.json`). Non-finite samples serialize as `null` so the
/// document always parses.
pub fn shard_sweep_json(cfg: &ShardSweepConfig, points: &[ShardSweepPoint]) -> Json {
    let num = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
    let mut config = Json::obj();
    config
        .set("clients", cfg.bench.clients)
        .set("requests", cfg.bench.requests)
        .set("tenants", cfg.bench.tenants)
        .set("system_tokens", cfg.bench.system_tokens)
        .set("query_tokens", cfg.bench.query_tokens)
        .set("max_new_tokens", cfg.bench.max_new_tokens)
        .set("seed", cfg.bench.seed)
        .set("chunk", cfg.chunk)
        .set("max_batch", cfg.max_batch)
        .set("queue_cap", cfg.queue_cap)
        .set("decode_interval_us", cfg.decode_interval.as_micros() as u64)
        .set("prefill_us_per_token", cfg.prefill_us_per_token)
        .set("prefill_chunk_tokens", cfg.prefill_chunk_tokens)
        .set("step_token_budget", cfg.step_token_budget);
    let rows: Vec<Json> = points
        .iter()
        .map(|p| {
            let rps = p.report.completed as f64 / p.report.wall_s.max(1e-9);
            let mut o = Json::obj();
            o.set("shards", p.shards)
                .set("rps", num(rps))
                .set("decode_tps", num(p.report.decode_tps()))
                .set("server_ttft_p50_ms", num(p.report.server_ttft_ms.0))
                .set("server_ttft_p99_ms", num(p.report.server_ttft_ms.1))
                .set("client_ttft_mean_ms", num(p.report.ttft_ms.mean()))
                .set("prefix_hit_rate", num(p.report.prefix_hit_rate))
                .set(
                    "per_shard_prefix_hit_rate",
                    Json::Arr(p.per_shard_hit_rate.iter().map(|&h| num(h)).collect()),
                )
                .set("completed", p.report.completed)
                .set("rejected", p.report.rejected)
                .set("errors", p.report.errors)
                .set("wall_s", num(p.report.wall_s));
            o
        })
        .collect();
    let mut root = Json::obj();
    root.set("bench", "shard_sweep").set("config", config).set("points", rows);
    root
}

/// Human-readable sweep table: RPS scaling against the first point and
/// per-shard prefix affinity quality.
pub fn render_shard_sweep(points: &[ShardSweepPoint]) -> String {
    let rps_of =
        |p: &ShardSweepPoint| p.report.completed as f64 / p.report.wall_s.max(1e-9);
    let base_rps = points.first().map(&rps_of).unwrap_or(f64::NAN);
    let mut out = format!(
        "shard sweep — closed-loop multi-tenant workload per shard count\n\n\
         {:<8}{:>9}{:>10}{:>15}{:>15}{:>15}  {}\n",
        "shards", "RPS", "speedup", "decode tok/s", "ttft p50 (ms)", "ttft p99 (ms)",
        "per-shard hit rate"
    );
    for p in points {
        let rps = rps_of(p);
        let hits = p
            .per_shard_hit_rate
            .iter()
            .map(|h| format!("{h:.2}"))
            .collect::<Vec<_>>()
            .join(" ");
        out.push_str(&format!(
            "{:<8}{:>9.2}{:>9.2}x{:>15.1}{:>15.1}{:>15.1}  {}\n",
            p.shards,
            rps,
            rps / base_rps,
            p.report.decode_tps(),
            p.report.server_ttft_ms.0,
            p.report.server_ttft_ms.1,
            hits,
        ));
    }
    out
}

/// Knobs for the `--tiered` retention scenario: one hot shared prefix
/// that stays resident plus a tail of cold one-shot prefixes, replayed
/// against a tiered gateway (int8 demotion + spill) and an untired
/// baseline with the *same* hot-tree chunk budget. The headline is the
/// resident-prompt ratio at that fixed budget: cold pins the baseline
/// must evict survive in the tiered gateway as int8 side memory or spill
/// files, and a revisit phase promotes a few of them back to measure
/// promote latency end to end.
#[derive(Debug, Clone)]
pub struct TieredBenchConfig {
    /// Cold one-shot prefixes (each a distinct tenant, touched once
    /// during the main phase).
    pub cold_tenants: usize,
    /// Tokens per pinned prefix (hot and cold alike).
    pub system_tokens: usize,
    /// Per-request query tokens after the prefix.
    pub query_tokens: usize,
    pub max_new_tokens: usize,
    /// Cold tenants revisited after the main phase: each revisit hits a
    /// demoted (or spilled) pin and must promote it before prefill.
    pub revisits: usize,
    pub seed: u64,
    pub chunk: usize,
    pub max_batch: usize,
    pub queue_cap: usize,
    /// Hot-tree retention budget in chunks — identical for both gateways,
    /// so resident-prompt counts compare at fixed tree RSS.
    pub retain_chunks: usize,
    /// Tiered gateway: demote pins untouched this many admissions.
    pub demote_after: u64,
    /// Tiered gateway: spill int8 pins untouched this many admissions
    /// (0 = keep demoted pins in memory).
    pub spill_after: u64,
    /// Spill directory; `None` auto-creates one under the OS temp dir and
    /// removes it after the run.
    pub spill_dir: Option<std::path::PathBuf>,
    pub kv_dtype: KvDtype,
    pub decode_interval: Duration,
    pub timeout: Duration,
}

impl Default for TieredBenchConfig {
    fn default() -> Self {
        TieredBenchConfig {
            cold_tenants: 24,
            system_tokens: 512,
            query_tokens: 16,
            max_new_tokens: 24,
            revisits: 8,
            seed: 7,
            chunk: 64,
            max_batch: 8,
            queue_cap: 64,
            // 6 prefixes of 8 chunks fit hot; with demote-after 6 the
            // tiered gateway's hot set stays under budget without ever
            // needing eviction, while the baseline must evict to admit.
            retain_chunks: 48,
            demote_after: 6,
            spill_after: 18,
            spill_dir: None,
            kv_dtype: KvDtype::F16,
            decode_interval: Duration::ZERO,
            timeout: Duration::from_secs(60),
        }
    }
}

/// Post-run `/metrics` snapshot of one gateway in the tiered comparison.
#[derive(Debug)]
pub struct TierScrape {
    pub completed: usize,
    pub errors: usize,
    pub wall_s: f64,
    /// Pins per tier `(hot, int8, spilled)`.
    pub pins: (f64, f64, f64),
    /// Bytes per tier `(hot, int8, spilled)`.
    pub bytes: (f64, f64, f64),
    pub promotions: f64,
    pub demotions: f64,
    pub spills: f64,
    pub spill_load_failures: f64,
    /// `(p50, p99)` ms from the `kv_promote_seconds` histogram.
    pub promote_ms: (f64, f64),
    /// `(p50, p99)` ms from the `kv_demote_seconds` histogram.
    pub demote_ms: (f64, f64),
    pub prefix_hit_rate: f64,
}

impl TierScrape {
    /// Prompts still resident in any tier (NaN-free: missing series
    /// count 0).
    pub fn resident_prompts(&self) -> f64 {
        let z = |x: f64| if x.is_finite() { x } else { 0.0 };
        z(self.pins.0) + z(self.pins.1) + z(self.pins.2)
    }
}

/// Both sides of the tiered-retention comparison.
#[derive(Debug)]
pub struct TieredReport {
    pub baseline: TierScrape,
    pub tiered: TierScrape,
}

impl TieredReport {
    /// Resident prompts under tiering over resident prompts without, at
    /// the same hot-tree chunk budget.
    pub fn resident_ratio(&self) -> f64 {
        self.tiered.resident_prompts() / self.baseline.resident_prompts().max(1.0)
    }
}

/// Issue one request and drain its stream; returns whether it completed
/// with at least one token.
fn tiered_issue(addr: &str, body: &Json, timeout: Duration) -> bool {
    let Ok((mut stream, _)) =
        client::generate_with_retry(addr, body, timeout, Duration::from_secs(2))
    else {
        return false;
    };
    if stream.status() != 200 {
        return false;
    }
    let mut got = 0u64;
    loop {
        match stream.next_event() {
            Ok(Some(StreamEvent::Token { .. })) => got += 1,
            Ok(Some(StreamEvent::Done { .. })) => return got > 0,
            _ => return false,
        }
    }
}

/// Replay the hot + cold-tail schedule against one freshly spawned
/// gateway (tiered or baseline) and scrape its tier metrics.
fn run_tiered_once(cfg: &TieredBenchConfig, tiered: bool) -> anyhow::Result<TierScrape> {
    // Auto-provision a spill dir when the tiered leg wants one; removed
    // after the scrape so repeated runs don't accumulate files.
    let mut temp_spill = None;
    let spill_dir = if tiered && cfg.spill_after > 0 {
        Some(cfg.spill_dir.clone().unwrap_or_else(|| {
            let d = std::env::temp_dir()
                .join(format!("kvspill-bench-{}", std::process::id()));
            temp_spill = Some(d.clone());
            d
        }))
    } else {
        None
    };
    let gw = Gateway::start_sharded(
        |_| {
            let runner = KernelRunner::new(16, 32, 32000);
            Engine::with_dtype(runner, cfg.chunk, cfg.max_batch, cfg.kv_dtype)
        },
        GatewayConfig {
            addr: "127.0.0.1:0".to_string(),
            shards: 1,
            queue_cap: cfg.queue_cap,
            decode_interval: cfg.decode_interval,
            retain_chunks: cfg.retain_chunks,
            retain_demote_after: if tiered { cfg.demote_after } else { 0 },
            retain_spill_after: if tiered { cfg.spill_after } else { 0 },
            kv_spill_dir: spill_dir,
            ..GatewayConfig::default()
        },
    )?;
    let addr = gw.addr().to_string();
    let tokenizer = Tokenizer::default_english();
    let corpus =
        Corpus::synthesize(&tokenizer, 1 + cfg.cold_tenants, cfg.system_tokens, cfg.seed);
    // Main phase interleaves the hot tenant (0) with each cold tenant
    // exactly once; the revisit phase re-hits the *earliest* cold tenants,
    // which by then are demoted (and, past spill_after, on disk).
    let mut schedule: Vec<usize> = Vec::new();
    for c in 0..cfg.cold_tenants {
        schedule.push(0);
        schedule.push(1 + c);
    }
    for c in 0..cfg.revisits.min(cfg.cold_tenants) {
        schedule.push(0);
        schedule.push(1 + c);
    }
    let mut rng = Pcg64::new(cfg.seed, 99);
    let (mut completed, mut errors) = (0usize, 0usize);
    let t0 = Instant::now();
    for &tenant in &schedule {
        let prompt = corpus.make_request_tokens(&tokenizer, tenant, cfg.query_tokens, &mut rng);
        let shared = corpus.tenants[tenant].system_tokens.len().min(prompt.len());
        let mut body = Json::obj();
        body.set("tokens", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()));
        body.set("shared_tokens", shared).set("tenant", tenant).set(
            "max_new_tokens",
            cfg.max_new_tokens,
        );
        if tiered_issue(&addr, &body, cfg.timeout) {
            completed += 1;
        } else {
            errors += 1;
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    // Let the idle stepper finish any pending demote/spill maintenance so
    // the scrape sees settled tiers.
    std::thread::sleep(Duration::from_millis(100));
    let doc =
        client::get(&addr, "/metrics", cfg.timeout).map(|r| r.body).unwrap_or_default();
    let tier = |name: &str, t: &str| {
        client::labeled_gauge_value(&doc, name, "tier", t).unwrap_or(f64::NAN)
    };
    let gauge = |name: &str| client::gauge_value(&doc, name).unwrap_or(f64::NAN);
    let quantiles = |name: &str| {
        (
            client::histogram_quantile(&doc, name, 0.5) * 1e3,
            client::histogram_quantile(&doc, name, 0.99) * 1e3,
        )
    };
    let scrape = TierScrape {
        completed,
        errors,
        wall_s,
        pins: (
            tier("kv_tier_pins", "hot"),
            tier("kv_tier_pins", "int8"),
            tier("kv_tier_pins", "spilled"),
        ),
        bytes: (
            tier("kv_tier_bytes", "hot"),
            tier("kv_tier_bytes", "int8"),
            tier("kv_tier_bytes", "spilled"),
        ),
        promotions: gauge("kv_promotions_total"),
        demotions: gauge("kv_demotions_total"),
        spills: gauge("kv_spills_total"),
        spill_load_failures: gauge("kv_spill_load_failures_total"),
        promote_ms: quantiles("kv_promote_seconds"),
        demote_ms: quantiles("kv_demote_seconds"),
        prefix_hit_rate: gauge("prefix_hit_rate"),
    };
    gw.shutdown()?;
    if let Some(d) = temp_spill {
        let _ = std::fs::remove_dir_all(d);
    }
    Ok(scrape)
}

/// Run the tiered-retention comparison: same schedule and chunk budget
/// against an untiered baseline and a tiered gateway.
pub fn run_tiered(cfg: &TieredBenchConfig) -> anyhow::Result<TieredReport> {
    anyhow::ensure!(cfg.cold_tenants > 0, "need at least one cold tenant");
    anyhow::ensure!(cfg.retain_chunks > 0, "tiered bench needs a retention budget");
    anyhow::ensure!(cfg.demote_after > 0, "tiered bench needs --demote-after > 0");
    let baseline = run_tiered_once(cfg, false)?;
    let tiered = run_tiered_once(cfg, true)?;
    Ok(TieredReport { baseline, tiered })
}

/// Machine-readable tiered results (`bench-http --tiered --tiered-out
/// BENCH_tiered.json`). Non-finite samples serialize as `null`.
pub fn tiered_json(cfg: &TieredBenchConfig, report: &TieredReport) -> Json {
    let num = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
    let mut config = Json::obj();
    config
        .set("cold_tenants", cfg.cold_tenants)
        .set("system_tokens", cfg.system_tokens)
        .set("query_tokens", cfg.query_tokens)
        .set("max_new_tokens", cfg.max_new_tokens)
        .set("revisits", cfg.revisits)
        .set("seed", cfg.seed)
        .set("chunk", cfg.chunk)
        .set("max_batch", cfg.max_batch)
        .set("retain_chunks", cfg.retain_chunks)
        .set("demote_after", cfg.demote_after)
        .set("spill_after", cfg.spill_after)
        .set("kv_dtype", cfg.kv_dtype.label());
    let side = |s: &TierScrape| {
        let mut o = Json::obj();
        o.set("completed", s.completed)
            .set("errors", s.errors)
            .set("wall_s", num(s.wall_s))
            .set("resident_prompts", num(s.resident_prompts()))
            .set("pins_hot", num(s.pins.0))
            .set("pins_int8", num(s.pins.1))
            .set("pins_spilled", num(s.pins.2))
            .set("bytes_hot", num(s.bytes.0))
            .set("bytes_int8", num(s.bytes.1))
            .set("bytes_spilled", num(s.bytes.2))
            .set("promotions", num(s.promotions))
            .set("demotions", num(s.demotions))
            .set("spills", num(s.spills))
            .set("spill_load_failures", num(s.spill_load_failures))
            .set("promote_p50_ms", num(s.promote_ms.0))
            .set("promote_p99_ms", num(s.promote_ms.1))
            .set("demote_p50_ms", num(s.demote_ms.0))
            .set("demote_p99_ms", num(s.demote_ms.1))
            .set("prefix_hit_rate", num(s.prefix_hit_rate));
        o
    };
    let mut root = Json::obj();
    root.set("bench", "tiered")
        .set("config", config)
        .set("baseline", side(&report.baseline))
        .set("tiered", side(&report.tiered))
        .set("resident_ratio", num(report.resident_ratio()));
    root
}

/// Human-readable tiered comparison.
pub fn render_tiered(report: &TieredReport) -> String {
    let row = |label: &str, s: &TierScrape| {
        format!(
            "{label:<10}{:>10.0}{:>7.0}{:>7.0}{:>9.0}{:>12.1}{:>12.1}{:>12.2}{:>12.2}\n",
            s.resident_prompts(),
            s.pins.0,
            s.pins.1,
            s.pins.2,
            s.promote_ms.0,
            s.promote_ms.1,
            s.demote_ms.0,
            s.demote_ms.1,
        )
    };
    let mut out = format!(
        "tiered retention — hot shared prefix + cold one-shot tail at a fixed hot-tree budget\n\n\
         {:<10}{:>10}{:>7}{:>7}{:>9}{:>12}{:>12}{:>12}{:>12}\n",
        "gateway", "resident", "hot", "int8", "spilled", "promo p50", "promo p99", "demo p50",
        "demo p99"
    );
    out.push_str(&row("baseline", &report.baseline));
    out.push_str(&row("tiered", &report.tiered));
    out.push_str(&format!(
        "\nresident prompts at fixed hot-tree RSS: {:.1}x the untiered baseline \
         ({:.0} vs {:.0}); latencies in ms from /metrics histograms\n",
        report.resident_ratio(),
        report.tiered.resident_prompts(),
        report.baseline.resident_prompts(),
    ));
    out
}

/// Mixed head-of-line workload: long *cold* prompts (unique tokens, so no
/// prefix reuse is possible) interleaved with short requests that share
/// one hot prefix. Under monolithic prefill every long admission stalls
/// all in-flight decoders and every queued short for the whole prompt;
/// chunked prefill bounds the stall at the per-step token budget — the
/// regime where the serving path's biggest latency cliff lives.
#[derive(Debug, Clone)]
pub struct MixedBenchConfig {
    /// Gateway address (filled in by [`run_prefill_comparison`] when it
    /// spawns its own gateways).
    pub addr: String,
    /// Closed-loop workers issuing long cold prompts.
    pub long_clients: usize,
    /// Closed-loop workers issuing short shared-prefix requests.
    pub short_clients: usize,
    pub long_requests: usize,
    pub short_requests: usize,
    /// Tokens per long prompt; every token is unique across the run.
    pub long_prompt_tokens: usize,
    /// Hot prefix length shared by every short request.
    pub shared_prefix_tokens: usize,
    /// Per-request query tokens appended after the shared prefix.
    pub short_query_tokens: usize,
    pub max_new_tokens: usize,
    pub timeout: Duration,
}

impl Default for MixedBenchConfig {
    fn default() -> Self {
        MixedBenchConfig {
            addr: String::new(),
            long_clients: 2,
            short_clients: 6,
            long_requests: 8,
            short_requests: 64,
            long_prompt_tokens: 2048,
            shared_prefix_tokens: 1024,
            short_query_tokens: 32,
            max_new_tokens: 8,
            timeout: Duration::from_secs(120),
        }
    }
}

/// Per-class tallies of one mixed run.
#[derive(Debug, Default)]
struct Tally {
    completed: usize,
    rejected: usize,
    errors: usize,
    ttft_ms: Summary,
}

/// Client-observed results of one mixed-workload run.
#[derive(Debug)]
pub struct MixedReport {
    pub short_ttft_ms: Summary,
    pub long_ttft_ms: Summary,
    pub short_completed: usize,
    pub long_completed: usize,
    pub rejected: usize,
    pub errors: usize,
    pub wall_s: f64,
}

/// Issue one streaming request and record its TTFT into `tally`.
fn issue_one(addr: &str, body: &Json, timeout: Duration, tally: &Mutex<Tally>) {
    let sent = Instant::now();
    let mut stream = match client::generate(addr, body, timeout) {
        Ok(s) => s,
        Err(_) => {
            tally_lock(tally).errors += 1;
            return;
        }
    };
    if stream.status() == 429 {
        tally_lock(tally).rejected += 1;
        return;
    }
    if stream.status() != 200 {
        tally_lock(tally).errors += 1;
        return;
    }
    let mut first: Option<Duration> = None;
    let mut got = 0u64;
    let mut done = false;
    loop {
        match stream.next_event() {
            Ok(Some(StreamEvent::Token { .. })) => {
                if first.is_none() {
                    first = Some(sent.elapsed());
                }
                got += 1;
            }
            Ok(Some(StreamEvent::Done { .. })) => {
                done = true;
                break;
            }
            Ok(Some(StreamEvent::Error { .. })) | Ok(Some(StreamEvent::Timeout)) => break,
            Ok(None) | Err(_) => break,
        }
    }
    let mut t = tally_lock(tally);
    if done && got > 0 {
        t.completed += 1;
        t.ttft_ms.add(first.expect("done implies a first token").as_secs_f64() * 1e3);
    } else {
        t.errors += 1;
    }
}

/// Run the mixed long-cold + short-shared-prefix workload against a live
/// gateway, reporting TTFT per request class.
pub fn run_mixed_bench(cfg: &MixedBenchConfig) -> anyhow::Result<MixedReport> {
    anyhow::ensure!(
        cfg.long_clients > 0 && cfg.short_clients > 0,
        "the mixed workload needs both long and short clients"
    );
    let shared_prefix: Arc<Vec<u32>> = Arc::new((0..cfg.shared_prefix_tokens as u32).collect());
    let next_long = Arc::new(AtomicUsize::new(0));
    let next_short = Arc::new(AtomicUsize::new(0));
    let long_tally = Arc::new(Mutex::new(Tally::default()));
    let short_tally = Arc::new(Mutex::new(Tally::default()));

    let t0 = Instant::now();
    let mut workers = Vec::new();
    for _ in 0..cfg.long_clients {
        let cfg = cfg.clone();
        let next = next_long.clone();
        let tally = long_tally.clone();
        workers.push(std::thread::spawn(move || loop {
            let i = next.fetch_add(1, Ordering::SeqCst);
            if i >= cfg.long_requests {
                break;
            }
            // Unique token ids per request: a genuinely cold prompt. The
            // long class is tenant 1 so per-tenant fairness metrics (and
            // the DRR/aging policies) see it as the cold minority tenant.
            let base = 1_000_000u32 + (i * cfg.long_prompt_tokens) as u32;
            let prompt: Vec<u32> = (0..cfg.long_prompt_tokens as u32).map(|j| base + j).collect();
            let mut body = Json::obj();
            body.set("tokens", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()));
            body.set("shared_tokens", 0usize)
                .set("tenant", 1usize)
                .set("max_new_tokens", cfg.max_new_tokens);
            issue_one(&cfg.addr, &body, cfg.timeout, &tally);
        }));
    }
    for _ in 0..cfg.short_clients {
        let cfg = cfg.clone();
        let next = next_short.clone();
        let tally = short_tally.clone();
        let prefix = shared_prefix.clone();
        workers.push(std::thread::spawn(move || loop {
            let i = next.fetch_add(1, Ordering::SeqCst);
            if i >= cfg.short_requests {
                break;
            }
            let mut prompt = (*prefix).clone();
            let base = 500_000_000u32 + (i * cfg.short_query_tokens.max(1)) as u32;
            prompt.extend((0..cfg.short_query_tokens as u32).map(|j| base + j));
            let shared = prefix.len();
            let mut body = Json::obj();
            body.set("tokens", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()));
            body.set("shared_tokens", shared)
                .set("tenant", 0usize)
                .set("max_new_tokens", cfg.max_new_tokens);
            issue_one(&cfg.addr, &body, cfg.timeout, &tally);
        }));
    }
    for w in workers {
        w.join().map_err(|_| anyhow::anyhow!("mixed bench worker panicked"))?;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    // A worker panicking mid-update poisons the tally mutex, but the data
    // itself stays valid (partial counts); recover it instead of failing
    // the whole report.
    let long = Mutex::into_inner(
        Arc::try_unwrap(long_tally).map_err(|_| anyhow::anyhow!("tally still shared"))?,
    )
    .unwrap_or_else(|e| e.into_inner());
    let short = Mutex::into_inner(
        Arc::try_unwrap(short_tally).map_err(|_| anyhow::anyhow!("tally still shared"))?,
    )
    .unwrap_or_else(|e| e.into_inner());
    Ok(MixedReport {
        short_ttft_ms: short.ttft_ms,
        long_ttft_ms: long.ttft_ms,
        short_completed: short.completed,
        long_completed: long.completed,
        rejected: short.rejected + long.rejected,
        errors: short.errors + long.errors,
        wall_s,
    })
}

/// Gateway knobs for the monolithic-vs-chunked comparison.
#[derive(Debug, Clone)]
pub struct ComparisonConfig {
    /// The workload (its `addr` is overwritten per spawned gateway).
    pub mixed: MixedBenchConfig,
    pub max_batch: usize,
    /// Tree KV chunk size.
    pub chunk: usize,
    pub queue_cap: usize,
    pub decode_interval: Duration,
    /// Emulated model prefill cost (the synthetic runner hashes rows in
    /// microseconds; real prefill FLOPs are what make head-of-line
    /// blocking hurt, so the bench paces them explicitly).
    pub prefill_us_per_token: u64,
    /// Chunked leg: prefill slice granularity.
    pub prefill_chunk_tokens: usize,
    /// Chunked leg: per-step token budget.
    pub step_token_budget: usize,
    /// KV storage dtype of both spawned gateways.
    pub kv_dtype: KvDtype,
}

impl Default for ComparisonConfig {
    fn default() -> Self {
        ComparisonConfig {
            mixed: MixedBenchConfig::default(),
            max_batch: 16,
            chunk: 64,
            queue_cap: 64,
            decode_interval: Duration::from_micros(200),
            prefill_us_per_token: 50,
            prefill_chunk_tokens: 128,
            step_token_budget: 160,
            kv_dtype: KvDtype::F32,
        }
    }
}

/// Run the mixed workload twice against freshly spawned in-process
/// gateways — monolithic prefill, then chunked prefill — and return both
/// reports `(monolithic, chunked)`.
pub fn run_prefill_comparison(cfg: &ComparisonConfig) -> anyhow::Result<(MixedReport, MixedReport)> {
    let run = |chunked: bool| -> anyhow::Result<MixedReport> {
        let runner = PacedRunner {
            inner: KernelRunner::new(16, 32, 32000),
            prefill_us_per_token: cfg.prefill_us_per_token,
        };
        let engine = Engine::with_dtype(runner, cfg.chunk, cfg.max_batch, cfg.kv_dtype);
        let gw = Gateway::start(
            engine,
            GatewayConfig {
                addr: "127.0.0.1:0".to_string(),
                queue_cap: cfg.queue_cap,
                decode_interval: cfg.decode_interval,
                prefill_chunk_tokens: if chunked { cfg.prefill_chunk_tokens } else { 0 },
                step_token_budget: if chunked { cfg.step_token_budget } else { 0 },
                ..GatewayConfig::default()
            },
        )?;
        let mut mixed = cfg.mixed.clone();
        mixed.addr = gw.addr().to_string();
        let report = run_mixed_bench(&mixed)?;
        gw.shutdown()?;
        Ok(report)
    };
    let monolithic = run(false)?;
    let chunked = run(true)?;
    Ok((monolithic, chunked))
}

/// Gateway + workload knobs for the `--skewed` policy comparison: one
/// *cold* tenant issuing long unshareable prompts (the `long_*` side of
/// [`MixedBenchConfig`], tenant 1) competes with a *hot* tenant storm of
/// short prefix-sharing requests (the `short_*` side, tenant 0). Under
/// `prefix-greedy` every freed slot goes to a sharer while any is queued,
/// so the cold tenant's TTFT degrades to the storm duration; `aging`
/// bounds its wait. Both gateways run chunked prefill with the same
/// budget — only the admission policy differs.
#[derive(Debug, Clone)]
pub struct PolicyComparisonConfig {
    /// The skewed workload (its `addr` is overwritten per gateway).
    pub mixed: MixedBenchConfig,
    pub max_batch: usize,
    pub chunk: usize,
    pub queue_cap: usize,
    pub decode_interval: Duration,
    pub prefill_us_per_token: u64,
    pub prefill_chunk_tokens: usize,
    pub step_token_budget: usize,
    pub kv_dtype: KvDtype,
    /// The two policies compared, `(baseline, contender)`.
    pub policies: (SchedPolicyKind, SchedPolicyKind),
}

impl Default for PolicyComparisonConfig {
    fn default() -> Self {
        PolicyComparisonConfig {
            mixed: MixedBenchConfig {
                // A storm of hot sharers against a small batch keeps the
                // queue contended, so admission *order* (not prefill
                // bandwidth) decides the cold tenant's wait.
                long_clients: 1,
                short_clients: 6,
                long_requests: 4,
                short_requests: 48,
                ..MixedBenchConfig::default()
            },
            max_batch: 4,
            chunk: 64,
            queue_cap: 64,
            decode_interval: Duration::from_micros(200),
            prefill_us_per_token: 20,
            prefill_chunk_tokens: 128,
            step_token_budget: 160,
            kv_dtype: KvDtype::F32,
            policies: (SchedPolicyKind::PrefixGreedy, SchedPolicyKind::Aging),
        }
    }
}

/// Run the skewed-tenant workload once per policy against freshly
/// spawned gateways; returns `(baseline, contender)` reports. The cold
/// tenant's numbers are the `long_*` fields of [`MixedReport`].
pub fn run_policy_comparison(
    cfg: &PolicyComparisonConfig,
) -> anyhow::Result<(MixedReport, MixedReport)> {
    let run = |policy: SchedPolicyKind| -> anyhow::Result<MixedReport> {
        let runner = PacedRunner {
            inner: KernelRunner::new(16, 32, 32000),
            prefill_us_per_token: cfg.prefill_us_per_token,
        };
        let engine = Engine::with_dtype(runner, cfg.chunk, cfg.max_batch, cfg.kv_dtype);
        let gw = Gateway::start(
            engine,
            GatewayConfig {
                addr: "127.0.0.1:0".to_string(),
                queue_cap: cfg.queue_cap,
                decode_interval: cfg.decode_interval,
                prefill_chunk_tokens: cfg.prefill_chunk_tokens,
                step_token_budget: cfg.step_token_budget,
                sched_policy: policy,
                ..GatewayConfig::default()
            },
        )?;
        let mut mixed = cfg.mixed.clone();
        mixed.addr = gw.addr().to_string();
        let report = run_mixed_bench(&mixed)?;
        gw.shutdown()?;
        Ok(report)
    };
    let baseline = run(cfg.policies.0)?;
    let contender = run(cfg.policies.1)?;
    Ok((baseline, contender))
}

/// Side-by-side rendering of the skewed-tenant policy comparison: the
/// cold tenant's TTFT is the fairness headline, the hot storm's TTFT
/// shows what the fairness costs.
pub fn render_policy_comparison(
    cfg: &PolicyComparisonConfig,
    baseline: &MixedReport,
    contender: &MixedReport,
) -> String {
    format!(
        "skewed-tenant comparison — 1 cold tenant ({} prompts x {} tok) vs a hot storm \
         ({} requests, {}-tok shared prefix); chunked prefill {} tok / budget {}\n\
         \n\
         {:<28}{:>14}{:>14}\n\
         {:<28}{:>14.1}{:>14.1}\n\
         {:<28}{:>14.1}{:>14.1}\n\
         {:<28}{:>14.1}{:>14.1}\n\
         {:<28}{:>14.1}{:>14.1}\n\
         {:<28}{:>11}/{:<2}{:>11}/{:<2}\n\
         {:<28}{:>14.2}{:>14.2}",
        cfg.mixed.long_requests,
        cfg.mixed.long_prompt_tokens,
        cfg.mixed.short_requests,
        cfg.mixed.shared_prefix_tokens,
        cfg.prefill_chunk_tokens,
        cfg.step_token_budget,
        "",
        cfg.policies.0.label(),
        cfg.policies.1.label(),
        "cold TTFT p50 (ms)",
        baseline.long_ttft_ms.percentile(50.0),
        contender.long_ttft_ms.percentile(50.0),
        "cold TTFT p99 (ms)",
        baseline.long_ttft_ms.percentile(99.0),
        contender.long_ttft_ms.percentile(99.0),
        "hot TTFT p50 (ms)",
        baseline.short_ttft_ms.percentile(50.0),
        contender.short_ttft_ms.percentile(50.0),
        "hot TTFT p99 (ms)",
        baseline.short_ttft_ms.percentile(99.0),
        contender.short_ttft_ms.percentile(99.0),
        "completed (hot/cold)",
        baseline.short_completed,
        baseline.long_completed,
        contender.short_completed,
        contender.long_completed,
        "wall time (s)",
        baseline.wall_s,
        contender.wall_s,
    )
}

/// Knobs for the `--chaos` availability bench: spawn an in-process
/// gateway, arm a failpoint profile against it, drive the standard
/// closed-loop workload while a side thread probes `/healthz`, and report
/// what fraction of requests (and health probes) survived the injected
/// faults.
#[derive(Debug, Clone)]
pub struct ChaosBenchConfig {
    /// The workload (its `addr` is overwritten by the spawned gateway).
    pub bench: BenchConfig,
    /// Failpoint profile, `--fail` grammar (comma/semicolon-separated
    /// `name=spec` entries), armed for the duration of the run.
    pub failpoints: String,
    pub max_batch: usize,
    pub chunk: usize,
    pub queue_cap: usize,
    pub decode_interval: Duration,
    pub prefill_us_per_token: u64,
    pub prefill_chunk_tokens: usize,
    pub step_token_budget: usize,
    /// Stepper watchdog threshold for the spawned gateway.
    pub watchdog_stall: Duration,
    /// Cadence of the `/healthz` availability probe.
    pub healthz_poll: Duration,
    pub kv_dtype: KvDtype,
    /// When set, the spawned gateway records a Chrome `trace_event` file
    /// here — fault injections (`step_retry`, `step_panic`) show up as
    /// instant events alongside the step/phase spans.
    pub trace_path: Option<std::path::PathBuf>,
}

impl Default for ChaosBenchConfig {
    fn default() -> Self {
        ChaosBenchConfig {
            bench: BenchConfig::default(),
            // Defaults exercise both rungs of the degradation ladder that
            // a bench can survive: injected step latency (watchdog food)
            // and transient prefill errors (retry food).
            failpoints: "engine.step=2%sleep(2),engine.prefill=2%err(injected chaos)".to_string(),
            max_batch: 16,
            chunk: 64,
            queue_cap: 64,
            decode_interval: Duration::from_micros(200),
            prefill_us_per_token: 20,
            prefill_chunk_tokens: 128,
            step_token_budget: 160,
            watchdog_stall: Duration::from_millis(500),
            healthz_poll: Duration::from_millis(25),
            kv_dtype: KvDtype::F32,
            trace_path: None,
        }
    }
}

/// Results of one chaos run: the client-side bench report plus the
/// health-probe tallies and the gateway's own failure counters.
#[derive(Debug)]
pub struct ChaosReport {
    pub bench: BenchReport,
    /// Failpoint sites armed for the run.
    pub armed: usize,
    pub failpoints: String,
    pub probes_total: usize,
    /// Probes answered 503 (stepper stalled past the watchdog threshold).
    pub probes_degraded: usize,
    /// Probes that failed outright (connect/read error).
    pub probes_failed: usize,
    pub engine_panics: f64,
    pub engine_rebuilds: f64,
    pub watchdog_stalls: f64,
    pub step_retries: f64,
    pub requests_timed_out: f64,
    pub requests_failed: f64,
}

impl ChaosReport {
    /// Fraction of issued requests that completed despite the faults.
    pub fn availability(&self) -> f64 {
        let issued = self.bench.completed + self.bench.rejected + self.bench.errors;
        if issued == 0 {
            return f64::NAN;
        }
        self.bench.completed as f64 / issued as f64
    }

    /// Fraction of health probes that came back 200.
    pub fn health_availability(&self) -> f64 {
        if self.probes_total == 0 {
            return f64::NAN;
        }
        (self.probes_total - self.probes_degraded - self.probes_failed) as f64
            / self.probes_total as f64
    }

    pub fn render(&self) -> String {
        format!(
            "chaos profile      {} ({} site{} armed)\n\
             availability       {:.1}% of requests completed, {:.1}% of health probes 200\n\
             health probes      {} total, {} degraded (503), {} failed\n\
             supervision        {} panics, {} rebuilds, {} watchdog stalls, {} step retries\n\
             failures           {} requests failed, {} timed out\n\
             \n\
             {}",
            self.failpoints,
            self.armed,
            if self.armed == 1 { "" } else { "s" },
            100.0 * self.availability(),
            100.0 * self.health_availability(),
            self.probes_total,
            self.probes_degraded,
            self.probes_failed,
            self.engine_panics,
            self.engine_rebuilds,
            self.watchdog_stalls,
            self.step_retries,
            self.requests_failed,
            self.requests_timed_out,
            self.bench.render(),
        )
    }
}

/// Run the closed-loop bench against a freshly spawned gateway with the
/// configured failpoint profile armed, measuring availability under
/// injected faults. All failpoints are disarmed before returning (on every
/// path), so a chaos run never leaks fault state into later runs.
pub fn run_chaos_bench(cfg: &ChaosBenchConfig) -> anyhow::Result<ChaosReport> {
    // Drop guard: whatever path exits this function, the process-global
    // failpoint registry goes back to fully disarmed.
    struct DisarmAll;
    impl Drop for DisarmAll {
        fn drop(&mut self) {
            failpoint::disarm_all();
        }
    }

    let runner = PacedRunner {
        inner: KernelRunner::new(16, 32, 32000),
        prefill_us_per_token: cfg.prefill_us_per_token,
    };
    let engine = Engine::with_dtype(runner, cfg.chunk, cfg.max_batch, cfg.kv_dtype);
    let gw = Gateway::start(
        engine,
        GatewayConfig {
            addr: "127.0.0.1:0".to_string(),
            queue_cap: cfg.queue_cap,
            decode_interval: cfg.decode_interval,
            prefill_chunk_tokens: cfg.prefill_chunk_tokens,
            step_token_budget: cfg.step_token_budget,
            watchdog_stall: cfg.watchdog_stall,
            trace_path: cfg.trace_path.clone(),
            ..GatewayConfig::default()
        },
    )?;
    let addr = gw.addr().to_string();

    // Arm only after the gateway is up, so startup runs clean.
    let _disarm = DisarmAll;
    let armed = failpoint::configure_list(&cfg.failpoints)
        .map_err(|e| anyhow::anyhow!("bad failpoint profile: {e}"))?;

    // Availability probe: poll /healthz on a fixed cadence for the whole
    // run so watchdog-degraded windows show up even if every request
    // eventually completes.
    let stop = Arc::new(AtomicBool::new(false));
    let probes = Arc::new((AtomicUsize::new(0), AtomicUsize::new(0), AtomicUsize::new(0)));
    let probe_handle = {
        let stop = stop.clone();
        let probes = probes.clone();
        let addr = addr.clone();
        let poll = cfg.healthz_poll;
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                probes.0.fetch_add(1, Ordering::SeqCst);
                match client::get(&addr, "/healthz", Duration::from_secs(2)) {
                    Ok(resp) if resp.status == 200 => {}
                    Ok(_) => {
                        probes.1.fetch_add(1, Ordering::SeqCst);
                    }
                    Err(_) => {
                        probes.2.fetch_add(1, Ordering::SeqCst);
                    }
                }
                std::thread::sleep(poll);
            }
        })
    };

    let mut bench = cfg.bench.clone();
    bench.addr = addr.clone();
    let bench_result = run_bench(&bench);

    stop.store(true, Ordering::SeqCst);
    probe_handle.join().map_err(|_| anyhow::anyhow!("healthz probe panicked"))?;
    let bench_report = bench_result?;

    // Scrape the supervision counters before tearing the gateway down.
    let doc = client::get(&addr, "/metrics", cfg.bench.timeout).map(|r| r.body).unwrap_or_default();
    let gauge = |name: &str| client::gauge_value(&doc, name).unwrap_or(0.0);
    let failed = ["panic", "error", "rebuild"]
        .iter()
        .filter_map(|r| client::labeled_gauge_value(&doc, "requests_failed_total", "reason", r))
        .sum::<f64>();
    let report = ChaosReport {
        armed,
        failpoints: cfg.failpoints.clone(),
        probes_total: probes.0.load(Ordering::SeqCst),
        probes_degraded: probes.1.load(Ordering::SeqCst),
        probes_failed: probes.2.load(Ordering::SeqCst),
        engine_panics: gauge("engine_panics_total"),
        engine_rebuilds: gauge("engine_rebuilds_total"),
        watchdog_stalls: gauge("watchdog_stalls_total"),
        step_retries: gauge("step_retries_total"),
        requests_timed_out: gauge("requests_timed_out_total"),
        requests_failed: failed,
        bench: bench_report,
    };

    // Disarm before shutdown so draining steps are not subject to faults.
    failpoint::disarm_all();
    gw.shutdown()?;
    Ok(report)
}

/// Side-by-side rendering of the monolithic-vs-chunked comparison.
pub fn render_comparison(cfg: &ComparisonConfig, mono: &MixedReport, chunked: &MixedReport) -> String {
    format!(
        "head-of-line comparison — {} long cold prompts ({} tok) + {} short requests \
         ({}-tok shared prefix), prefill paced at {}µs/tok\n\
         \n\
         {:<26}{:>12}{:>12}\n\
         {:<26}{:>12.1}{:>12.1}\n\
         {:<26}{:>12.1}{:>12.1}\n\
         {:<26}{:>12.1}{:>12.1}\n\
         {:<26}{:>12.1}{:>12.1}\n\
         {:<26}{:>9}/{:<2}{:>9}/{:<2}\n\
         {:<26}{:>12.2}{:>12.2}",
        cfg.mixed.long_requests,
        cfg.mixed.long_prompt_tokens,
        cfg.mixed.short_requests,
        cfg.mixed.shared_prefix_tokens,
        cfg.prefill_us_per_token,
        "",
        "monolithic",
        "chunked",
        "short TTFT p50 (ms)",
        mono.short_ttft_ms.percentile(50.0),
        chunked.short_ttft_ms.percentile(50.0),
        "short TTFT p99 (ms)",
        mono.short_ttft_ms.percentile(99.0),
        chunked.short_ttft_ms.percentile(99.0),
        "short TTFT max (ms)",
        mono.short_ttft_ms.max(),
        chunked.short_ttft_ms.max(),
        "long TTFT p99 (ms)",
        mono.long_ttft_ms.percentile(99.0),
        chunked.long_ttft_ms.percentile(99.0),
        "completed (short/long)",
        mono.short_completed,
        mono.long_completed,
        chunked.short_completed,
        chunked.long_completed,
        "wall time (s)",
        mono.wall_s,
        chunked.wall_s,
    )
}
