//! Minimal HTTP/1.1 framing over `std::net` streams.
//!
//! The offline crate set has no `hyper`/`axum`, and the gateway needs only
//! a narrow slice of the protocol: parse one request per connection
//! (`Connection: close` discipline), write fixed-length responses, and
//! stream Server-Sent Events for token delivery. Both the server and the
//! in-crate client/load-generator use these helpers, so the wire format is
//! exercised from both ends in tests.

use std::io::{BufRead, Read, Write};

/// Parsed request head + body.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// Header names lower-cased; values trimmed.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    pub fn body_utf8(&self) -> Result<&str, String> {
        std::str::from_utf8(&self.body).map_err(|e| format!("body is not UTF-8: {e}"))
    }
}

/// Upper bounds keeping a hostile or confused peer from ballooning memory.
const MAX_HEADER_BYTES: usize = 64 * 1024;
const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;

/// Read one request from a buffered stream. Returns `Ok(None)` on a clean
/// EOF before any bytes (peer connected and left), `Err` on malformed or
/// oversized input.
pub fn read_request<R: BufRead>(reader: &mut R) -> std::io::Result<Option<HttpRequest>> {
    let bad = |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
    // All head reads go through a hard `Take` limit: a peer streaming
    // bytes without a newline hits the cap (read_line then sees EOF)
    // instead of growing the line buffer without bound.
    let mut head = reader.by_ref().take(MAX_HEADER_BYTES as u64);
    let mut line = String::new();
    if head.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    if !line.ends_with('\n') {
        return Err(bad("request line exceeds the header limit".into()));
    }
    let mut parts = line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next()) {
        (Some(m), Some(p)) => (m.to_string(), p.to_string()),
        _ => return Err(bad(format!("malformed request line {line:?}"))),
    };
    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        if head.read_line(&mut h)? == 0 {
            return Err(bad("header section truncated or too large".into()));
        }
        let trimmed = h.trim_end();
        if trimmed.is_empty() {
            break;
        }
        if let Some((k, v)) = trimmed.split_once(':') {
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
    }
    let content_length = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse::<usize>().ok())
        .unwrap_or(0);
    if content_length > MAX_BODY_BYTES {
        return Err(bad(format!("body of {content_length} bytes exceeds the limit")));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Some(HttpRequest { method, path, headers, body }))
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response and flush it.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    write_response_with(w, status, content_type, &[], body)
}

/// Write a response head: status line, `Content-Type`, optional
/// `Content-Length` (omitted for SSE, whose `Connection: close` delimits
/// the stream), `Connection: close`, any extra headers, and the blank
/// line. Every response — fixed-length or streaming, server or shard
/// path — goes through here so the wire format cannot drift.
pub fn write_head<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    content_length: Option<usize>,
    extra_headers: &[(&str, &str)],
) -> std::io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\nContent-Type: {}\r\n", status, reason(status), content_type)?;
    if let Some(len) = content_length {
        write!(w, "Content-Length: {len}\r\n")?;
    }
    write!(w, "Connection: close\r\n")?;
    for (name, value) in extra_headers {
        write!(w, "{name}: {value}\r\n")?;
    }
    write!(w, "\r\n")
}

/// Like [`write_response`], with extra headers (name, value) — the gateway
/// uses this for `Retry-After` on backpressure and degraded-health replies.
pub fn write_response_with<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<()> {
    write_head(w, status, content_type, Some(body.len()), extra_headers)?;
    w.write_all(body)?;
    w.flush()
}

/// Write a JSON response (the gateway's non-streaming replies).
pub fn write_json<W: Write>(
    w: &mut W,
    status: u16,
    json: &crate::util::json::Json,
) -> std::io::Result<()> {
    write_response(w, status, "application/json", json.to_string().as_bytes())
}

/// JSON response with extra headers (`Retry-After` et al.).
pub fn write_json_with<W: Write>(
    w: &mut W,
    status: u16,
    extra_headers: &[(&str, &str)],
    json: &crate::util::json::Json,
) -> std::io::Result<()> {
    write_response_with(w, status, "application/json", extra_headers, json.to_string().as_bytes())
}

/// Start a Server-Sent-Events response: headers only, no Content-Length —
/// the `Connection: close` frame delimits the stream.
pub fn start_sse<W: Write>(w: &mut W) -> std::io::Result<()> {
    start_sse_with(w, &[])
}

/// [`start_sse`] with extra headers — the gateway echoes a client-supplied
/// `X-Request-Id` on the stream head this way.
pub fn start_sse_with<W: Write>(w: &mut W, extra_headers: &[(&str, &str)]) -> std::io::Result<()> {
    let mut headers: Vec<(&str, &str)> = vec![("Cache-Control", "no-cache")];
    headers.extend_from_slice(extra_headers);
    write_head(w, 200, "text/event-stream", None, &headers)?;
    w.flush()
}

/// Write one SSE event (`data: <payload>\n\n`) and flush so the client
/// observes each token as it is decoded, not at request completion.
pub fn write_sse_event<W: Write>(w: &mut W, data: &str) -> std::io::Result<()> {
    write!(w, "data: {data}\n\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\n\r\nhello world";
        let mut r = BufReader::new(&raw[..]);
        let req = read_request(&mut r).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body_utf8().unwrap(), "hello world");
    }

    #[test]
    fn parses_get_without_body() {
        let raw = b"GET /metrics HTTP/1.1\r\nAccept: */*\r\n\r\n";
        let mut r = BufReader::new(&raw[..]);
        let req = read_request(&mut r).unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/metrics");
        assert!(req.body.is_empty());
    }

    #[test]
    fn clean_eof_yields_none() {
        let raw: &[u8] = b"";
        let mut r = BufReader::new(raw);
        assert!(read_request(&mut r).unwrap().is_none());
    }

    #[test]
    fn truncated_body_errors() {
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort";
        let mut r = BufReader::new(&raw[..]);
        assert!(read_request(&mut r).is_err());
    }

    #[test]
    fn newline_free_flood_is_cut_at_the_header_limit() {
        // A peer streaming bytes with no '\n' must hit the Take cap, not
        // grow the line buffer indefinitely.
        let raw = vec![b'G'; MAX_HEADER_BYTES + 1024];
        let mut r = BufReader::new(&raw[..]);
        assert!(read_request(&mut r).is_err());
    }

    #[test]
    fn oversized_header_section_is_rejected() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        while raw.len() <= MAX_HEADER_BYTES {
            raw.extend_from_slice(b"X-Filler: aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa\r\n");
        }
        raw.extend_from_slice(b"\r\n");
        let mut r = BufReader::new(&raw[..]);
        assert!(read_request(&mut r).is_err());
    }

    #[test]
    fn oversized_body_is_rejected_up_front() {
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n";
        let mut r = BufReader::new(&raw[..]);
        assert!(read_request(&mut r).is_err());
    }

    #[test]
    fn response_roundtrips_through_parser_shape() {
        let mut buf = Vec::new();
        write_response(&mut buf, 429, "application/json", b"{\"error\":\"full\"}").unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Content-Length: 16\r\n"));
        assert!(text.ends_with("{\"error\":\"full\"}"));
    }

    #[test]
    fn extra_headers_land_before_the_body() {
        let mut buf = Vec::new();
        write_response_with(&mut buf, 503, "application/json", &[("Retry-After", "2")], b"{}")
            .unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(text.contains("Retry-After: 2\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
    }

    #[test]
    fn sse_head_carries_extra_headers() {
        let mut buf = Vec::new();
        start_sse_with(&mut buf, &[("X-Request-Id", "abc-123")]).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Type: text/event-stream\r\n"));
        assert!(text.contains("Cache-Control: no-cache\r\n"));
        assert!(text.contains("X-Request-Id: abc-123\r\n"));
        assert!(!text.contains("Content-Length"));
        assert!(text.ends_with("\r\n\r\n"));
    }

    #[test]
    fn sse_event_frames() {
        let mut buf = Vec::new();
        start_sse(&mut buf).unwrap();
        write_sse_event(&mut buf, "{\"token\":7}").unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("Content-Type: text/event-stream"));
        assert!(text.ends_with("data: {\"token\":7}\n\n"));
    }
}
