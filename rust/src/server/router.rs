//! Prefix-affinity routing over N engine shards.
//!
//! The gateway no longer owns an engine: it owns a [`Router`], which maps
//! every request to one shard's [`EngineHandle`](super::shard::EngineHandle)
//! by **consistent hashing the longest chunk-aligned prefix** of the
//! prompt. Requests sharing a system prompt therefore land on the shard
//! whose prefix tree already holds its KV chunks — the cross-shard
//! analogue of the intra-node sharing ChunkAttention exploits.
//!
//! The ring ([`HashRing`]) is deterministic: virtual-node positions depend
//! only on `(seed, shard, vnode)`, so identical prompts route identically
//! across router restarts, and draining then rejoining a shard restores
//! the exact original mapping. Removing one of N members remaps only the
//! keys that lived on it (~1/N of the corpus); everything else keeps its
//! successor point untouched.
//!
//! Live **drain** is a routing-only state change: the shard stops
//! receiving new admissions but its stepper keeps running, so in-flight
//! requests finish and stream to completion — zero accepted requests are
//! lost. **Join** re-inserts the shard's points, moving only the affected
//! key range back.
//!
//! [`aggregate_expositions`] merges N per-shard `/metrics` documents into
//! one: each family keeps a cluster **rollup** sample first (sum for
//! counters, max/min/mean where summing would lie, ratio-of-sums for hit
//! rates) followed by per-shard `shard="N"` series; histograms merge
//! bucket-wise. A single-shard document passes through byte-for-byte.

use super::shard::EngineHandle;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Virtual nodes per shard on the ring; enough for ~±10% load spread at
/// small N without making membership changes expensive.
pub const RING_VNODES: usize = 64;

/// Fixed ring seed: routing must be reproducible across gateway restarts
/// (same prompts → same shard), so the seed is part of the protocol, not
/// a runtime knob.
pub const RING_SEED: u64 = 0x9e37_79b9_7f4a_7c15;

/// SplitMix64 finalizer: a cheap, well-distributed 64-bit mixer.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// The routing key for a prompt: FNV-1a over its longest chunk-aligned
/// shared prefix, finalized through SplitMix64.
///
/// `shared_tokens > 0` declares the prefix the client expects to share
/// (the system prompt); otherwise the whole prompt is the candidate. The
/// candidate is truncated down to a chunk boundary so every prompt
/// sharing the same leading chunks hashes identically regardless of its
/// private tail — the tree dedupes at chunk granularity, so that is the
/// granularity at which affinity pays. Prompts shorter than one chunk
/// (prefix-less traffic) fall back to hashing the full prompt, which
/// spreads them uniformly.
pub fn routing_key(prompt: &[u32], shared_tokens: usize, chunk_size: usize) -> u64 {
    let chunk = chunk_size.max(1);
    let declared =
        if shared_tokens > 0 { shared_tokens.min(prompt.len()) } else { prompt.len() };
    let aligned = (declared / chunk) * chunk;
    let span = if aligned > 0 { &prompt[..aligned] } else { prompt };
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in span {
        h ^= t as u64;
        h = h.wrapping_mul(0x1_0000_0001_b3);
    }
    splitmix64(h)
}

/// A consistent-hash ring over shard ids with virtual nodes.
///
/// Deterministic by construction: point positions are pure functions of
/// `(seed, shard, vnode)`. `remove` + `add` of the same shard is an exact
/// involution.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// `(position, shard)` sorted by position (shard breaks ties).
    points: Vec<(u64, usize)>,
    vnodes: usize,
    seed: u64,
}

impl HashRing {
    /// A ring with members `0..shards`.
    pub fn new(shards: usize, vnodes: usize, seed: u64) -> HashRing {
        let mut ring = HashRing { points: Vec::new(), vnodes: vnodes.max(1), seed };
        for s in 0..shards {
            ring.add(s);
        }
        ring
    }

    fn point(&self, shard: usize, vnode: usize) -> u64 {
        splitmix64(self.seed ^ splitmix64((shard as u64) << 32 | vnode as u64))
    }

    /// Insert `shard`'s virtual nodes (no-op if already a member).
    pub fn add(&mut self, shard: usize) {
        if self.contains(shard) {
            return;
        }
        for v in 0..self.vnodes {
            self.points.push((self.point(shard, v), shard));
        }
        self.points.sort_unstable();
    }

    /// Remove `shard`'s virtual nodes (no-op if not a member).
    pub fn remove(&mut self, shard: usize) {
        self.points.retain(|&(_, s)| s != shard);
    }

    pub fn contains(&self, shard: usize) -> bool {
        self.points.iter().any(|&(_, s)| s == shard)
    }

    /// Current members, ascending.
    pub fn members(&self) -> Vec<usize> {
        let mut m: Vec<usize> = self.points.iter().map(|&(_, s)| s).collect();
        m.sort_unstable();
        m.dedup();
        m
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The shard owning `key`: the first point at or after it, wrapping.
    pub fn shard_for(&self, key: u64) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let idx = self.points.partition_point(|&(p, _)| p < key);
        let (_, shard) = self.points[if idx == self.points.len() { 0 } else { idx }];
        Some(shard)
    }
}

/// The gateway's routing table: shard handles, the ring, and per-shard
/// draining flags. Ring membership changes (drain/join) are serialized by
/// the ring mutex; routing is one lock + one binary search.
pub(crate) struct Router {
    shards: Vec<Arc<EngineHandle>>,
    ring: Mutex<HashRing>,
    draining: Vec<AtomicBool>,
    chunk_size: usize,
}

impl Router {
    pub(crate) fn new(shards: Vec<Arc<EngineHandle>>, chunk_size: usize) -> Router {
        let n = shards.len();
        Router {
            shards,
            ring: Mutex::new(HashRing::new(n, RING_VNODES, RING_SEED)),
            draining: (0..n).map(|_| AtomicBool::new(false)).collect(),
            chunk_size,
        }
    }

    pub(crate) fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub(crate) fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    pub(crate) fn handles(&self) -> &[Arc<EngineHandle>] {
        &self.shards
    }

    pub(crate) fn handle(&self, id: usize) -> Option<Arc<EngineHandle>> {
        self.shards.get(id).cloned()
    }

    /// Route a key to its owning shard's handle; `None` when every shard
    /// is draining (the caller answers 503).
    pub(crate) fn route(&self, key: u64) -> Option<Arc<EngineHandle>> {
        let ring = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        ring.shard_for(key).and_then(|s| self.shards.get(s).cloned())
    }

    /// Live drain: stop routing new admissions to `id`. In-flight requests
    /// keep streaming (the shard's stepper is untouched). Idempotent.
    pub(crate) fn drain(&self, id: usize) -> Result<Vec<usize>, String> {
        if id >= self.shards.len() {
            return Err(format!("no such shard {id} (have {})", self.shards.len()));
        }
        self.draining[id].store(true, Ordering::SeqCst);
        let mut ring = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        ring.remove(id);
        Ok(ring.members())
    }

    /// Rejoin a drained shard: its ring points return to their original
    /// positions, moving back exactly the key range it owned. Idempotent.
    pub(crate) fn join(&self, id: usize) -> Result<Vec<usize>, String> {
        if id >= self.shards.len() {
            return Err(format!("no such shard {id} (have {})", self.shards.len()));
        }
        self.draining[id].store(false, Ordering::SeqCst);
        let mut ring = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        ring.add(id);
        Ok(ring.members())
    }

    pub(crate) fn is_draining(&self, id: usize) -> bool {
        self.draining.get(id).map(|d| d.load(Ordering::SeqCst)).unwrap_or(false)
    }

    pub(crate) fn members(&self) -> Vec<usize> {
        self.ring.lock().unwrap_or_else(|e| e.into_inner()).members()
    }
}

// ---------------------------------------------------------------------------
// /metrics aggregation
// ---------------------------------------------------------------------------

/// One histogram child (labels minus `le`) accumulated across shards.
struct HistChild {
    /// `le` bounds in the order the first contributing shard emitted them.
    bounds: Vec<String>,
    /// Summed cumulative count per `le` bound.
    bucket_sums: BTreeMap<String, f64>,
    sum: f64,
    count: f64,
    /// Raw per-shard children for `shard="N"` emission:
    /// `(shard, buckets as (le, cum-string), sum-string, count-string)`.
    per_shard: Vec<(usize, Vec<(String, String)>, String, String)>,
}

/// One family accumulated across shards.
struct Family {
    help: String,
    ty: String,
    /// Gauge samples grouped by label body, in first-seen order:
    /// `(labels, per-shard (shard, value, raw-string))`.
    rows: Vec<(String, Vec<(usize, f64, String)>)>,
    /// Histogram children keyed by labels-minus-le, in first-seen order.
    children: Vec<(String, HistChild)>,
}

/// Split a sample's series into `(name, label-body)`.
fn split_series(series: &str) -> (&str, &str) {
    match series.split_once('{') {
        Some((name, rest)) => (name, rest.strip_suffix('}').unwrap_or(rest)),
        None => (series, ""),
    }
}

/// Append `shard="N"` to a label body ("" stays valid).
fn with_shard(labels: &str, shard: usize) -> String {
    if labels.is_empty() {
        format!("shard=\"{shard}\"")
    } else {
        format!("{labels},shard=\"{shard}\"")
    }
}

fn fmt_series(name: &str, labels: &str) -> String {
    if labels.is_empty() {
        name.to_string()
    } else {
        format!("{name}{{{labels}}}")
    }
}

/// Cluster rollup of one gauge family's samples. Summing is right for
/// counters and occupancy; info gauges and config echoes take max (every
/// shard reports the same value), health probes take min (degraded if any
/// shard is), and pre-averaged statistics take the mean.
fn rollup_value(name: &str, values: &[f64]) -> f64 {
    let max = || values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if name.ends_with("_info") {
        return max();
    }
    if name.ends_with("tree_invariants_ok") {
        return values.iter().cloned().fold(f64::INFINITY, f64::min);
    }
    if name.ends_with("step_token_budget")
        || name.ends_with("prefill_chunk_tokens")
        || name.ends_with("pool_workers")
        || name.ends_with("pool_workers_pinned")
        || name.ends_with("decode_lag_max")
    {
        return max();
    }
    if name.ends_with("_mean")
        || name.ends_with("_p50")
        || name.ends_with("_p99")
        || name.ends_with("_rate")
    {
        return values.iter().sum::<f64>() / values.len().max(1) as f64;
    }
    values.iter().sum()
}

/// Sum one unlabeled gauge family (matched by name suffix) across docs.
fn sum_suffix(docs: &[String], suffix: &str) -> Option<f64> {
    let mut total = 0.0;
    let mut seen = false;
    for doc in docs {
        for line in doc.lines() {
            if line.starts_with('#') {
                continue;
            }
            let Some((series, value)) = line.rsplit_once(' ') else { continue };
            let (name, labels) = split_series(series);
            if labels.is_empty() && name.ends_with(suffix) {
                if let Ok(v) = value.parse::<f64>() {
                    total += v;
                    seen = true;
                }
            }
        }
    }
    seen.then_some(total)
}

/// Merge N per-shard exposition documents into one cluster document.
///
/// For every family (order taken from the first document that has it):
/// `# HELP`/`# TYPE` once, the cluster rollup sample(s) first — so
/// suffix-matching parsers and dashboards that predate sharding keep
/// reading cluster totals — then per-shard `shard="N"` series. Histograms
/// merge bucket-wise (per-shard children are emitted only for unlabeled
/// families, keeping labeled-family cardinality bounded). Hit rates are
/// recomputed as ratio-of-sums from their component counters so idle
/// shards cannot dilute them. One document passes through unchanged.
pub fn aggregate_expositions(docs: &[String]) -> String {
    if docs.len() == 1 {
        return docs[0].clone();
    }
    let mut order: Vec<String> = Vec::new();
    let mut families: BTreeMap<String, Family> = BTreeMap::new();
    // First pass: metadata, so histogram sample names resolve to families.
    for doc in docs {
        for line in doc.lines() {
            if let Some(rest) = line.strip_prefix("# HELP ") {
                let mut it = rest.splitn(2, ' ');
                let name = it.next().unwrap_or("").to_string();
                let help = it.next().unwrap_or("").to_string();
                if let std::collections::btree_map::Entry::Vacant(slot) = families.entry(name) {
                    order.push(slot.key().clone());
                    slot.insert(Family {
                        help,
                        ty: "untyped".to_string(),
                        rows: Vec::new(),
                        children: Vec::new(),
                    });
                }
            } else if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split_whitespace();
                let name = it.next().unwrap_or("");
                let ty = it.next().unwrap_or("untyped");
                if let Some(f) = families.get_mut(name) {
                    if f.ty == "untyped" {
                        f.ty = ty.to_string();
                    }
                }
            }
        }
    }
    let hist = |families: &BTreeMap<String, Family>, sname: &str| -> Option<String> {
        for suffix in ["_bucket", "_sum", "_count"] {
            if let Some(base) = sname.strip_suffix(suffix) {
                if families.get(base).is_some_and(|f| f.ty == "histogram") {
                    return Some(base.to_string());
                }
            }
        }
        None
    };
    // Second pass: samples.
    for (shard, doc) in docs.iter().enumerate() {
        for line in doc.lines() {
            if line.starts_with('#') || line.trim().is_empty() {
                continue;
            }
            let Some((series, value)) = line.rsplit_once(' ') else { continue };
            let (sname, labels) = split_series(series);
            if let Some(base) = hist(&families, sname) {
                // Histogram sample: fold into the child keyed by labels
                // minus `le`.
                let mut le: Option<String> = None;
                let child_labels: Vec<&str> = labels
                    .split(',')
                    .filter(|p| !p.is_empty())
                    .filter(|p| match p.strip_prefix("le=\"").and_then(|r| r.strip_suffix('"')) {
                        Some(bound) => {
                            le = Some(bound.to_string());
                            false
                        }
                        None => true,
                    })
                    .collect();
                let key = child_labels.join(",");
                let fam = families.get_mut(&base).expect("family registered");
                let child = match fam.children.iter_mut().find(|(k, _)| *k == key) {
                    Some((_, c)) => c,
                    None => {
                        fam.children.push((
                            key.clone(),
                            HistChild {
                                bounds: Vec::new(),
                                bucket_sums: BTreeMap::new(),
                                sum: 0.0,
                                count: 0.0,
                                per_shard: Vec::new(),
                            },
                        ));
                        &mut fam.children.last_mut().expect("just pushed").1
                    }
                };
                if child.per_shard.last().map(|p| p.0) != Some(shard) {
                    child.per_shard.push((shard, Vec::new(), "0".to_string(), "0".to_string()));
                }
                let slot = child.per_shard.last_mut().expect("just ensured");
                let v: f64 = value.parse().unwrap_or(0.0);
                if sname.ends_with("_bucket") {
                    let bound = le.unwrap_or_default();
                    if !child.bounds.contains(&bound) {
                        child.bounds.push(bound.clone());
                    }
                    *child.bucket_sums.entry(bound.clone()).or_insert(0.0) += v;
                    slot.1.push((bound, value.to_string()));
                } else if sname.ends_with("_sum") {
                    child.sum += v;
                    slot.2 = value.to_string();
                } else {
                    child.count += v;
                    slot.3 = value.to_string();
                }
            } else if let Some(fam) = families.get_mut(sname) {
                let v: f64 = match value.parse() {
                    Ok(v) => v,
                    Err(_) => continue,
                };
                match fam.rows.iter_mut().find(|(k, _)| *k == labels) {
                    Some((_, samples)) => samples.push((shard, v, value.to_string())),
                    None => fam
                        .rows
                        .push((labels.to_string(), vec![(shard, v, value.to_string())])),
                }
            }
        }
    }
    // Ratio-of-sums overrides: a mean of per-shard rates would let idle
    // shards (0/0 → 0.0) dilute the cluster number.
    let reused = sum_suffix(docs, "_prefill_reused_tokens_total");
    let computed = sum_suffix(docs, "_prefill_computed_tokens_total");
    let cache_hits = sum_suffix(docs, "_context_cache_hits_total");
    let cache_rebuilds = sum_suffix(docs, "_context_rebuilds_total");

    let mut out = String::new();
    for name in &order {
        let fam = &families[name];
        out.push_str(&format!("# HELP {name} {}\n", fam.help));
        out.push_str(&format!("# TYPE {name} {}\n", fam.ty));
        if fam.ty == "histogram" {
            for (labels, child) in &fam.children {
                // Merged cluster child first.
                for bound in &child.bounds {
                    let b = with_le(labels, bound);
                    let v = child.bucket_sums.get(bound).copied().unwrap_or(0.0);
                    out.push_str(&format!("{} {v}\n", fmt_series(&format!("{name}_bucket"), &b)));
                }
                out.push_str(&format!("{} {}\n", fmt_series(&format!("{name}_sum"), labels), child.sum));
                out.push_str(&format!("{} {}\n", fmt_series(&format!("{name}_count"), labels), child.count));
                // Per-shard children only for unlabeled families: labeled
                // families (per-phase timings) would explode cardinality.
                if labels.is_empty() {
                    for (shard, buckets, sum, count) in &child.per_shard {
                        let shard_labels = with_shard(labels, *shard);
                        for (bound, cum) in buckets {
                            let b = with_le(&shard_labels, bound);
                            out.push_str(&format!(
                                "{} {cum}\n",
                                fmt_series(&format!("{name}_bucket"), &b)
                            ));
                        }
                        out.push_str(&format!(
                            "{} {sum}\n",
                            fmt_series(&format!("{name}_sum"), &shard_labels)
                        ));
                        out.push_str(&format!(
                            "{} {count}\n",
                            fmt_series(&format!("{name}_count"), &shard_labels)
                        ));
                    }
                }
            }
        } else {
            for (labels, samples) in &fam.rows {
                let values: Vec<f64> = samples.iter().map(|&(_, v, _)| v).collect();
                let mut v = rollup_value(name, &values);
                if name.ends_with("_prefix_hit_rate") {
                    if let (Some(r), Some(c)) = (reused, computed) {
                        v = r / (r + c).max(1.0);
                    }
                } else if name.ends_with("_context_cache_hit_rate") {
                    if let (Some(h), Some(r)) = (cache_hits, cache_rebuilds) {
                        v = if h + r > 0.0 { h / (h + r) } else { 0.0 };
                    }
                }
                out.push_str(&format!("{} {v}\n", fmt_series(name, labels)));
                for (shard, _, raw) in samples {
                    out.push_str(&format!(
                        "{} {raw}\n",
                        fmt_series(name, &with_shard(labels, *shard))
                    ));
                }
            }
        }
    }
    out
}

/// Append `le="bound"` to a label body (the `le` label goes last, matching
/// the exporter's layout).
fn with_le(labels: &str, bound: &str) -> String {
    if labels.is_empty() {
        format!("le=\"{bound}\"")
    } else {
        format!("{labels},le=\"{bound}\"")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::client::{gauge_value, histogram_snapshot, labeled_gauge_value, lint_exposition};

    fn corpus(n: usize, chunk: usize) -> Vec<u64> {
        // Distinct chunk-aligned prefixes: each "tenant" is one shared
        // system prompt of 2 chunks.
        (0..n)
            .map(|i| {
                let prompt: Vec<u32> = (0..2 * chunk as u32).map(|j| i as u32 * 10_000 + j).collect();
                routing_key(&prompt, 2 * chunk, chunk)
            })
            .collect()
    }

    #[test]
    fn draining_one_of_n_remaps_only_its_own_keys() {
        let keys = corpus(2000, 64);
        let mut ring = HashRing::new(4, RING_VNODES, RING_SEED);
        let before: Vec<usize> = keys.iter().map(|&k| ring.shard_for(k).unwrap()).collect();
        // Every member owns a sane share (vnode spread, not exact balance).
        for s in 0..4 {
            let share = before.iter().filter(|&&b| b == s).count() as f64 / keys.len() as f64;
            assert!((0.10..=0.45).contains(&share), "shard {s} owns {share:.2} of the corpus");
        }
        ring.remove(2);
        let after: Vec<usize> = keys.iter().map(|&k| ring.shard_for(k).unwrap()).collect();
        let mut moved = 0usize;
        for (i, (&b, &a)) in before.iter().zip(after.iter()).enumerate() {
            if b == 2 {
                assert_ne!(a, 2, "key {i} still routed to the drained shard");
                moved += 1;
            } else {
                // Consistent hashing: keys not owned by the drained shard
                // keep their successor point, hence their shard.
                assert_eq!(a, b, "key {i} moved although its shard stayed");
            }
        }
        let frac = moved as f64 / keys.len() as f64;
        assert!((0.10..=0.45).contains(&frac), "drain moved {frac:.2} of keys, expected ~1/4");
        // Join restores the exact original mapping.
        ring.add(2);
        let rejoined: Vec<usize> = keys.iter().map(|&k| ring.shard_for(k).unwrap()).collect();
        assert_eq!(rejoined, before);
    }

    #[test]
    fn ring_is_deterministic_across_restarts() {
        let keys = corpus(500, 64);
        let a = HashRing::new(3, RING_VNODES, RING_SEED);
        let b = HashRing::new(3, RING_VNODES, RING_SEED);
        for &k in &keys {
            assert_eq!(a.shard_for(k), b.shard_for(k));
        }
        assert_eq!(a.members(), vec![0, 1, 2]);
        assert!(HashRing::new(0, RING_VNODES, RING_SEED).shard_for(7).is_none());
    }

    #[test]
    fn routing_key_is_chunk_aligned_and_tail_blind() {
        let chunk = 64;
        let prefix: Vec<u32> = (0..128).collect();
        let mut a = prefix.clone();
        a.extend([900, 901, 902]);
        let mut b = prefix.clone();
        b.extend([7000, 7001]);
        // Same declared shared prefix → same key, any private tail.
        assert_eq!(routing_key(&a, 128, chunk), routing_key(&b, 128, chunk));
        // A mid-chunk shared length truncates down to the boundary.
        assert_eq!(routing_key(&a, 130, chunk), routing_key(&b, 128, chunk));
        // Different prefixes diverge.
        let other: Vec<u32> = (1000..1128).collect();
        assert_ne!(routing_key(&other, 128, chunk), routing_key(&a, 128, chunk));
        // Prefix-less short prompts still hash deterministically (full
        // prompt fallback) and depend on the tail.
        let s1 = vec![1, 2, 3];
        let s2 = vec![1, 2, 4];
        assert_eq!(routing_key(&s1, 0, chunk), routing_key(&s1, 0, chunk));
        assert_ne!(routing_key(&s1, 0, chunk), routing_key(&s2, 0, chunk));
    }

    fn doc(prefix: &str, depth: f64, reused: f64, computed: f64, tenant: &str, ttft: &[f64]) -> String {
        use crate::metrics::{push_gauge, push_histogram, push_labeled_gauge, push_labeled_series};
        use crate::util::stats::LogHistogram;
        let mut out = String::new();
        push_gauge(&mut out, prefix, "queue_depth", "q", depth);
        push_gauge(&mut out, prefix, "prefill_reused_tokens_total", "r", reused);
        push_gauge(&mut out, prefix, "prefill_computed_tokens_total", "c", computed);
        push_gauge(
            &mut out,
            prefix,
            "prefix_hit_rate",
            "h",
            reused / (reused + computed).max(1.0),
        );
        push_gauge(&mut out, prefix, "step_token_budget", "b", 128.0);
        push_labeled_gauge(&mut out, prefix, "kv_dtype_info", "d", &[("dtype", "f16")], 1.0);
        push_labeled_series(
            &mut out,
            prefix,
            "tenant_admitted_total",
            "t",
            &[(vec![("tenant", tenant.to_string())], 2.0)],
        );
        let mut h = LogHistogram::time_seconds();
        for &x in ttft {
            h.record(x);
        }
        push_histogram(&mut out, prefix, "ttft_seconds", "ttft", &h);
        out
    }

    #[test]
    fn aggregation_rolls_up_then_labels_per_shard() {
        let docs = vec![
            doc("gw", 3.0, 900.0, 100.0, "0", &[0.01, 0.02]),
            doc("gw", 5.0, 0.0, 0.0, "7", &[0.04]),
        ];
        let merged = aggregate_expositions(&docs);
        assert_eq!(lint_exposition(&merged), Vec::<String>::new(), "merged doc must lint clean");
        // Counters sum; the rollup line is the suffix-matchable one.
        assert_eq!(gauge_value(&merged, "queue_depth"), Some(8.0));
        // Config echoes take max, not sum.
        assert!(merged.contains("gw_step_token_budget 128\n"), "{merged}");
        // Hit rate is ratio-of-sums (0.9), not the diluted mean (0.45).
        let hit = gauge_value(&merged, "prefix_hit_rate").unwrap();
        assert!((hit - 0.9).abs() < 1e-9, "hit rate {hit}");
        // Info gauges keep their label and value 1.
        assert!(merged.contains("gw_kv_dtype_info{dtype=\"f16\"} 1\n"), "{merged}");
        // Tenant series from different shards coexist with rollups first.
        assert_eq!(labeled_gauge_value(&merged, "tenant_admitted_total", "tenant", "0"), Some(2.0));
        assert_eq!(labeled_gauge_value(&merged, "tenant_admitted_total", "tenant", "7"), Some(2.0));
        // Per-shard series are present and labeled.
        assert_eq!(labeled_gauge_value(&merged, "queue_depth", "shard", "1"), Some(5.0));
        // Histograms merge bucket-wise: cluster count is 3, and the
        // unlabeled child is the rollup (exact-label-match semantics).
        let snap = histogram_snapshot(&merged, "ttft_seconds", None).expect("merged histogram");
        assert_eq!(snap.count, 3);
        let s0 = histogram_snapshot(&merged, "ttft_seconds", Some(("shard", "0"))).expect("shard 0");
        assert_eq!(s0.count, 2);
        // Single doc passes through byte-for-byte.
        assert_eq!(aggregate_expositions(&docs[..1]), docs[0]);
    }
}
