//! # ChunkAttention
//!
//! Reproduction of *ChunkAttention: Efficient Self-Attention with
//! Prefix-Aware KV Cache and Two-Phase Partition* (Ye et al., ACL 2024) as a
//! three-layer Rust + JAX + Pallas serving library:
//!
//! - **Layer 3 (this crate)** — the serving coordinator:
//!   - prefix-aware KV cache ([`kvcache::PrefixTree`]) storing K/V in
//!     dtype-erased slabs ([`kvcache::KvSlab`], `f32`/`f16`/`bf16` via
//!     [`kvcache::KvDtype`] and `--kv-dtype`): half-precision storage
//!     halves resident KV bytes and the chunk-first phase's streamed
//!     traffic while every kernel keeps f32 accumulation (see DESIGN.md
//!     "The KV storage seam"), with a cached,
//!     generation-counted kernel context: the tree bumps
//!     [`kvcache::PrefixTree::generation`] only on structural changes, so
//!     the engine reuses one [`kvcache::TreeContext`] across every decode
//!     step between chunk-boundary crossings (observable via the
//!     `context_rebuilds` / `context_cache_hits` metrics);
//!   - the two-phase-partition decode kernel and its baselines
//!     ([`attention`]): production is the 2D *(head × chunk-run)*
//!     schedule [`attention::tpp_attention_2d`] — chunk-first partials
//!     fan out over `heads × runs` pool tasks, sequence-first merges fan
//!     out over `heads × batch`, deterministically merged so results are
//!     bit-identical for every thread count — on top of an 8-row,
//!     d-monomorphized register-blocked micro-kernel
//!     ([`attention::online`]);
//!   - a continuous-batching engine ([`coordinator`]) with the ablation
//!     switchboard ([`coordinator::AblationConfig`]) keeping the 1D and
//!     single-threaded kernel variants runnable as baselines;
//!   - an online serving gateway ([`server`]): a dependency-free HTTP/1.1
//!     frontend with SSE token streaming, bounded admission (429
//!     backpressure), client-disconnect cancellation, graceful drain, and
//!     a closed-loop load generator (`chunk-serve bench-http` /
//!     `gateway`);
//!   - workload generation ([`workload`]) and an A100 roofline model
//!     ([`perf_model`]) for the paper's analytical tables.
//! - **Layer 2** — `python/compile/model.py`: a mini Llama-style decoder in
//!   JAX, AOT-lowered to HLO text artifacts at build time.
//! - **Layer 1** — `python/compile/kernels/chunk_attn.py`: the TPP kernel in
//!   Pallas (interpret mode), lowered inside the L2 module.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT and serves
//! them from the decode path — Python never runs at request time.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod attention;
pub mod coordinator;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod perf_model;
pub mod runtime;
pub mod server;
pub mod util;
pub mod workload;
