//! Work-stealing-free persistent thread pool with a `parallel_for` primitive.
//!
//! The kernel layer partitions work over (head, chunk) pairs exactly as the
//! paper partitions CUDA thread blocks; on CPU those partitions map to pool
//! workers. The pool is persistent (workers park between calls) so the decode
//! hot loop pays no thread-spawn cost per iteration.
//!
//! On a single-core host the pool degrades gracefully: `ThreadPool::new(1)`
//! runs everything inline on the caller thread with zero synchronisation.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size persistent worker pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` workers. `size == 1` means "inline": no
    /// workers are spawned and all work runs on the caller.
    pub fn new(size: usize) -> Self {
        assert!(size >= 1);
        if size == 1 {
            return ThreadPool { tx: None, workers: Vec::new(), size };
        }
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("chunk-attn-worker-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, size }
    }

    /// Pool sized from `CHUNK_ATTN_THREADS` env or the number of cpus.
    pub fn default_for_host() -> Self {
        let n = std::env::var("CHUNK_ATTN_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        Self::new(n.max(1))
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `f(i)` for every `i` in `0..n`, distributing indices over workers
    /// in contiguous blocks. Blocks until all iterations complete.
    ///
    /// `f` must be `Sync` because multiple workers call it concurrently.
    ///
    /// Panic safety: a panic inside `f` is caught on the worker, the latch
    /// still counts down (no deadlocked caller, no dead worker thread), the
    /// remaining indices are abandoned, and the first panic payload is
    /// re-raised on the submitting thread once every task has stopped.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        if self.tx.is_none() || n == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let latch = Arc::new(Latch::new(self.size.min(n)));
        let next = Arc::new(AtomicUsize::new(0));
        let poisoned = Arc::new(AtomicBool::new(false));
        let panic_payload: Arc<Mutex<Option<Box<dyn std::any::Any + Send>>>> =
            Arc::new(Mutex::new(None));
        // Safety: `parallel_for` blocks on the latch until every submitted
        // closure has finished, so borrowing `f` across the 'static job
        // boundary never outlives this frame.
        let f_ptr = &f as *const F as usize;
        let tx = self.tx.as_ref().unwrap();
        let grain = (n / (self.size * 4)).max(1);
        for _ in 0..self.size.min(n) {
            let latch = Arc::clone(&latch);
            let next = Arc::clone(&next);
            let poisoned = Arc::clone(&poisoned);
            let panic_payload = Arc::clone(&panic_payload);
            let job: Job = Box::new(move || {
                let f = unsafe { &*(f_ptr as *const F) };
                while !poisoned.load(Ordering::Relaxed) {
                    let start = next.fetch_add(grain, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + grain).min(n);
                    // Catch so the worker thread survives and the latch
                    // always fires; re-raised on the caller below.
                    if let Err(p) = std::panic::catch_unwind(AssertUnwindSafe(|| {
                        for i in start..end {
                            f(i);
                        }
                    })) {
                        let mut slot = panic_payload.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(p);
                        }
                        poisoned.store(true, Ordering::Relaxed);
                        break;
                    }
                }
                latch.count_down();
            });
            tx.send(job).expect("pool alive");
        }
        latch.wait();
        if let Some(p) = panic_payload.lock().unwrap().take() {
            std::panic::resume_unwind(p);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>) {
    loop {
        let job = {
            let guard = rx.lock().expect("pool lock");
            guard.recv()
        };
        match job {
            Ok(job) => job(),
            Err(_) => break, // channel closed: pool dropped
        }
    }
}

/// Count-down latch: `wait` blocks until `count_down` has been called N times.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch { remaining: Mutex::new(n), cv: Condvar::new() }
    }

    fn count_down(&self) {
        let mut rem = self.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            rem = self.cv.wait(rem).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn inline_pool_runs_everything() {
        let pool = ThreadPool::new(1);
        let sum = AtomicU64::new(0);
        pool.parallel_for(100, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn multi_worker_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn pool_is_reusable() {
        let pool = ThreadPool::new(3);
        for round in 0..10 {
            let sum = AtomicU64::new(0);
            pool.parallel_for(round * 13 + 1, |i| {
                sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
            let n = (round * 13 + 1) as u64;
            assert_eq!(sum.load(Ordering::Relaxed), n * (n + 1) / 2);
        }
    }

    #[test]
    fn zero_iterations_is_noop() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(10, |_| {});
        drop(pool); // must not hang
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        // Before the fix this deadlocked: the panicking worker skipped
        // `latch.count_down()` and `wait` blocked forever.
        let pool = ThreadPool::new(4);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(100, |i| {
                if i == 37 {
                    panic!("task 37 exploded");
                }
            });
        }));
        let payload = result.expect_err("panic must reach the caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert!(msg.contains("exploded"), "unexpected payload {msg:?}");
    }

    #[test]
    fn pool_survives_a_panicked_parallel_for() {
        let pool = ThreadPool::new(3);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(30, |i| {
                if i % 7 == 3 {
                    panic!("boom");
                }
            });
        }));
        // Workers caught the panic instead of dying; the pool still works.
        let sum = AtomicU64::new(0);
        pool.parallel_for(100, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn inline_pool_panic_propagates() {
        let pool = ThreadPool::new(1);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(4, |i| {
                if i == 2 {
                    panic!("inline");
                }
            });
        }));
        assert!(result.is_err());
    }
}
