//! Persistent thread pool with reusable `parallel_for` machinery, a
//! locality-aware *sticky* schedule, and best-effort core-affinity pinning.
//!
//! The kernel layer partitions work over (head, chunk) pairs exactly as the
//! paper partitions CUDA thread blocks; on CPU those partitions map to pool
//! workers. The pool is persistent (workers park between calls) so the decode
//! hot loop pays no thread-spawn cost per iteration.
//!
//! ## Steady-state cost
//!
//! The original pool funnelled per-call boxed jobs through one
//! `Mutex<Receiver>`, allocating a latch, several `Arc`s and `size` boxed
//! closures on every `parallel_for` — visible in
//! `step_phase_seconds{phase=chunk_first}` at small batch. This version
//! broadcasts an *epoch*: the caller publishes one `Copy` operation record
//! (a type-erased borrow of the closure plus the iteration geometry) under
//! a mutex, bumps an epoch counter and wakes the workers; completion is a
//! reusable counter + condvar. A decode step therefore allocates nothing
//! in the pool.
//!
//! ## Schedules
//!
//! [`ThreadPool::parallel_for`] claims grain-sized index blocks dynamically
//! (load balances when per-index cost varies); `parallel_for_sticky`
//! instead gives worker `w` the fixed contiguous
//! range `[w·n/P, (w+1)·n/P)`: the same index lands on the same worker on
//! every call, so per-index working sets (a chunk-run's KV slabs — slab
//! addresses are stable) stay in one worker's cache across decode steps
//! (the CoDec/RelayAttention locality argument). Numerics never depend on
//! the schedule — both produce bit-identical results for the kernels here.
//!
//! ## Affinity
//!
//! On Linux each worker pins itself to one allowed CPU (round-robin over
//! the process's `sched_getaffinity` mask) via raw `sched_setaffinity`
//! syscalls — best effort, a no-op elsewhere. `PALLAS_AFFINITY=none`
//! disables pinning; [`affinity_mode`] and [`placement`] expose what
//! happened for `/metrics` and startup logs.
//!
//! On a single-core host the pool degrades gracefully: `ThreadPool::new(1)`
//! runs everything inline on the caller thread with zero synchronisation.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// How an operation's index space maps to workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Schedule {
    /// Grain-sized blocks claimed dynamically from a shared cursor.
    Dynamic,
    /// Deterministic contiguous partition: worker `w` owns `[w·n/P, (w+1)·n/P)`.
    Sticky,
}

/// A published operation: a type-erased borrow of the caller's closure plus
/// iteration geometry. `data` borrows the `parallel_for` frame; the epoch
/// protocol guarantees every participant finishes (and counts down) before
/// that frame returns, so the borrow never escapes.
#[derive(Clone, Copy)]
struct Op {
    data: *const (),
    call: unsafe fn(*const (), usize),
    n: usize,
    grain: usize,
    participants: usize,
    schedule: Schedule,
}

// Safety: `Op` is only dereferenced between publication and the matching
// count-down, while the owning `parallel_for` frame is pinned on the
// done-condvar; the raw pointer itself is just bits.
unsafe impl Send for Op {}

unsafe fn call_erased<F: Fn(usize) + Sync>(data: *const (), i: usize) {
    (*(data as *const F))(i);
}

struct Ctrl {
    epoch: u64,
    op: Option<Op>,
    shutdown: bool,
}

struct Done {
    remaining: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

struct Shared {
    ctrl: Mutex<Ctrl>,
    work_cv: Condvar,
    done: Mutex<Done>,
    done_cv: Condvar,
    next: AtomicUsize,
    poisoned: AtomicBool,
    /// Workers of this pool that successfully pinned to a core.
    pinned: AtomicUsize,
}

// Process-wide placement counters for /metrics (live pools only).
static POOLS: AtomicUsize = AtomicUsize::new(0);
static WORKERS: AtomicUsize = AtomicUsize::new(0);
static PINNED: AtomicUsize = AtomicUsize::new(0);

/// Live thread-pool placement across the process, for `/metrics`.
#[derive(Debug, Clone, Copy)]
pub struct PoolPlacement {
    pub pools: usize,
    pub workers: usize,
    pub pinned: usize,
}

/// Snapshot of the process-wide pool placement counters.
pub fn placement() -> PoolPlacement {
    PoolPlacement {
        pools: POOLS.load(Ordering::Relaxed),
        workers: WORKERS.load(Ordering::Relaxed),
        pinned: PINNED.load(Ordering::Relaxed),
    }
}

/// The effective affinity policy: `"compact"` (workers pin round-robin over
/// the allowed CPUs), `"none"` (`PALLAS_AFFINITY=none`), or
/// `"unsupported"` (no Linux `sched_setaffinity` on this target).
pub fn affinity_mode() -> &'static str {
    if !affinity::supported() {
        "unsupported"
    } else if affinity_requested() {
        "compact"
    } else {
        "none"
    }
}

fn affinity_requested() -> bool {
    !matches!(
        std::env::var("PALLAS_AFFINITY").ok().as_deref(),
        Some("none") | Some("off") | Some("0")
    )
}

/// A fixed-size persistent worker pool.
pub struct ThreadPool {
    shared: Option<Arc<Shared>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
    /// Serialises concurrent `parallel_for` callers (one op slot).
    submit: Mutex<()>,
}

impl ThreadPool {
    /// Create a pool with `size` workers. `size == 1` means "inline": no
    /// workers are spawned and all work runs on the caller.
    pub fn new(size: usize) -> Self {
        assert!(size >= 1);
        POOLS.fetch_add(1, Ordering::Relaxed);
        if size == 1 {
            return ThreadPool { shared: None, workers: Vec::new(), size, submit: Mutex::new(()) };
        }
        let shared = Arc::new(Shared {
            ctrl: Mutex::new(Ctrl { epoch: 0, op: None, shutdown: false }),
            work_cv: Condvar::new(),
            done: Mutex::new(Done { remaining: 0, panic: None }),
            done_cv: Condvar::new(),
            next: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
            pinned: AtomicUsize::new(0),
        });
        let cpus = if affinity::supported() && affinity_requested() {
            affinity::allowed_cpus()
        } else {
            Vec::new()
        };
        // Keep the global pinned ≤ workers invariant: count the workers
        // before any of them can report a successful pin.
        WORKERS.fetch_add(size, Ordering::Relaxed);
        let workers = (0..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                let pin_cpu = if cpus.is_empty() { None } else { Some(cpus[i % cpus.len()]) };
                std::thread::Builder::new()
                    .name(format!("chunk-attn-worker-{i}"))
                    .spawn(move || worker_loop(shared, i, pin_cpu))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared: Some(shared), workers, size, submit: Mutex::new(()) }
    }

    /// Pool sized from `CHUNK_ATTN_THREADS` env or the number of cpus.
    pub fn default_for_host() -> Self {
        let n = std::env::var("CHUNK_ATTN_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        Self::new(n.max(1))
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `f(i)` for every `i` in `0..n`, workers claiming contiguous
    /// grain-sized blocks dynamically. Blocks until all iterations complete.
    ///
    /// `f` must be `Sync` because multiple workers call it concurrently.
    ///
    /// Panic safety: a panic inside `f` is caught on the worker, completion
    /// still counts down (no deadlocked caller, no dead worker thread), the
    /// remaining indices are abandoned, and the first panic payload is
    /// re-raised on the submitting thread once every participant has
    /// stopped.
    ///
    /// Not reentrant: `f` must not call back into the same pool.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.run(n, f, Schedule::Dynamic);
    }

    /// Like [`ThreadPool::parallel_for`], but with the deterministic sticky
    /// partition: index `i` always runs on worker `i·P/n` (same mapping on
    /// every call with the same `n`), trading load balancing for cache
    /// locality of per-index working sets across calls.
    pub fn parallel_for_sticky<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.run(n, f, Schedule::Sticky);
    }

    fn run<F>(&self, n: usize, f: F, schedule: Schedule)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let Some(shared) = &self.shared else {
            for i in 0..n {
                f(i);
            }
            return;
        };
        if n == 1 {
            f(0);
            return;
        }
        let _caller = self.submit.lock().unwrap();
        let participants = self.size.min(n);
        let grain = (n / (self.size * 4)).max(1);
        shared.next.store(0, Ordering::Relaxed);
        shared.poisoned.store(false, Ordering::Relaxed);
        {
            let mut done = shared.done.lock().unwrap();
            done.remaining = participants;
            done.panic = None;
        }
        let op = Op {
            data: &f as *const F as *const (),
            call: call_erased::<F>,
            n,
            grain,
            participants,
            schedule,
        };
        {
            let mut ctrl = shared.ctrl.lock().unwrap();
            ctrl.epoch += 1;
            ctrl.op = Some(op);
        }
        shared.work_cv.notify_all();
        let mut done = shared.done.lock().unwrap();
        while done.remaining > 0 {
            done = shared.done_cv.wait(done).unwrap();
        }
        let payload = done.panic.take();
        drop(done);
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if let Some(shared) = self.shared.take() {
            {
                let mut ctrl = shared.ctrl.lock().unwrap();
                ctrl.shutdown = true;
            }
            shared.work_cv.notify_all();
            let spawned = self.workers.len();
            for w in self.workers.drain(..) {
                let _ = w.join();
            }
            // Workers are joined, so the pool's pin count is final.
            PINNED.fetch_sub(shared.pinned.load(Ordering::Relaxed), Ordering::Relaxed);
            WORKERS.fetch_sub(spawned, Ordering::Relaxed);
        }
        POOLS.fetch_sub(1, Ordering::Relaxed);
    }
}

fn worker_loop(shared: Arc<Shared>, index: usize, pin_cpu: Option<usize>) {
    if let Some(cpu) = pin_cpu {
        if affinity::pin_current(cpu) {
            shared.pinned.fetch_add(1, Ordering::Relaxed);
            PINNED.fetch_add(1, Ordering::Relaxed);
        }
    }
    let mut seen = 0u64;
    loop {
        let op = {
            let mut ctrl = shared.ctrl.lock().unwrap();
            loop {
                if ctrl.shutdown {
                    return;
                }
                if ctrl.epoch != seen {
                    seen = ctrl.epoch;
                    break ctrl.op;
                }
                ctrl = shared.work_cv.wait(ctrl).unwrap();
            }
        };
        let Some(op) = op else { continue };
        if index >= op.participants {
            continue;
        }
        run_op(&shared, &op, index);
        let mut done = shared.done.lock().unwrap();
        done.remaining -= 1;
        if done.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

fn run_op(shared: &Shared, op: &Op, worker: usize) {
    let run_range = |lo: usize, hi: usize| {
        // Catch so the worker thread survives and completion always counts
        // down; the payload is re-raised on the caller.
        std::panic::catch_unwind(AssertUnwindSafe(|| {
            for i in lo..hi {
                unsafe { (op.call)(op.data, i) };
            }
        }))
    };
    match op.schedule {
        Schedule::Dynamic => loop {
            if shared.poisoned.load(Ordering::Relaxed) {
                return;
            }
            let start = shared.next.fetch_add(op.grain, Ordering::Relaxed);
            if start >= op.n {
                return;
            }
            if let Err(p) = run_range(start, (start + op.grain).min(op.n)) {
                poison(shared, p);
                return;
            }
        },
        Schedule::Sticky => {
            let lo = worker * op.n / op.participants;
            let hi = (worker + 1) * op.n / op.participants;
            let mut s = lo;
            while s < hi {
                if shared.poisoned.load(Ordering::Relaxed) {
                    return;
                }
                let e = (s + op.grain).min(hi);
                if let Err(p) = run_range(s, e) {
                    poison(shared, p);
                    return;
                }
                s = e;
            }
        }
    }
}

fn poison(shared: &Shared, p: Box<dyn std::any::Any + Send>) {
    {
        let mut done = shared.done.lock().unwrap();
        if done.panic.is_none() {
            done.panic = Some(p);
        }
    }
    shared.poisoned.store(true, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Best-effort core affinity. No libc in the offline crate set, so the two
// Linux targets issue raw syscalls; everything else is a no-op.
// ---------------------------------------------------------------------------

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod affinity {
    /// 16 × 64 bits = 1024 CPUs, the conventional cpu_set_t size.
    const MASK_WORDS: usize = 16;

    #[cfg(target_arch = "x86_64")]
    const SYS_SETAFFINITY: usize = 203;
    #[cfg(target_arch = "x86_64")]
    const SYS_GETAFFINITY: usize = 204;
    #[cfg(target_arch = "aarch64")]
    const SYS_SETAFFINITY: usize = 122;
    #[cfg(target_arch = "aarch64")]
    const SYS_GETAFFINITY: usize = 123;

    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall3(nr: usize, a1: usize, a2: usize, a3: usize) -> isize {
        let ret: isize;
        // `syscall` clobbers rcx/r11 and rflags (so no preserves_flags).
        std::arch::asm!(
            "syscall",
            inlateout("rax") nr as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall3(nr: usize, a1: usize, a2: usize, a3: usize) -> isize {
        let ret: isize;
        std::arch::asm!(
            "svc 0",
            in("x8") nr,
            inlateout("x0") a1 as isize => ret,
            in("x1") a2,
            in("x2") a3,
            options(nostack),
        );
        ret
    }

    pub(super) fn supported() -> bool {
        true
    }

    /// CPUs the process may run on, from `sched_getaffinity(0)` — respects
    /// cgroup cpusets, so pinning targets only CPUs we can actually use.
    /// Empty on failure (callers then skip pinning).
    pub(super) fn allowed_cpus() -> Vec<usize> {
        let mut mask = [0u64; MASK_WORDS];
        let ret = unsafe {
            syscall3(
                SYS_GETAFFINITY,
                0,
                std::mem::size_of_val(&mask),
                mask.as_mut_ptr() as usize,
            )
        };
        if ret <= 0 {
            return Vec::new();
        }
        let mut cpus = Vec::new();
        for (word, &bits) in mask.iter().enumerate() {
            for bit in 0..64 {
                if bits & (1u64 << bit) != 0 {
                    cpus.push(word * 64 + bit);
                }
            }
        }
        cpus
    }

    /// Pin the calling thread to one CPU. Best effort: `false` on any
    /// failure (e.g. the CPU left the allowed set), leaving the thread
    /// unpinned.
    pub(super) fn pin_current(cpu: usize) -> bool {
        if cpu >= MASK_WORDS * 64 {
            return false;
        }
        let mut mask = [0u64; MASK_WORDS];
        mask[cpu / 64] = 1u64 << (cpu % 64);
        let ret = unsafe {
            syscall3(SYS_SETAFFINITY, 0, std::mem::size_of_val(&mask), mask.as_ptr() as usize)
        };
        ret == 0
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod affinity {
    pub(super) fn supported() -> bool {
        false
    }

    pub(super) fn allowed_cpus() -> Vec<usize> {
        Vec::new()
    }

    pub(super) fn pin_current(_cpu: usize) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn inline_pool_runs_everything() {
        let pool = ThreadPool::new(1);
        let sum = AtomicU64::new(0);
        pool.parallel_for(100, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn multi_worker_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn pool_is_reusable() {
        let pool = ThreadPool::new(3);
        for round in 0..10 {
            let sum = AtomicU64::new(0);
            pool.parallel_for(round * 13 + 1, |i| {
                sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
            let n = (round * 13 + 1) as u64;
            assert_eq!(sum.load(Ordering::Relaxed), n * (n + 1) / 2);
        }
    }

    #[test]
    fn zero_iterations_is_noop() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(10, |_| {});
        drop(pool); // must not hang
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        // Before the fix this deadlocked: the panicking worker skipped
        // the completion count-down and the caller blocked forever.
        let pool = ThreadPool::new(4);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(100, |i| {
                if i == 37 {
                    panic!("task 37 exploded");
                }
            });
        }));
        let payload = result.expect_err("panic must reach the caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert!(msg.contains("exploded"), "unexpected payload {msg:?}");
    }

    #[test]
    fn pool_survives_a_panicked_parallel_for() {
        let pool = ThreadPool::new(3);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(30, |i| {
                if i % 7 == 3 {
                    panic!("boom");
                }
            });
        }));
        // Workers caught the panic instead of dying; the pool still works.
        let sum = AtomicU64::new(0);
        pool.parallel_for(100, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn inline_pool_panic_propagates() {
        let pool = ThreadPool::new(1);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(4, |i| {
                if i == 2 {
                    panic!("inline");
                }
            });
        }));
        assert!(result.is_err());
    }

    #[test]
    fn sticky_covers_all_indices() {
        let pool = ThreadPool::new(4);
        for &n in &[1usize, 3, 4, 7, 103, 1000] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.parallel_for_sticky(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "n={n}: every index exactly once"
            );
        }
    }

    #[test]
    fn sticky_maps_indices_to_the_same_worker_every_call() {
        let pool = ThreadPool::new(4);
        let n = 103;
        let record = || {
            let owners: Vec<Mutex<Option<std::thread::ThreadId>>> =
                (0..n).map(|_| Mutex::new(None)).collect();
            pool.parallel_for_sticky(n, |i| {
                *owners[i].lock().unwrap() = Some(std::thread::current().id());
            });
            owners.into_iter().map(|m| m.into_inner().unwrap().unwrap()).collect::<Vec<_>>()
        };
        let first = record();
        for round in 0..3 {
            assert_eq!(record(), first, "round {round}: index→worker mapping must be stable");
        }
    }

    #[test]
    fn sticky_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for_sticky(60, |i| {
                if i == 41 {
                    panic!("sticky boom");
                }
            });
        }));
        assert!(result.is_err());
        let sum = AtomicU64::new(0);
        pool.parallel_for_sticky(100, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn inline_pool_sticky_runs_everything() {
        let pool = ThreadPool::new(1);
        let sum = AtomicU64::new(0);
        pool.parallel_for_sticky(100, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn placement_counters_track_live_pools() {
        // Other tests create and drop pools concurrently, so only the
        // invariants that survive interleaving are asserted.
        let pool = ThreadPool::new(3);
        let snap = placement();
        assert!(snap.pools >= 1, "our pool is live: {snap:?}");
        assert!(snap.workers >= 3, "our 3 workers are counted: {snap:?}");
        assert!(snap.pinned <= snap.workers, "pinned never exceeds workers: {snap:?}");
        drop(pool);
    }

    #[test]
    fn affinity_mode_is_a_known_label() {
        assert!(["compact", "none", "unsupported"].contains(&affinity_mode()));
    }
}
