//! Minimal JSON value model, writer, and parser.
//!
//! `serde`/`serde_json` are not in the offline crate set; benchmark results,
//! artifact manifests, and metrics snapshots are exchanged as JSON, so a
//! small self-contained implementation lives here. The parser accepts
//! standard JSON (RFC 8259) minus `\u` surrogate-pair edge cases, which none
//! of our producers emit.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if self is not an object.
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<Json>> for Json {
    fn from(x: Vec<Json>) -> Json {
        Json::Arr(x)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", "chunk-attn").set("n", 42i64).set("ratio", 0.75).set("ok", true);
        j.set("tags", Json::Arr(vec!["a".into(), "b".into()]));
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2.5, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(j.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""tab\t quote\" uA""#).unwrap();
        assert_eq!(j.as_str(), Some("tab\t quote\" uA"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn pretty_is_parseable() {
        let mut j = Json::obj();
        j.set("xs", Json::Arr(vec![1i64.into(), 2i64.into()]));
        let back = Json::parse(&j.pretty()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
    }
}
