//! Criterion-style micro-benchmark harness (criterion itself is not in the
//! offline crate set). Each `cargo bench` target is a `harness = false`
//! binary that builds a [`BenchSuite`], registers measurements, and calls
//! [`BenchSuite::finish`] to print a table and write JSON results.
//!
//! Measurement protocol: warmup iterations, then timed iterations until both
//! a minimum sample count and a minimum measurement time are reached. Wall
//! clock only — this host has one core, so cycle counters add nothing.

use super::json::Json;
use super::stats::{fmt_us, Summary};
use std::time::Instant;

/// Global bench mode, from the `CHUNK_ATTN_BENCH_MODE` env var:
/// `quick` (default; smaller shapes, fewer samples) or `full` (paper-scale).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Quick,
    Full,
}

impl Mode {
    pub fn from_env() -> Mode {
        match std::env::var("CHUNK_ATTN_BENCH_MODE").as_deref() {
            Ok("full") | Ok("FULL") => Mode::Full,
            _ => Mode::Quick,
        }
    }

    pub fn is_full(self) -> bool {
        self == Mode::Full
    }

    /// Pick `q` in quick mode, `f` in full mode.
    pub fn pick<T>(self, q: T, f: T) -> T {
        match self {
            Mode::Quick => q,
            Mode::Full => f,
        }
    }
}

/// Measurement settings.
#[derive(Debug, Clone, Copy)]
pub struct Settings {
    pub warmup_iters: usize,
    pub min_samples: usize,
    pub max_samples: usize,
    pub min_time_s: f64,
}

impl Settings {
    pub fn for_mode(mode: Mode) -> Settings {
        match mode {
            Mode::Quick => Settings { warmup_iters: 1, min_samples: 3, max_samples: 10, min_time_s: 0.05 },
            Mode::Full => Settings { warmup_iters: 2, min_samples: 5, max_samples: 30, min_time_s: 0.25 },
        }
    }
}

/// One recorded result row.
#[derive(Debug, Clone)]
pub struct Row {
    pub id: String,
    pub params: Vec<(String, String)>,
    pub stats: Summary,
    /// Optional derived metric, e.g. tokens/s, reported alongside latency.
    pub throughput: Option<(String, f64)>,
}

/// A suite accumulates rows and renders them at the end.
pub struct BenchSuite {
    name: String,
    mode: Mode,
    settings: Settings,
    rows: Vec<Row>,
    started: Instant,
}

impl BenchSuite {
    pub fn new(name: &str) -> Self {
        let mode = Mode::from_env();
        let settings = Settings::for_mode(mode);
        println!("== bench suite {name} (mode: {mode:?}) ==");
        BenchSuite { name: name.to_string(), mode, settings, rows: Vec::new(), started: Instant::now() }
    }

    pub fn mode(&self) -> Mode {
        self.mode
    }

    pub fn settings(&self) -> Settings {
        self.settings
    }

    /// Time `f` under the suite's protocol and record a row.
    /// `f` performs ONE unit of work per call and returns the number of
    /// "items" processed (tokens, requests, ...) for throughput reporting.
    pub fn measure<F>(&mut self, id: &str, params: &[(&str, String)], item_unit: Option<&str>, mut f: F)
    where
        F: FnMut() -> u64,
    {
        for _ in 0..self.settings.warmup_iters {
            std::hint::black_box(f());
        }
        let mut stats = Summary::new();
        let mut items_total = 0u64;
        let suite_start = Instant::now();
        while stats.count() < self.settings.min_samples
            || (suite_start.elapsed().as_secs_f64() < self.settings.min_time_s
                && stats.count() < self.settings.max_samples)
        {
            let t0 = Instant::now();
            let items = std::hint::black_box(f());
            let dt = t0.elapsed().as_secs_f64() * 1e6; // µs
            stats.add(dt);
            items_total += items;
        }
        let throughput = item_unit.map(|unit| {
            let per_iter = items_total as f64 / stats.count() as f64;
            (unit.to_string(), per_iter / (stats.mean() / 1e6))
        });
        let row = Row {
            id: id.to_string(),
            params: params.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
            stats,
            throughput,
        };
        let tp = row
            .throughput
            .as_ref()
            .map(|(u, v)| format!("  {:>10.0} {u}", v))
            .unwrap_or_default();
        println!(
            "  {:<44} {:>12} ±{:>9} (n={}){tp}",
            row.id,
            fmt_us(row.stats.mean()),
            fmt_us(row.stats.std()),
            row.stats.count()
        );
        self.rows.push(row);
    }

    /// Record an externally produced measurement (virtual-time simulations).
    pub fn record(&mut self, id: &str, params: &[(&str, String)], value_us: f64, throughput: Option<(&str, f64)>) {
        let mut stats = Summary::new();
        stats.add(value_us);
        let tp = throughput.map(|(u, v)| format!("  {v:>10.2} {u}")).unwrap_or_default();
        println!("  {:<44} {:>12}{tp}", id, fmt_us(value_us));
        self.rows.push(Row {
            id: id.to_string(),
            params: params.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
            stats,
            throughput: throughput.map(|(u, v)| (u.to_string(), v)),
        });
    }

    pub fn rows(&self) -> &[Row] {
        &self.rows
    }

    /// Print the closing summary and write `target/bench-results/<name>.json`.
    pub fn finish(self) {
        let elapsed = self.started.elapsed().as_secs_f64();
        let mut arr = Vec::new();
        for row in &self.rows {
            let mut j = Json::obj();
            j.set("id", row.id.as_str());
            let mut params = Json::obj();
            for (k, v) in &row.params {
                params.set(k, v.as_str());
            }
            j.set("params", params);
            j.set("mean_us", row.stats.mean());
            j.set("std_us", row.stats.std());
            j.set("min_us", row.stats.min());
            j.set("max_us", row.stats.max());
            j.set("samples", row.stats.count());
            if let Some((unit, v)) = &row.throughput {
                j.set("throughput", *v);
                j.set("throughput_unit", unit.as_str());
            }
            arr.push(j);
        }
        let mut doc = Json::obj();
        doc.set("suite", self.name.as_str());
        doc.set("mode", format!("{:?}", self.mode));
        doc.set("rows", Json::Arr(arr));
        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.json", self.name));
        if let Err(e) = std::fs::write(&path, doc.pretty()) {
            eprintln!("warn: could not write {}: {e}", path.display());
        } else {
            println!("-- results written to {}", path.display());
        }
        println!("== suite {} done in {:.1}s ==\n", self.name, elapsed);
    }
}

/// Render rows as a fixed-width table with one line per row, columns taken
/// from `params` keys in order. Used to print paper-table-shaped output.
pub fn print_table(title: &str, columns: &[&str], rows: &[(Vec<String>, String)]) {
    println!("\n### {title}");
    let mut widths: Vec<usize> = columns.iter().map(|c| c.len()).collect();
    for (cells, _) in rows {
        for (i, c) in cells.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let header: Vec<String> =
        columns.iter().enumerate().map(|(i, c)| format!("{:>w$}", c, w = widths[i])).collect();
    println!("| {} |", header.join(" | "));
    println!("|{}|", widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|"));
    for (cells, _) in rows {
        let line: Vec<String> =
            cells.iter().enumerate().map(|(i, c)| format!("{:>w$}", c, w = widths[i])).collect();
        println!("| {} |", line.join(" | "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_pick() {
        assert_eq!(Mode::Quick.pick(1, 2), 1);
        assert_eq!(Mode::Full.pick(1, 2), 2);
    }

    #[test]
    fn measure_records_samples_and_throughput() {
        let mut suite = BenchSuite::new("unit-test-suite");
        suite.measure("noop", &[("k", "v".to_string())], Some("items/s"), || {
            std::hint::black_box(1 + 1);
            10
        });
        assert_eq!(suite.rows().len(), 1);
        let row = &suite.rows()[0];
        assert!(row.stats.count() >= 3);
        let (unit, tput) = row.throughput.as_ref().unwrap();
        assert_eq!(unit, "items/s");
        assert!(*tput > 0.0);
    }

    #[test]
    fn record_external_value() {
        let mut suite = BenchSuite::new("unit-test-suite-2");
        suite.record("sim", &[], 1234.0, Some(("tok/s", 1000.0)));
        assert_eq!(suite.rows()[0].stats.mean(), 1234.0);
    }
}
