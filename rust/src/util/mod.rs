//! In-house substrates: everything a serving framework normally pulls from
//! crates.io, rebuilt on the offline crate set (see DESIGN.md §2).

pub mod bench;
pub mod cli;
pub mod config;
pub mod failpoint;
pub mod json;
pub mod logger;
pub mod pbt;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod threadpool;
pub mod trace;

pub use bench::{BenchSuite, Mode};
pub use cli::{Args, Cli};
pub use config::Config;
pub use json::Json;
pub use rng::Pcg64;
pub use simd::SimdIsa;
pub use stats::Summary;
pub use threadpool::ThreadPool;
