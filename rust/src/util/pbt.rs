//! Mini property-based testing harness (the offline crate set has no
//! `proptest`), used by the kv-cache and coordinator invariant tests.
//!
//! Provides seeded random case generation, failure reporting with the seed
//! needed to replay, and greedy input shrinking for `Vec`-shaped inputs.

use super::rng::Pcg64;

/// Number of random cases per property (override with `CHUNK_ATTN_PBT_CASES`).
pub fn default_cases() -> usize {
    std::env::var("CHUNK_ATTN_PBT_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(64)
}

/// Run `prop` on `cases` random inputs produced by `gen`. On failure, panic
/// with the case index and seed so the failure replays deterministically.
pub fn check<T, G, P>(name: &str, seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Pcg64::new(seed, case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed at case {case} (seed={seed}, stream={case}):\n  {msg}\n  input: {input:#?}"
            );
        }
    }
}

/// Run `prop` on `cases` random inputs × every element of a parameter
/// `grid` (thread counts, batch sizes, ...). Each grid point sees the SAME
/// random inputs — stream `case` depends only on the case index — so a
/// failure report names both the case seed and the grid point, and
/// cross-grid properties (e.g. "bit-identical for every thread count") can
/// be phrased per input by closing over state keyed on the case index.
pub fn check_grid<T, P1, G, P>(
    name: &str,
    seed: u64,
    cases: usize,
    grid: &[P1],
    mut gen: G,
    mut prop: P,
) where
    T: std::fmt::Debug,
    P1: std::fmt::Debug + Copy,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(usize, &T, P1) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Pcg64::new(seed, case as u64);
        let input = gen(&mut rng);
        for &point in grid {
            if let Err(msg) = prop(case, &input, point) {
                panic!(
                    "property {name:?} failed at case {case} (seed={seed}, stream={case}), \
                     grid point {point:?}:\n  {msg}\n  input: {input:#?}"
                );
            }
        }
    }
}

/// Like [`check`], but for `Vec<T>` inputs: on failure, greedily shrink the
/// failing vector (halving windows, then element removal) and report the
/// smallest failing input found.
pub fn check_shrink<T, G, P>(name: &str, seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> Vec<T>,
    P: FnMut(&[T]) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Pcg64::new(seed, case as u64);
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            let (smallest, msg) = shrink(input, first_msg, &mut prop);
            panic!(
                "property {name:?} failed at case {case} (seed={seed}, stream={case});\n  \
                 shrunk to {} elements:\n  {msg}\n  input: {smallest:#?}",
                smallest.len()
            );
        }
    }
}

fn shrink<T, P>(mut failing: Vec<T>, mut msg: String, prop: &mut P) -> (Vec<T>, String)
where
    T: Clone,
    P: FnMut(&[T]) -> Result<(), String>,
{
    // Phase 1: try dropping halves/quarters/... of the input.
    let mut window = failing.len() / 2;
    while window >= 1 {
        let mut start = 0;
        while start + window <= failing.len() {
            let mut candidate = failing.clone();
            candidate.drain(start..start + window);
            match prop(&candidate) {
                Err(m) => {
                    failing = candidate;
                    msg = m;
                    // Restart this window size on the smaller input.
                    start = 0;
                }
                Ok(()) => start += window,
            }
        }
        window /= 2;
    }
    (failing, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("sum-commutes", 1, 32, |rng| (rng.below(100), rng.below(100)), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 7, 8, |rng| rng.below(10), |_| Err("always-fails".into()));
    }

    #[test]
    fn shrinking_finds_minimal_counterexample() {
        // Property: no element equals 13. Gen vectors guaranteed to contain 13.
        let result = std::panic::catch_unwind(|| {
            check_shrink(
                "no-thirteen",
                3,
                1,
                |rng| {
                    let mut v: Vec<u64> = (0..50).map(|_| rng.below(12)).collect();
                    let pos = rng.range(0, v.len() - 1);
                    v[pos] = 13;
                    v
                },
                |xs| {
                    if xs.contains(&13) {
                        Err("contains 13".into())
                    } else {
                        Ok(())
                    }
                },
            )
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("shrunk to 1 elements"), "{msg}");
    }

    #[test]
    fn grid_visits_every_point_with_identical_inputs() {
        use std::collections::BTreeMap;
        let mut seen: BTreeMap<usize, (u64, Vec<usize>)> = BTreeMap::new();
        check_grid(
            "grid-coverage",
            5,
            4,
            &[1usize, 2, 8],
            |rng| rng.below(1000),
            |case, &input, point| {
                let entry = seen.entry(case).or_insert_with(|| (input, Vec::new()));
                if entry.0 != input {
                    return Err(format!("input changed across grid: {} vs {input}", entry.0));
                }
                entry.1.push(point);
                Ok(())
            },
        );
        assert_eq!(seen.len(), 4);
        for (_, (_, points)) in seen {
            assert_eq!(points, vec![1, 2, 8]);
        }
    }

    #[test]
    #[should_panic(expected = "grid point")]
    fn grid_failure_names_the_point() {
        check_grid("grid-fails", 1, 2, &[3usize], |rng| rng.below(10), |_, _, _| {
            Err("nope".into())
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let collect = |seed: u64| {
            let mut seen = Vec::new();
            check("collect", seed, 4, |rng| rng.below(1000), |&x| {
                // Property never fails; abuse closure to record inputs.
                let _ = x;
                Ok(())
            });
            for case in 0..4 {
                let mut rng = Pcg64::new(seed, case);
                seen.push(rng.below(1000));
            }
            seen
        };
        assert_eq!(collect(99), collect(99));
    }
}
