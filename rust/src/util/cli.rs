//! Tiny declarative CLI argument parser (the offline crate set has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments, and
//! subcommands; renders `--help` from the declared options.

use std::collections::BTreeMap;

/// One declared option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative CLI definition for one (sub)command.
#[derive(Debug, Clone)]
pub struct Cli {
    program: String,
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>, // (name, help)
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli { program: program.to_string(), about: about.to_string(), opts: Vec::new(), positionals: Vec::new() }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a required `--name <value>` (no default).
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec { name: name.to_string(), help: help.to_string(), default: None, is_flag: false });
        self
    }

    /// Declare a boolean `--name` flag (defaults to false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec { name: name.to_string(), help: help.to_string(), default: None, is_flag: true });
        self
    }

    /// Declare a positional argument (for help rendering only).
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        for (name, _) in &self.positionals {
            s.push_str(&format!(" <{name}>"));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (name, help) in &self.positionals {
                s.push_str(&format!("  <{name}>  {help}\n"));
            }
        }
        s.push_str("\nOPTIONS:\n");
        for o in &self.opts {
            let lhs = if o.is_flag { format!("--{}", o.name) } else { format!("--{} <v>", o.name) };
            let dflt = match &o.default {
                Some(d) if !o.is_flag => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("  {lhs:<24} {}{dflt}\n", o.help));
        }
        s.push_str("  --help                   print this help\n");
        s
    }

    /// Parse a raw argv slice (without the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        // Seed defaults.
        for o in &self.opts {
            if o.is_flag {
                args.flags.insert(o.name.clone(), false);
            } else if let Some(d) = &o.default {
                args.values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.help_text());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.help_text()))?;
                if spec.is_flag {
                    match inline_val.as_deref() {
                        None | Some("true") => {
                            args.flags.insert(key, true);
                        }
                        Some("false") => {
                            args.flags.insert(key, false);
                        }
                        Some(v) => return Err(format!("flag --{key} takes no value, got {v}")),
                    }
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i).cloned().ok_or_else(|| format!("--{key} needs a value"))?
                        }
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positionals.push(a.clone());
            }
            i += 1;
        }
        // Check required options.
        for o in &self.opts {
            if !o.is_flag && o.default.is_none() && !args.values.contains_key(&o.name) {
                return Err(format!("missing required --{}\n\n{}", o.name, self.help_text()));
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args()`; on `--help`/error, print and exit.
    pub fn parse_or_exit(&self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&argv) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(if msg.starts_with(&self.program) { 0 } else { 2 });
            }
        }
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {:?}", self.get(name)))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {:?}", self.get(name)))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {:?}", self.get(name)))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Comma-separated list of integers, e.g. `--sizes 1,2,4`.
    pub fn get_usize_list(&self, name: &str) -> Vec<usize> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad integer {s:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("batch", "32", "batch size")
            .opt("mode", "quick", "mode")
            .flag("verbose", "verbose output")
            .req("seed", "rng seed")
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli().parse(&argv(&["--seed", "1"])).unwrap();
        assert_eq!(a.get_usize("batch"), 32);
        assert_eq!(a.get("mode"), "quick");
        assert!(!a.get_flag("verbose"));
        let a = cli().parse(&argv(&["--seed=2", "--batch=64", "--verbose"])).unwrap();
        assert_eq!(a.get_usize("batch"), 64);
        assert_eq!(a.get_u64("seed"), 2);
        assert!(a.get_flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse(&argv(&["--batch", "8"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(&argv(&["--seed", "1", "--nope", "2"])).is_err());
    }

    #[test]
    fn positionals_collected() {
        let a = cli().parse(&argv(&["pos1", "--seed", "3", "pos2"])).unwrap();
        assert_eq!(a.positionals(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn help_lists_options() {
        let err = cli().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("--batch"));
        assert!(err.contains("--seed"));
    }

    #[test]
    fn int_list() {
        let c = Cli::new("t", "t").opt("sizes", "1,2,4", "");
        let a = c.parse(&argv(&["--sizes", "8, 16,32"])).unwrap();
        assert_eq!(a.get_usize_list("sizes"), vec![8, 16, 32]);
    }
}
