//! TOML-subset configuration parser for serving configs.
//!
//! Supports the subset real deployments of this system need:
//! `[section]` / `[section.sub]` headers, `key = value` with string, integer,
//! float, boolean and homogeneous-array values, `#` comments. No multiline
//! strings, no inline tables, no datetimes.

use std::collections::BTreeMap;

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat view of a TOML-subset document: `section.key -> Value`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    entries: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let full_key = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            let value = parse_value(value.trim()).map_err(|e| format!("line {}: {e}", lineno + 1))?;
            entries.insert(full_key, value);
        }
        Ok(Config { entries })
    }

    pub fn load(path: &std::path::Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn int(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.int(key, default as i64) as usize
    }

    pub fn float(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    /// Apply `key=value` override strings (CLI `--set engine.chunk_size=32`).
    pub fn apply_overrides(&mut self, overrides: &[String]) -> Result<(), String> {
        for ov in overrides {
            let (k, v) = ov.split_once('=').ok_or_else(|| format!("bad override {ov:?}, want key=value"))?;
            let val = parse_value(v.trim())?;
            self.entries.insert(k.trim().to_string(), val);
        }
        Ok(())
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(body) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(body.replace("\\\"", "\"").replace("\\n", "\n").replace("\\\\", "\\")));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?} (bare strings must be quoted)"))
}

/// Split on commas that are not nested inside brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, ch) in s.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# serving config
name = "chunk-attn"        # inline comment
max_batch = 32

[engine]
chunk_size = 64
backend = "chunk_tpp"
gpu_fraction = 0.9
lazy_context = true
sizes = [1, 2, 4]

[engine.limits]
max_tokens = 8_192
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(DOC).unwrap();
        assert_eq!(c.str("name", ""), "chunk-attn");
        assert_eq!(c.int("max_batch", 0), 32);
        assert_eq!(c.usize("engine.chunk_size", 0), 64);
        assert_eq!(c.str("engine.backend", ""), "chunk_tpp");
        assert!((c.float("engine.gpu_fraction", 0.0) - 0.9).abs() < 1e-12);
        assert!(c.bool("engine.lazy_context", false));
        assert_eq!(c.int("engine.limits.max_tokens", 0), 8192);
    }

    #[test]
    fn arrays() {
        let c = Config::parse("xs = [1, 2, 3]\nys = [\"a\", \"b,c\"]").unwrap();
        match c.get("xs").unwrap() {
            Value::Arr(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
        match c.get("ys").unwrap() {
            Value::Arr(v) => assert_eq!(v[1], Value::Str("b,c".into())),
            _ => panic!(),
        }
    }

    #[test]
    fn defaults_on_missing() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.int("nope", 7), 7);
        assert_eq!(c.str("nope", "x"), "x");
    }

    #[test]
    fn errors_are_reported_with_line() {
        let err = Config::parse("a = ").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        assert!(Config::parse("[open\n").is_err());
        assert!(Config::parse("bare = word").is_err());
    }

    #[test]
    fn overrides() {
        let mut c = Config::parse("[e]\nx = 1").unwrap();
        c.apply_overrides(&["e.x=5".into(), "e.y=\"z\"".into()]).unwrap();
        assert_eq!(c.int("e.x", 0), 5);
        assert_eq!(c.str("e.y", ""), "z");
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let c = Config::parse("s = \"a#b\"").unwrap();
        assert_eq!(c.str("s", ""), "a#b");
    }
}
