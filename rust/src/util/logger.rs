//! Minimal `log`-crate backend writing to stderr with a monotonic timestamp.
//!
//! Verbosity is controlled by `LOG_LEVEL`, an env_logger-style filter list:
//! `LOG_LEVEL=debug` sets the default level, and
//! `LOG_LEVEL=gateway=debug,engine=info` raises or lowers individual
//! modules — a spec name matches any `::`-separated segment of the log
//! target, so `gateway` covers `chunk_attention::server::gateway`. The
//! legacy `CHUNK_ATTN_LOG` (`error|warn|info|debug|trace`) still sets the
//! default level when `LOG_LEVEL` is unset. Install once with [`init`];
//! repeated calls are no-ops.

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::OnceLock;
use std::time::Instant;

fn parse_level(s: &str) -> Option<LevelFilter> {
    match s.trim().to_ascii_lowercase().as_str() {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

/// Parsed filter config: a default level plus per-module overrides.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Filters {
    default: LevelFilter,
    /// `(module segment, level)`; later entries win on overlap.
    modules: Vec<(String, LevelFilter)>,
}

impl Filters {
    /// Parse a `LOG_LEVEL` spec: comma-separated entries, each either a
    /// bare level (sets the default) or `module=level`. Unparseable
    /// entries are ignored rather than fatal — a misconfigured filter
    /// must never take logging down with it.
    fn parse(spec: &str, fallback_default: LevelFilter) -> Filters {
        let mut default = fallback_default;
        let mut modules = Vec::new();
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            match entry.split_once('=') {
                Some((module, level)) => {
                    if let Some(l) = parse_level(level) {
                        modules.push((module.trim().to_string(), l));
                    }
                }
                None => {
                    if let Some(l) = parse_level(entry) {
                        default = l;
                    }
                }
            }
        }
        Filters { default, modules }
    }

    /// Effective level for a log target (a Rust module path). A module
    /// spec matches any `::` path segment, so `gateway` covers
    /// `chunk_attention::server::gateway`; the last matching entry wins.
    fn level_for(&self, target: &str) -> LevelFilter {
        let mut level = self.default;
        for (module, l) in &self.modules {
            if target.split("::").any(|seg| seg == module) || target == module {
                level = *l;
            }
        }
        level
    }

    /// Upper bound across default and overrides — what `log::max_level`
    /// must be set to so no override is filtered out upstream.
    fn max(&self) -> LevelFilter {
        self.modules.iter().map(|(_, l)| *l).fold(self.default, |a, b| a.max(b))
    }
}

struct StderrLogger {
    start: Instant,
    filters: Filters,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.filters.level_for(metadata.target())
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:10.4}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger (idempotent).
pub fn init() {
    let logger = LOGGER.get_or_init(|| {
        // Legacy default-level knob, overridden by any LOG_LEVEL default.
        let fallback = std::env::var("CHUNK_ATTN_LOG")
            .ok()
            .and_then(|v| parse_level(&v))
            .unwrap_or(LevelFilter::Info);
        let filters = match std::env::var("LOG_LEVEL") {
            Ok(spec) => Filters::parse(&spec, fallback),
            Err(_) => Filters { default: fallback, modules: Vec::new() },
        };
        StderrLogger { start: Instant::now(), filters }
    });
    if log::set_logger(logger).is_ok() {
        log::set_max_level(logger.filters.max());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }

    #[test]
    fn parse_bare_level_sets_default() {
        let f = Filters::parse("debug", LevelFilter::Info);
        assert_eq!(f.default, LevelFilter::Debug);
        assert!(f.modules.is_empty());
        assert_eq!(f.max(), LevelFilter::Debug);
    }

    #[test]
    fn parse_per_module_overrides() {
        let f = Filters::parse("gateway=debug,engine=warn", LevelFilter::Info);
        assert_eq!(f.default, LevelFilter::Info);
        assert_eq!(f.level_for("chunk_attention::server::gateway"), LevelFilter::Debug);
        assert_eq!(f.level_for("chunk_attention::coordinator::engine"), LevelFilter::Warn);
        assert_eq!(f.level_for("chunk_attention::kvcache::tree"), LevelFilter::Info);
        assert_eq!(f.max(), LevelFilter::Debug);
    }

    #[test]
    fn parse_mixed_default_and_modules() {
        let f = Filters::parse("warn,gateway=trace", LevelFilter::Info);
        assert_eq!(f.default, LevelFilter::Warn);
        assert_eq!(f.level_for("chunk_attention::server::gateway"), LevelFilter::Trace);
        assert_eq!(f.level_for("other"), LevelFilter::Warn);
        assert_eq!(f.max(), LevelFilter::Trace);
    }

    #[test]
    fn garbage_entries_are_ignored() {
        let f = Filters::parse("nonsense,gateway=loud,,=,engine=debug", LevelFilter::Info);
        assert_eq!(f.default, LevelFilter::Info);
        assert_eq!(f.modules, vec![("engine".to_string(), LevelFilter::Debug)]);
    }

    #[test]
    fn exact_target_match_works_without_path() {
        let f = Filters::parse("bench=debug", LevelFilter::Error);
        assert_eq!(f.level_for("bench"), LevelFilter::Debug);
    }
}
