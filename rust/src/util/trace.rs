//! Lightweight span tracing with Chrome `trace_event` export.
//!
//! Mirrors the arming discipline of [`crate::util::failpoint`]: trace sites
//! are compiled into the serving hot paths unconditionally and evaluate to
//! *nothing* until armed — the disarmed fast path is a single relaxed atomic
//! load, so shipping the sites costs no measurable overhead (asserted by the
//! `table3_microkernel` bench staying within run-to-run noise).
//!
//! Two kinds of data flow through this module:
//!
//! - **Trace events** ([`span`] / [`instant`]): buffered only while armed
//!   ([`arm`]), drained with [`drain`], and serialized to the Chrome
//!   `trace_event` JSON array format by [`write_chrome_trace`] so a run
//!   opens directly in `chrome://tracing` / Perfetto. Events carry a `tid`
//!   used as a logical track: track 0 is the engine stepper; per-request
//!   lifecycle events use the request id as their track so each request
//!   renders as its own timeline row.
//! - **Kernel phase timings** ([`record_kernel_phases`] /
//!   [`take_kernel_phases`]): a thread-local side channel the TPP kernel
//!   writes (chunk-first and seq-first phase durations) and the engine
//!   drains after each `runner.decode` call. This path is *always on* —
//!   the per-phase histograms on `/metrics` must populate without tracing
//!   armed — and costs two `Instant::now` reads plus one `Cell` store per
//!   kernel invocation.
//!
//! Timestamps are microseconds on a process-wide monotonic epoch
//! ([`now_us`]), established lazily on first use so spans from different
//! threads share one clock.

use std::cell::Cell;
use std::io::{self, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// Upper bound on buffered events; beyond it new events are dropped (and
/// counted in [`dropped`]) so a long armed run cannot exhaust memory.
const MAX_EVENTS: usize = 1 << 20;

/// One Chrome `trace_event` record.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: String,
    /// Event category (`"step"`, `"kernel"`, `"request"`, `"fault"`).
    pub cat: &'static str,
    /// `'X'` = complete span (uses `dur_us`), `'i'` = instant event.
    pub ph: char,
    /// Start time, microseconds since the trace epoch.
    pub ts_us: u64,
    /// Duration for `'X'` events; ignored for instants.
    pub dur_us: u64,
    /// Logical track (Chrome thread id): 0 = engine stepper, request
    /// events use the request id.
    pub tid: u64,
    /// Extra key/value payload rendered into the event's `args` object.
    pub args: Vec<(&'static str, String)>,
}

static ARMED: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process trace epoch (monotonic).
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

fn buffer() -> MutexGuard<'static, Vec<TraceEvent>> {
    static BUF: OnceLock<Mutex<Vec<TraceEvent>>> = OnceLock::new();
    BUF.get_or_init(|| Mutex::new(Vec::new()))
        .lock()
        // A panic unwinding through an armed caller can poison this lock;
        // the buffer is always left consistent, so recover the value.
        .unwrap_or_else(|e| e.into_inner())
}

/// Cheap check used by call sites to skip span assembly while disarmed.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Start collecting trace events (pins the epoch if not already set).
pub fn arm() {
    epoch();
    ARMED.store(true, Ordering::SeqCst);
}

/// Stop collecting. Buffered events stay available to [`drain`].
pub fn disarm() {
    ARMED.store(false, Ordering::SeqCst);
}

/// Take every buffered event, leaving the buffer empty.
pub fn drain() -> Vec<TraceEvent> {
    std::mem::take(&mut *buffer())
}

/// Events discarded because the buffer hit [`MAX_EVENTS`].
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

fn push(ev: TraceEvent) {
    let mut buf = buffer();
    if buf.len() >= MAX_EVENTS {
        DROPPED.fetch_add(1, Ordering::Relaxed);
    } else {
        buf.push(ev);
    }
}

/// Record a complete span (`ph: "X"`). No-op while disarmed.
pub fn span(
    name: &str,
    cat: &'static str,
    tid: u64,
    ts_us: u64,
    dur_us: u64,
    args: Vec<(&'static str, String)>,
) {
    if !armed() {
        return;
    }
    push(TraceEvent { name: name.to_string(), cat, ph: 'X', ts_us, dur_us, tid, args });
}

/// Record an instant event (`ph: "i"`) stamped now. No-op while disarmed.
pub fn instant(name: &str, cat: &'static str, tid: u64, args: Vec<(&'static str, String)>) {
    if !armed() {
        return;
    }
    push(TraceEvent { name: name.to_string(), cat, ph: 'i', ts_us: now_us(), dur_us: 0, tid, args });
}

thread_local! {
    // (chunk_first_us, seq_first_us) accumulated since the last take.
    static KERNEL_PHASES: Cell<(u64, u64)> = const { Cell::new((0, 0)) };
}

/// Called by the TPP kernel after every invocation with the measured
/// durations of its two phases. Accumulates (a step may run the kernel
/// more than once); always on — the `/metrics` phase histograms depend
/// on it whether or not tracing is armed.
pub fn record_kernel_phases(chunk_first_us: u64, seq_first_us: u64) {
    KERNEL_PHASES.with(|c| {
        let (a, b) = c.get();
        c.set((a.wrapping_add(chunk_first_us), b.wrapping_add(seq_first_us)));
    });
}

/// Drain the kernel-phase accumulator for the calling thread. The engine
/// calls this right after `runner.decode`; `(0, 0)` means the runner never
/// entered the TPP kernel on this thread.
pub fn take_kernel_phases() -> (u64, u64) {
    KERNEL_PHASES.with(|c| c.replace((0, 0)))
}

fn escape_json(s: &str, out: &mut String) {
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

/// Serialize events as a Chrome `trace_event` JSON array (the format
/// `chrome://tracing` and Perfetto open directly).
pub fn write_chrome_trace(w: &mut dyn Write, events: &[TraceEvent]) -> io::Result<()> {
    w.write_all(b"[\n")?;
    for (i, ev) in events.iter().enumerate() {
        let mut line = String::with_capacity(128);
        line.push_str("{\"name\":\"");
        escape_json(&ev.name, &mut line);
        line.push_str("\",\"cat\":\"");
        escape_json(ev.cat, &mut line);
        line.push_str(&format!(
            "\",\"ph\":\"{}\",\"ts\":{},\"pid\":1,\"tid\":{}",
            ev.ph, ev.ts_us, ev.tid
        ));
        if ev.ph == 'X' {
            line.push_str(&format!(",\"dur\":{}", ev.dur_us));
        }
        if ev.ph == 'i' {
            // Scope the instant to its thread track.
            line.push_str(",\"s\":\"t\"");
        }
        if !ev.args.is_empty() {
            line.push_str(",\"args\":{");
            for (j, (k, v)) in ev.args.iter().enumerate() {
                if j > 0 {
                    line.push(',');
                }
                line.push('"');
                escape_json(k, &mut line);
                line.push_str("\":\"");
                escape_json(v, &mut line);
                line.push('"');
            }
            line.push('}');
        }
        line.push('}');
        if i + 1 < events.len() {
            line.push(',');
        }
        line.push('\n');
        w.write_all(line.as_bytes())?;
    }
    w.write_all(b"]\n")
}

/// Write a drained event list to `path` as Chrome trace JSON.
pub fn write_chrome_trace_file(path: &std::path::Path, events: &[TraceEvent]) -> io::Result<()> {
    let mut f = io::BufWriter::new(std::fs::File::create(path)?);
    write_chrome_trace(&mut f, events)?;
    f.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tracing is process-global; serialize tests in this module and always
    // disarm + drain on exit so concurrent lib tests see a quiet recorder.
    fn guard() -> MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
    }

    struct Reset;
    impl Drop for Reset {
        fn drop(&mut self) {
            disarm();
            drain();
        }
    }

    #[test]
    fn disarmed_records_nothing() {
        let _g = guard();
        let _r = Reset;
        disarm();
        drain();
        span("step", "step", 0, 0, 10, vec![]);
        instant("queued", "request", 7, vec![]);
        assert!(drain().is_empty());
        assert!(!armed());
    }

    #[test]
    fn armed_buffers_and_drains() {
        let _g = guard();
        let _r = Reset;
        arm();
        span("step", "step", 0, 100, 50, vec![("batch", "4".into())]);
        instant("first_token", "request", 9, vec![]);
        let evs = drain();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].name, "step");
        assert_eq!(evs[0].ph, 'X');
        assert_eq!(evs[0].dur_us, 50);
        assert_eq!(evs[1].tid, 9);
        assert!(drain().is_empty());
    }

    #[test]
    fn kernel_phase_channel_accumulates_and_clears() {
        // Thread-local: no cross-test interference, no guard needed.
        take_kernel_phases();
        assert_eq!(take_kernel_phases(), (0, 0));
        record_kernel_phases(5, 7);
        record_kernel_phases(3, 2);
        assert_eq!(take_kernel_phases(), (8, 9));
        assert_eq!(take_kernel_phases(), (0, 0));
    }

    #[test]
    fn chrome_trace_is_valid_shape() {
        let events = vec![
            TraceEvent {
                name: "step \"quoted\"".into(),
                cat: "step",
                ph: 'X',
                ts_us: 10,
                dur_us: 20,
                tid: 0,
                args: vec![("batch", "3".into())],
            },
            TraceEvent {
                name: "queued".into(),
                cat: "request",
                ph: 'i',
                ts_us: 15,
                dur_us: 0,
                tid: 4,
                args: vec![],
            },
        ];
        let mut out = Vec::new();
        write_chrome_trace(&mut out, &events).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("[\n"));
        assert!(text.trim_end().ends_with(']'));
        assert!(text.contains("\\\"quoted\\\""));
        assert!(text.contains("\"ph\":\"X\""));
        assert!(text.contains("\"dur\":20"));
        assert!(text.contains("\"ph\":\"i\""));
        assert!(text.contains("\"args\":{\"batch\":\"3\"}"));
        // Parses as JSON via the crate's own parser.
        let parsed = crate::util::json::Json::parse(&text).expect("valid json");
        assert_eq!(parsed.as_arr().map(|a| a.len()), Some(2));
    }
}
