//! Explicit-SIMD micro-kernel seam with runtime ISA dispatch.
//!
//! The attention hot loops (`attention/online.rs`) historically leaned on
//! LLVM autovectorization. This module makes the vector width explicit:
//! the q·k dot products, the `fast_exp`-based softmax pass, the V
//! accumulation, and the f16/bf16→f32 widening loads each get
//! `#[target_feature]` bodies per ISA, selected once at runtime.
//!
//! ## Dispatch
//!
//! [`active`] probes the host once (`is_x86_feature_detected!`-style) and
//! caches the result: AVX-512F ≻ AVX2(+FMA+F16C) on x86-64, NEON on
//! aarch64, scalar everywhere else. `PALLAS_SIMD=scalar|avx2|avx512|neon|
//! auto` forces a path (an unavailable request falls back to the best
//! available one, with a warning); [`force`] is the in-process override
//! test grids use to run every path in one binary.
//!
//! ## Bit-identity policy
//!
//! Every accelerated path is **bit-identical** to the scalar kernel, not
//! merely within tolerance. This is cheap to guarantee because the scalar
//! bodies already fix their reduction geometry (8 accumulator lanes in
//! `dot_d`, 4 in `dot_kv`, sequential normalizer sums), so the vector
//! code reproduces exactly that geometry:
//!
//! - dots use the same lane count as the scalar body they replace (even
//!   on AVX-512, which keeps 8-lane ymm dots and spends its width on the
//!   element-wise widen/V passes, where any width is exact);
//! - no FMA contractions — multiply and add round separately, exactly as
//!   the scalar `a * b` then `+=` do (the `fma` feature is required for
//!   dispatch parity with real serving hosts but never used to contract);
//! - horizontal lane sums run sequentially in scalar lane order;
//! - `f32::round` (ties away from zero) is emulated exactly on x86 where
//!   SSE4 rounding only offers ties-to-even (see `exp` bodies); NEON's
//!   FRINTA is natively ties-away;
//! - f16/bf16→f32 widening is exact in any order, so the conversions may
//!   use full vector width freely.
//!
//! The scalar kernel therefore stays the oracle: `PALLAS_SIMD=scalar`
//! must reproduce today's outputs bit-for-bit, and every other path must
//! reproduce *it* bit-for-bit (asserted by the cross-ISA property tests).
//!
//! ## Why widening lives here
//!
//! Half-precision KV pays a per-element scalar decode tax in the generic
//! kernels (`to_f32` inside every dot/axpy). The SIMD entry path instead
//! widens a whole K/V block once into a thread-local f32 scratch
//! (hardware `vcvtph2ps` for f16, a vector shift for bf16) and runs the
//! f32 body — the conversion is exact, so the seam relocation cannot
//! change results (asserted per dtype by `simd_paths_match_scalar_bitwise`
//! in `attention/online.rs`).

use std::sync::atomic::{AtomicU8, Ordering};

/// An instruction-set path the kernel can run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum SimdIsa {
    /// Portable scalar bodies — always available, the bit-identity oracle.
    Scalar = 0,
    /// AVX2 + FMA + F16C (x86-64 serving hosts since Haswell).
    Avx2 = 1,
    /// AVX-512F (dots stay 8-lane for bit-identity; widen/V passes go 16-wide).
    Avx512 = 2,
    /// aarch64 NEON (baseline on every aarch64 target).
    Neon = 3,
}

impl SimdIsa {
    /// Canonical lowercase label (metrics labels, logs, bench rows).
    pub fn label(self) -> &'static str {
        match self {
            SimdIsa::Scalar => "scalar",
            SimdIsa::Avx2 => "avx2",
            SimdIsa::Avx512 => "avx512",
            SimdIsa::Neon => "neon",
        }
    }

    /// Parse a `PALLAS_SIMD` value (not including `auto`).
    pub fn parse(s: &str) -> Option<SimdIsa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" | "off" | "none" => Some(SimdIsa::Scalar),
            "avx2" => Some(SimdIsa::Avx2),
            "avx512" | "avx-512" | "avx512f" => Some(SimdIsa::Avx512),
            "neon" => Some(SimdIsa::Neon),
            _ => None,
        }
    }

    /// Whether this path uses explicit vector bodies (false = generic
    /// scalar kernel, which also stays the fallback for exotic targets).
    #[inline]
    pub fn is_accelerated(self) -> bool {
        !matches!(self, SimdIsa::Scalar)
    }

    fn from_u8(v: u8) -> SimdIsa {
        match v {
            1 => SimdIsa::Avx2,
            2 => SimdIsa::Avx512,
            3 => SimdIsa::Neon,
            _ => SimdIsa::Scalar,
        }
    }
}

/// Is `isa` runnable on this host?
pub fn is_available(isa: SimdIsa) -> bool {
    match isa {
        SimdIsa::Scalar => true,
        _ => probe_available(isa),
    }
}

/// Every ISA path runnable on this host, scalar first — the grid the
/// cross-ISA bit-identity property tests iterate.
pub fn available() -> Vec<SimdIsa> {
    [SimdIsa::Scalar, SimdIsa::Avx2, SimdIsa::Avx512, SimdIsa::Neon]
        .into_iter()
        .filter(|&i| is_available(i))
        .collect()
}

#[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
fn probe_available(isa: SimdIsa) -> bool {
    match isa {
        SimdIsa::Scalar => true,
        // FMA/F16C ship with AVX2 on every real core; requiring them keeps
        // the f16 widen on hardware conversions. (FMA is detected for host
        // parity but never used to contract — see the bit-identity policy.)
        SimdIsa::Avx2 => {
            std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
                && std::arch::is_x86_feature_detected!("f16c")
        }
        SimdIsa::Avx512 => {
            std::arch::is_x86_feature_detected!("avx512f")
                && std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
                && std::arch::is_x86_feature_detected!("f16c")
        }
        SimdIsa::Neon => false,
    }
}

#[cfg(target_arch = "aarch64")]
fn probe_available(isa: SimdIsa) -> bool {
    // NEON is baseline on aarch64; the x86 paths never are.
    matches!(isa, SimdIsa::Scalar | SimdIsa::Neon)
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "x86", target_arch = "aarch64")))]
fn probe_available(isa: SimdIsa) -> bool {
    matches!(isa, SimdIsa::Scalar)
}

/// Best path the host supports.
fn detect_best() -> SimdIsa {
    for isa in [SimdIsa::Avx512, SimdIsa::Avx2, SimdIsa::Neon] {
        if is_available(isa) {
            return isa;
        }
    }
    SimdIsa::Scalar
}

const ISA_UNSET: u8 = 0xff;
static ACTIVE: AtomicU8 = AtomicU8::new(ISA_UNSET);

/// The ISA path the kernels are currently dispatching to. Detected once
/// (honouring `PALLAS_SIMD`) and cached; [`force`] overrides it.
pub fn active() -> SimdIsa {
    let v = ACTIVE.load(Ordering::Relaxed);
    if v != ISA_UNSET {
        return SimdIsa::from_u8(v);
    }
    let isa = choose_from_env();
    // A racing first call resolves identically (env + cpuid are stable).
    ACTIVE.store(isa as u8, Ordering::Relaxed);
    isa
}

/// The raw `PALLAS_SIMD` request, for startup logs (`auto` when unset).
pub fn env_request() -> String {
    match std::env::var("PALLAS_SIMD") {
        Ok(s) if !s.is_empty() => s,
        _ => "auto".to_string(),
    }
}

fn choose_from_env() -> SimdIsa {
    match std::env::var("PALLAS_SIMD").ok().as_deref() {
        None | Some("") | Some("auto") => detect_best(),
        Some(s) => match SimdIsa::parse(s) {
            Some(req) if is_available(req) => req,
            Some(req) => {
                let best = detect_best();
                log::warn!(
                    "PALLAS_SIMD={} is not available on this host; using {}",
                    req.label(),
                    best.label()
                );
                best
            }
            None => {
                let best = detect_best();
                log::warn!(
                    "PALLAS_SIMD={s:?} not recognised (want auto|scalar|avx2|avx512|neon); \
                     using {}",
                    best.label()
                );
                best
            }
        },
    }
}

/// Test/bench hook: pin the dispatch to `isa` (`None` re-runs detection on
/// the next [`active`] call). Panics if `isa` is not runnable on this host
/// — forcing an absent ISA would execute illegal instructions.
///
/// The override is process-global. That is safe to flip even while other
/// threads run kernels precisely because every path is bit-identical; the
/// cross-ISA property tests rely on this to cover all paths in one binary.
pub fn force(isa: Option<SimdIsa>) {
    if let Some(isa) = isa {
        assert!(is_available(isa), "cannot force {}: not available on this host", isa.label());
        ACTIVE.store(isa as u8, Ordering::Relaxed);
    } else {
        ACTIVE.store(ISA_UNSET, Ordering::Relaxed);
    }
}

/// Serialises unit tests that assert on exact [`active`] values while
/// flipping [`force`] (tests run in parallel threads within one binary).
/// Bit-identity makes concurrent flips harmless to *outputs*, but not to
/// assertions about which path is currently selected.
#[cfg(test)]
pub(crate) fn force_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// Scalar reference bodies.
//
// These replicate, operation for operation, the geometries of the generic
// kernels in `attention/online.rs` (`dot_d`, `dot_kv`, `fast_exp`,
// `fast_exp_block`, `axpy_kv`). They are the fallback arm of every
// dispatcher and the oracle the unit tests compare the vector bodies
// against. Any drift from `online.rs` breaks the cross-ISA bit-identity
// suite, which compares full kernels, not just these helpers.
// ---------------------------------------------------------------------------

const EXP_LOG2E: f32 = std::f32::consts::LOG2_E;
const EXP_LN2_HI: f32 = 0.693_359_4;
const EXP_LN2_LO: f32 = -2.121_944_4e-4;
const EXP_C3: f32 = 0.166_666_55;
const EXP_C4: f32 = 0.041_665_795;
const EXP_C5: f32 = 0.008_333_452;
const EXP_C6: f32 = 0.001_388_89;

/// Core of `fast_exp`/`fast_exp_block` for an argument already clamped to
/// `[-87, 88]`: `2^k · poly(r)` with `k = round(a·log2 e)`.
#[inline]
fn exp_core(a: f32) -> f32 {
    let k = (a * EXP_LOG2E).round();
    let r = a - k * EXP_LN2_HI - k * EXP_LN2_LO;
    let p = 1.0 + r * (1.0 + r * (0.5 + r * (EXP_C3 + r * (EXP_C4 + r * (EXP_C5 + r * EXP_C6)))));
    let bits = ((k as i32 + 127) as u32) << 23;
    p * f32::from_bits(bits)
}

/// One element of the `fast_exp_block` pass (clamp-at−87 semantics).
#[inline]
fn exp_clamped(x: f32, shift: f32) -> f32 {
    exp_core((x - shift).max(-87.0))
}

/// One element of the per-row tail pass (`fast_exp` semantics: exactly
/// 0.0 below −87 — note this *differs in the last bits* from the clamped
/// variant, which returns e⁻⁸⁷ ≈ 1.6e-38; each call site replicates the
/// scalar kernel it replaces).
#[inline]
fn exp_cutoff(x: f32, shift: f32) -> f32 {
    let a = x - shift;
    if a < -87.0 {
        return 0.0;
    }
    exp_core(a)
}

/// `dot_d` geometry: 8 accumulator lanes, stride 8, sequential lane fold,
/// scalar tail.
fn dot8_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let mut lanes = [0.0f32; 8];
    let mut i = 0;
    while i + 8 <= n {
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane += a[i + l] * b[i + l];
        }
        i += 8;
    }
    let mut s = 0.0;
    for l in lanes {
        s += l;
    }
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// `dot_kv` geometry: 4 accumulator lanes, `((s0+s1)+s2)+s3` fold, tail.
fn dot4_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

fn exp_block_scalar(w: &mut [f32], shift: f32, cutoff: bool) -> f32 {
    let mut acc = 0.0f32;
    for x in w.iter_mut() {
        let e = if cutoff { exp_cutoff(*x, shift) } else { exp_clamped(*x, shift) };
        *x = e;
        acc += e;
    }
    acc
}

fn axpy_scalar(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

fn widen_f16_scalar(src: &[u16], dst: &mut [f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = crate::kvcache::dtype::f16_bits_to_f32(s);
    }
}

fn widen_bf16_scalar(src: &[u16], dst: &mut [f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = crate::kvcache::dtype::bf16_bits_to_f32(s);
    }
}

fn widen_i8_scalar(src: &[i8], scale: f32, dst: &mut [f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s as f32 * scale;
    }
}

fn qk_dots8_scalar(q: &[f32], d: usize, k_t: &[f32], out: &mut [f32; 8]) {
    for (r, o) in out.iter_mut().enumerate() {
        let q_r = &q[r * d..(r + 1) * d];
        *o = if d == 64 || d == 128 { dot8_scalar(q_r, k_t) } else { dot4_scalar(q_r, k_t) };
    }
}

// ---------------------------------------------------------------------------
// Public dispatchers. Each takes the ISA explicitly so the kernel reads
// `active()` once per block instead of once per primitive call.
// ---------------------------------------------------------------------------

/// Widen f16 bit patterns to f32 (exact; hardware `vcvtph2ps` where
/// available). `src` and `dst` must have equal lengths.
pub fn widen_f16(isa: SimdIsa, src: &[u16], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    match isa {
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx2 => unsafe { x86::widen_f16_avx2(src, dst) },
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx512 => unsafe { x86::widen_f16_avx512(src, dst) },
        // No stable aarch64 f16 conversion intrinsics; the bf16 shift and
        // the f32 bodies still make NEON worthwhile.
        _ => widen_f16_scalar(src, dst),
    }
}

/// Widen bf16 bit patterns to f32 (exact: a 16-bit left shift).
pub fn widen_bf16(isa: SimdIsa, src: &[u16], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    match isa {
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx2 => unsafe { x86::widen_bf16_avx2(src, dst) },
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx512 => unsafe { x86::widen_bf16_avx512(src, dst) },
        #[cfg(target_arch = "aarch64")]
        SimdIsa::Neon => unsafe { neon::widen_bf16_neon(src, dst) },
        _ => widen_bf16_scalar(src, dst),
    }
}

/// Int8 dequant widening load: `dst[i] = (src[i] as f32) * scale`. The
/// int→f32 convert is exact (|q| ≤ 127 ≪ 2²⁴) and the single multiply
/// rounds identically at every vector width, so every ISA arm is
/// bit-identical to the scalar body by construction — same exactness
/// policy as the f16/bf16 widen arms, enforced by the exhaustive
/// 256-pattern test below.
pub fn widen_i8(isa: SimdIsa, src: &[i8], scale: f32, dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    match isa {
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx2 => unsafe { x86::widen_i8_avx2(src, scale, dst) },
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx512 => unsafe { x86::widen_i8_avx512(src, scale, dst) },
        #[cfg(target_arch = "aarch64")]
        SimdIsa::Neon => unsafe { neon::widen_i8_neon(src, scale, dst) },
        _ => widen_i8_scalar(src, scale, dst),
    }
}

/// Eight q·k dots sharing one K row: `out[r] = q[r*d..][..d] · k_t`.
/// Replicates the scalar reduction geometry for the given `d` (8-lane for
/// the monomorphized head dims 64/128, `dot_kv`'s 4-lane otherwise).
pub fn qk_dots8(isa: SimdIsa, q: &[f32], d: usize, k_t: &[f32], out: &mut [f32; 8]) {
    debug_assert!(q.len() >= 8 * d && k_t.len() >= d);
    match isa {
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx2 | SimdIsa::Avx512 => unsafe { x86::qk_dots8_avx2(q, d, k_t, out) },
        #[cfg(target_arch = "aarch64")]
        SimdIsa::Neon => unsafe { neon::qk_dots8_neon(q, d, k_t, out) },
        _ => qk_dots8_scalar(q, d, k_t, out),
    }
}

/// Single dot with `dot_kv`'s 4-lane geometry (the per-row tail path).
pub fn dot_kv_f32(isa: SimdIsa, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match isa {
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx2 | SimdIsa::Avx512 => unsafe { x86::dot4_sse(a, b) },
        #[cfg(target_arch = "aarch64")]
        SimdIsa::Neon => unsafe { neon::dot4_neon(a, b) },
        _ => dot4_scalar(a, b),
    }
}

/// `fast_exp_block`: `w[i] = e^(w[i]-shift)` with the −87 clamp, returning
/// the sum accumulated in element order.
pub fn exp_block(isa: SimdIsa, w: &mut [f32], shift: f32) -> f32 {
    exp_block_dispatch(isa, w, shift, false)
}

/// Per-row tail exp pass: `fast_exp` semantics (exact 0.0 below −87),
/// returning the element-order sum.
pub fn exp_block_cutoff(isa: SimdIsa, w: &mut [f32], shift: f32) -> f32 {
    exp_block_dispatch(isa, w, shift, true)
}

fn exp_block_dispatch(isa: SimdIsa, w: &mut [f32], shift: f32, cutoff: bool) -> f32 {
    match isa {
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx2 | SimdIsa::Avx512 => unsafe { x86::exp_block_avx2(w, shift, cutoff) },
        #[cfg(target_arch = "aarch64")]
        SimdIsa::Neon => unsafe { neon::exp_block_neon(w, shift, cutoff) },
        _ => exp_block_scalar(w, shift, cutoff),
    }
}

/// V accumulation for 8 rows: `o8[r*d + i] += e[r] * v_t[i]`. Element-wise
/// multiply-then-add, bit-identical at any vector width.
pub fn axpy_rows8(isa: SimdIsa, e: &[f32; 8], v_t: &[f32], d: usize, o8: &mut [f32]) {
    debug_assert!(v_t.len() >= d && o8.len() >= 8 * d);
    match isa {
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx2 => unsafe { x86::axpy_rows_avx2(e, v_t, d, o8) },
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx512 => unsafe { x86::axpy_rows_avx512(e, v_t, d, o8) },
        #[cfg(target_arch = "aarch64")]
        SimdIsa::Neon => unsafe { neon::axpy_rows_neon(e, v_t, d, o8) },
        _ => {
            for (r, &er) in e.iter().enumerate() {
                axpy_scalar(er, &v_t[..d], &mut o8[r * d..(r + 1) * d]);
            }
        }
    }
}

/// V accumulation for 4 rows (same contract as [`axpy_rows8`]).
pub fn axpy_rows4(isa: SimdIsa, e: &[f32; 4], v_t: &[f32], d: usize, o4: &mut [f32]) {
    debug_assert!(v_t.len() >= d && o4.len() >= 4 * d);
    match isa {
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx2 => unsafe { x86::axpy_rows_avx2(&e[..], v_t, d, o4) },
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx512 => unsafe { x86::axpy_rows_avx512(&e[..], v_t, d, o4) },
        #[cfg(target_arch = "aarch64")]
        SimdIsa::Neon => unsafe { neon::axpy_rows_neon(&e[..], v_t, d, o4) },
        _ => {
            for (r, &er) in e.iter().enumerate() {
                axpy_scalar(er, &v_t[..d], &mut o4[r * d..(r + 1) * d]);
            }
        }
    }
}

/// `y += alpha * x` (the per-row tail V pass).
pub fn axpy_f32(isa: SimdIsa, alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    match isa {
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx2 => unsafe { x86::axpy_avx2(alpha, x, y) },
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        SimdIsa::Avx512 => unsafe { x86::axpy_avx512(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        SimdIsa::Neon => unsafe { neon::axpy_neon(alpha, x, y) },
        _ => axpy_scalar(alpha, x, y),
    }
}

// ---------------------------------------------------------------------------
// x86 / x86-64 vector bodies.
//
// Safety contract for every function here: the caller must have verified
// the corresponding features at runtime (the dispatchers above only route
// here for Avx2/Avx512, which `probe_available` gates on cpuid). All use
// raw-pointer loads/stores, so slice bounds are the callers' contract
// (debug-asserted at the dispatchers).
// ---------------------------------------------------------------------------

#[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
mod x86 {
    use super::{exp_clamped, exp_cutoff, EXP_C3, EXP_C4, EXP_C5, EXP_C6};
    use super::{EXP_LN2_HI, EXP_LN2_LO, EXP_LOG2E};
    #[cfg(target_arch = "x86")]
    use core::arch::x86::*;
    #[cfg(target_arch = "x86_64")]
    use core::arch::x86_64::*;

    #[target_feature(enable = "avx2,f16c")]
    pub(super) unsafe fn widen_f16_avx2(src: &[u16], dst: &mut [f32]) {
        let n = src.len();
        let mut i = 0;
        while i + 8 <= n {
            let h = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_cvtph_ps(h));
            i += 8;
        }
        while i < n {
            *dst.get_unchecked_mut(i) =
                crate::kvcache::dtype::f16_bits_to_f32(*src.get_unchecked(i));
            i += 1;
        }
    }

    #[target_feature(enable = "avx512f,f16c")]
    pub(super) unsafe fn widen_f16_avx512(src: &[u16], dst: &mut [f32]) {
        let n = src.len();
        let mut i = 0;
        while i + 16 <= n {
            let h = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            _mm512_storeu_ps(dst.as_mut_ptr().add(i), _mm512_cvtph_ps(h));
            i += 16;
        }
        if i < n {
            widen_f16_avx2(&src[i..], &mut dst[i..]);
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn widen_bf16_avx2(src: &[u16], dst: &mut [f32]) {
        let n = src.len();
        let mut i = 0;
        while i + 8 <= n {
            let h = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            let w = _mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_castsi256_ps(w));
            i += 8;
        }
        while i < n {
            *dst.get_unchecked_mut(i) =
                crate::kvcache::dtype::bf16_bits_to_f32(*src.get_unchecked(i));
            i += 1;
        }
    }

    #[target_feature(enable = "avx512f")]
    pub(super) unsafe fn widen_bf16_avx512(src: &[u16], dst: &mut [f32]) {
        let n = src.len();
        let mut i = 0;
        while i + 16 <= n {
            let h = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let w = _mm512_slli_epi32::<16>(_mm512_cvtepu16_epi32(h));
            _mm512_storeu_ps(dst.as_mut_ptr().add(i), _mm512_castsi512_ps(w));
            i += 16;
        }
        if i < n {
            widen_bf16_avx2(&src[i..], &mut dst[i..]);
        }
    }

    /// Int8 dequant load, 8-wide: sign-extend to i32, exact convert to
    /// f32, one multiply by the broadcast scale (no FMA anywhere).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn widen_i8_avx2(src: &[i8], scale: f32, dst: &mut [f32]) {
        let n = src.len();
        let sv = _mm256_set1_ps(scale);
        let mut i = 0;
        while i + 8 <= n {
            let q = _mm_loadl_epi64(src.as_ptr().add(i) as *const __m128i);
            let w = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(q));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_mul_ps(w, sv));
            i += 8;
        }
        while i < n {
            *dst.get_unchecked_mut(i) = *src.get_unchecked(i) as f32 * scale;
            i += 1;
        }
    }

    #[target_feature(enable = "avx512f,avx2")]
    pub(super) unsafe fn widen_i8_avx512(src: &[i8], scale: f32, dst: &mut [f32]) {
        let n = src.len();
        let sv = _mm512_set1_ps(scale);
        let mut i = 0;
        while i + 16 <= n {
            let q = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            let w = _mm512_cvtepi32_ps(_mm512_cvtepi8_epi32(q));
            _mm512_storeu_ps(dst.as_mut_ptr().add(i), _mm512_mul_ps(w, sv));
            i += 16;
        }
        if i < n {
            widen_i8_avx2(&src[i..], scale, &mut dst[i..]);
        }
    }

    /// 8 dots against one K row. For d ∈ {64, 128} this replicates
    /// `dot_d`'s 8-lane geometry: one ymm accumulator per query row, the
    /// shared K vector loaded once per 8 columns, then a sequential
    /// lane-order horizontal fold. Multiply and add stay separate ops —
    /// a vfmadd here would skip the intermediate rounding the scalar
    /// kernel performs and break bit-identity.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn qk_dots8_avx2(q: &[f32], d: usize, k_t: &[f32], out: &mut [f32; 8]) {
        if d != 64 && d != 128 {
            // Dynamic head dims use dot_kv's 4-lane geometry per row.
            for (r, o) in out.iter_mut().enumerate() {
                *o = dot4_sse(&q[r * d..(r + 1) * d], &k_t[..d]);
            }
            return;
        }
        let qp = q.as_ptr();
        let kp = k_t.as_ptr();
        let mut acc = [_mm256_setzero_ps(); 8];
        let mut i = 0;
        while i + 8 <= d {
            let kv = _mm256_loadu_ps(kp.add(i));
            for (r, a) in acc.iter_mut().enumerate() {
                let qv = _mm256_loadu_ps(qp.add(r * d + i));
                *a = _mm256_add_ps(*a, _mm256_mul_ps(qv, kv));
            }
            i += 8;
        }
        for (r, o) in out.iter_mut().enumerate() {
            let mut lanes = [0.0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), acc[r]);
            let mut s = 0.0f32;
            for l in lanes {
                s += l;
            }
            *o = s;
        }
    }

    /// `dot_kv` geometry on SSE registers: 4 accumulator lanes, the scalar
    /// `((s0+s1)+s2)+s3` fold, then the scalar tail.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot4_sse(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc = _mm_setzero_ps();
        for i in 0..chunks {
            let av = _mm_loadu_ps(a.as_ptr().add(i * 4));
            let bv = _mm_loadu_ps(b.as_ptr().add(i * 4));
            acc = _mm_add_ps(acc, _mm_mul_ps(av, bv));
        }
        let mut lanes = [0.0f32; 4];
        _mm_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut s = ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3];
        for i in chunks * 4..n {
            s += *a.get_unchecked(i) * *b.get_unchecked(i);
        }
        s
    }

    /// Vectorized `fast_exp_block` body. The one subtlety is rounding:
    /// the scalar kernel uses `f32::round` (ties away from zero) while
    /// SSE4/AVX rounding instructions only offer ties-to-even. For the
    /// softmax domain (arguments ≤ 0, so y = a·log₂e ∈ [−125.6, 0], far
    /// below 2²³) ties-away is exactly `trunc(y) − (frac(y) ≤ −0.5)`:
    /// `trunc` is exact, the fraction `y − trunc(y)` is exact in f32, and
    /// the comparison reproduces the away-from-zero tie break.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn exp_block_avx2(w: &mut [f32], shift: f32, cutoff: bool) -> f32 {
        let n = w.len();
        let shift_v = _mm256_set1_ps(shift);
        let clamp_v = _mm256_set1_ps(-87.0);
        let log2e_v = _mm256_set1_ps(EXP_LOG2E);
        let ln2_hi_v = _mm256_set1_ps(EXP_LN2_HI);
        let ln2_lo_v = _mm256_set1_ps(EXP_LN2_LO);
        let neg_half = _mm256_set1_ps(-0.5);
        let neg_one = _mm256_set1_ps(-1.0);
        let half = _mm256_set1_ps(0.5);
        let one = _mm256_set1_ps(1.0);
        let bias = _mm256_set1_epi32(127);
        let mut i = 0;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(w.as_ptr().add(i));
            let arg = _mm256_sub_ps(x, shift_v);
            let a = _mm256_max_ps(arg, clamp_v);
            let y = _mm256_mul_ps(a, log2e_v);
            let t = _mm256_round_ps::<{ _MM_FROUND_TO_ZERO | _MM_FROUND_NO_EXC }>(y);
            let frac = _mm256_sub_ps(y, t);
            let tie = _mm256_cmp_ps::<_CMP_LE_OQ>(frac, neg_half);
            let k = _mm256_add_ps(t, _mm256_and_ps(tie, neg_one));
            let r = _mm256_sub_ps(
                _mm256_sub_ps(a, _mm256_mul_ps(k, ln2_hi_v)),
                _mm256_mul_ps(k, ln2_lo_v),
            );
            // Horner in the scalar evaluation order, multiply and add
            // rounded separately (no FMA).
            let mut p = _mm256_set1_ps(EXP_C6);
            p = _mm256_add_ps(_mm256_set1_ps(EXP_C5), _mm256_mul_ps(r, p));
            p = _mm256_add_ps(_mm256_set1_ps(EXP_C4), _mm256_mul_ps(r, p));
            p = _mm256_add_ps(_mm256_set1_ps(EXP_C3), _mm256_mul_ps(r, p));
            p = _mm256_add_ps(half, _mm256_mul_ps(r, p));
            p = _mm256_add_ps(one, _mm256_mul_ps(r, p));
            p = _mm256_add_ps(one, _mm256_mul_ps(r, p));
            // k is integral, so the f32→i32 convert is exact.
            let bits = _mm256_slli_epi32::<23>(_mm256_add_epi32(_mm256_cvtps_epi32(k), bias));
            let mut e = _mm256_mul_ps(p, _mm256_castsi256_ps(bits));
            if cutoff {
                // fast_exp semantics: exactly 0.0 where the argument is
                // below −87 (mask-and with the "alive" lanes).
                let alive = _mm256_cmp_ps::<_CMP_GE_OQ>(arg, clamp_v);
                e = _mm256_and_ps(e, alive);
            }
            _mm256_storeu_ps(w.as_mut_ptr().add(i), e);
            i += 8;
        }
        while i < n {
            let x = *w.get_unchecked(i);
            *w.get_unchecked_mut(i) =
                if cutoff { exp_cutoff(x, shift) } else { exp_clamped(x, shift) };
            i += 1;
        }
        // The normalizer must fold in the scalar loop's element order.
        let mut acc = 0.0f32;
        for &e in w.iter() {
            acc += e;
        }
        acc
    }

    /// Row-major V accumulation: `o[r*d + i] += e[r] * v_t[i]`. The scalar
    /// kernel interleaves rows per element; every (r, i) update is an
    /// independent mul-then-add on the same operands, so the row-major
    /// order here is bit-identical.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_rows_avx2(e: &[f32], v_t: &[f32], d: usize, o: &mut [f32]) {
        let vp = v_t.as_ptr();
        for (r, &er) in e.iter().enumerate() {
            let ev = _mm256_set1_ps(er);
            let op = o.as_mut_ptr().add(r * d);
            let mut i = 0;
            while i + 8 <= d {
                let prod = _mm256_mul_ps(ev, _mm256_loadu_ps(vp.add(i)));
                _mm256_storeu_ps(op.add(i), _mm256_add_ps(_mm256_loadu_ps(op.add(i)), prod));
                i += 8;
            }
            while i < d {
                *op.add(i) += er * *vp.add(i);
                i += 1;
            }
        }
    }

    #[target_feature(enable = "avx512f")]
    pub(super) unsafe fn axpy_rows_avx512(e: &[f32], v_t: &[f32], d: usize, o: &mut [f32]) {
        let vp = v_t.as_ptr();
        for (r, &er) in e.iter().enumerate() {
            let ev = _mm512_set1_ps(er);
            let op = o.as_mut_ptr().add(r * d);
            let mut i = 0;
            while i + 16 <= d {
                let prod = _mm512_mul_ps(ev, _mm512_loadu_ps(vp.add(i)));
                _mm512_storeu_ps(op.add(i), _mm512_add_ps(_mm512_loadu_ps(op.add(i)), prod));
                i += 16;
            }
            while i < d {
                *op.add(i) += er * *vp.add(i);
                i += 1;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let av = _mm256_set1_ps(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let prod = _mm256_mul_ps(av, _mm256_loadu_ps(xp.add(i)));
            _mm256_storeu_ps(yp.add(i), _mm256_add_ps(_mm256_loadu_ps(yp.add(i)), prod));
            i += 8;
        }
        while i < n {
            *yp.add(i) += alpha * *xp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx512f")]
    pub(super) unsafe fn axpy_avx512(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let av = _mm512_set1_ps(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0;
        while i + 16 <= n {
            let prod = _mm512_mul_ps(av, _mm512_loadu_ps(xp.add(i)));
            _mm512_storeu_ps(yp.add(i), _mm512_add_ps(_mm512_loadu_ps(yp.add(i)), prod));
            i += 16;
        }
        while i < n {
            *yp.add(i) += alpha * *xp.add(i);
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// aarch64 NEON vector bodies. NEON is baseline on aarch64, so the only
// safety obligation is the raw-pointer bounds contract. `vmlaq_f32` is
// deliberately avoided: it may lower to a fused FMLA, which would skip the
// intermediate rounding the scalar kernel performs.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{exp_clamped, exp_cutoff, EXP_C3, EXP_C4, EXP_C5, EXP_C6};
    use super::{EXP_LN2_HI, EXP_LN2_LO, EXP_LOG2E};
    use core::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn widen_bf16_neon(src: &[u16], dst: &mut [f32]) {
        let n = src.len();
        let mut i = 0;
        while i + 4 <= n {
            let h = vld1_u16(src.as_ptr().add(i));
            let w = vshlq_n_u32::<16>(vmovl_u16(h));
            vst1q_f32(dst.as_mut_ptr().add(i), vreinterpretq_f32_u32(w));
            i += 4;
        }
        while i < n {
            *dst.get_unchecked_mut(i) =
                crate::kvcache::dtype::bf16_bits_to_f32(*src.get_unchecked(i));
            i += 1;
        }
    }

    /// Int8 dequant load, 8-wide: widen i8→i16→i32, exact convert, one
    /// multiply by the broadcast scale (vmulq, never vfma).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn widen_i8_neon(src: &[i8], scale: f32, dst: &mut [f32]) {
        let n = src.len();
        let sv = vdupq_n_f32(scale);
        let mut i = 0;
        while i + 8 <= n {
            let q16 = vmovl_s8(vld1_s8(src.as_ptr().add(i)));
            let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(q16)));
            let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(q16)));
            vst1q_f32(dst.as_mut_ptr().add(i), vmulq_f32(lo, sv));
            vst1q_f32(dst.as_mut_ptr().add(i + 4), vmulq_f32(hi, sv));
            i += 8;
        }
        while i < n {
            *dst.get_unchecked_mut(i) = *src.get_unchecked(i) as f32 * scale;
            i += 1;
        }
    }

    /// 8 dots against one K row; `dot_d`'s 8-lane geometry is split over
    /// two q-registers (lanes 0–3 and 4–7), folded in scalar lane order.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn qk_dots8_neon(q: &[f32], d: usize, k_t: &[f32], out: &mut [f32; 8]) {
        if d != 64 && d != 128 {
            for (r, o) in out.iter_mut().enumerate() {
                *o = dot4_neon(&q[r * d..(r + 1) * d], &k_t[..d]);
            }
            return;
        }
        let kp = k_t.as_ptr();
        for (r, o) in out.iter_mut().enumerate() {
            let qp = q.as_ptr().add(r * d);
            let mut lo = vdupq_n_f32(0.0);
            let mut hi = vdupq_n_f32(0.0);
            let mut i = 0;
            while i + 8 <= d {
                lo = vaddq_f32(lo, vmulq_f32(vld1q_f32(qp.add(i)), vld1q_f32(kp.add(i))));
                hi = vaddq_f32(
                    hi,
                    vmulq_f32(vld1q_f32(qp.add(i + 4)), vld1q_f32(kp.add(i + 4))),
                );
                i += 8;
            }
            let mut s = 0.0f32;
            s += vgetq_lane_f32::<0>(lo);
            s += vgetq_lane_f32::<1>(lo);
            s += vgetq_lane_f32::<2>(lo);
            s += vgetq_lane_f32::<3>(lo);
            s += vgetq_lane_f32::<0>(hi);
            s += vgetq_lane_f32::<1>(hi);
            s += vgetq_lane_f32::<2>(hi);
            s += vgetq_lane_f32::<3>(hi);
            *o = s;
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot4_neon(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc = vdupq_n_f32(0.0);
        for i in 0..chunks {
            acc = vaddq_f32(
                acc,
                vmulq_f32(vld1q_f32(a.as_ptr().add(i * 4)), vld1q_f32(b.as_ptr().add(i * 4))),
            );
        }
        let mut s = ((vgetq_lane_f32::<0>(acc) + vgetq_lane_f32::<1>(acc))
            + vgetq_lane_f32::<2>(acc))
            + vgetq_lane_f32::<3>(acc);
        for i in chunks * 4..n {
            s += *a.get_unchecked(i) * *b.get_unchecked(i);
        }
        s
    }

    /// Vectorized `fast_exp_block` body. FRINTA (`vrndaq_f32`) rounds
    /// ties away from zero natively — exactly `f32::round` — so no
    /// emulation is needed on this path.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn exp_block_neon(w: &mut [f32], shift: f32, cutoff: bool) -> f32 {
        let n = w.len();
        let shift_v = vdupq_n_f32(shift);
        let clamp_v = vdupq_n_f32(-87.0);
        let log2e_v = vdupq_n_f32(EXP_LOG2E);
        let ln2_hi_v = vdupq_n_f32(EXP_LN2_HI);
        let ln2_lo_v = vdupq_n_f32(EXP_LN2_LO);
        let half = vdupq_n_f32(0.5);
        let one = vdupq_n_f32(1.0);
        let bias = vdupq_n_s32(127);
        let mut i = 0;
        while i + 4 <= n {
            let x = vld1q_f32(w.as_ptr().add(i));
            let arg = vsubq_f32(x, shift_v);
            let a = vmaxq_f32(arg, clamp_v);
            let y = vmulq_f32(a, log2e_v);
            let k = vrndaq_f32(y);
            let r = vsubq_f32(vsubq_f32(a, vmulq_f32(k, ln2_hi_v)), vmulq_f32(k, ln2_lo_v));
            let mut p = vdupq_n_f32(EXP_C6);
            p = vaddq_f32(vdupq_n_f32(EXP_C5), vmulq_f32(r, p));
            p = vaddq_f32(vdupq_n_f32(EXP_C4), vmulq_f32(r, p));
            p = vaddq_f32(vdupq_n_f32(EXP_C3), vmulq_f32(r, p));
            p = vaddq_f32(half, vmulq_f32(r, p));
            p = vaddq_f32(one, vmulq_f32(r, p));
            p = vaddq_f32(one, vmulq_f32(r, p));
            // k is integral, so the truncating convert is exact.
            let bits = vshlq_n_s32::<23>(vaddq_s32(vcvtq_s32_f32(k), bias));
            let mut e = vmulq_f32(p, vreinterpretq_f32_s32(bits));
            if cutoff {
                let alive = vcgeq_f32(arg, clamp_v);
                e = vreinterpretq_f32_u32(vandq_u32(vreinterpretq_u32_f32(e), alive));
            }
            vst1q_f32(w.as_mut_ptr().add(i), e);
            i += 4;
        }
        while i < n {
            let x = *w.get_unchecked(i);
            *w.get_unchecked_mut(i) =
                if cutoff { exp_cutoff(x, shift) } else { exp_clamped(x, shift) };
            i += 1;
        }
        let mut acc = 0.0f32;
        for &e in w.iter() {
            acc += e;
        }
        acc
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy_rows_neon(e: &[f32], v_t: &[f32], d: usize, o: &mut [f32]) {
        let vp = v_t.as_ptr();
        for (r, &er) in e.iter().enumerate() {
            let ev = vdupq_n_f32(er);
            let op = o.as_mut_ptr().add(r * d);
            let mut i = 0;
            while i + 4 <= d {
                let prod = vmulq_f32(ev, vld1q_f32(vp.add(i)));
                vst1q_f32(op.add(i), vaddq_f32(vld1q_f32(op.add(i)), prod));
                i += 4;
            }
            while i < d {
                *op.add(i) += er * *vp.add(i);
                i += 1;
            }
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy_neon(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let av = vdupq_n_f32(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let prod = vmulq_f32(av, vld1q_f32(xp.add(i)));
            vst1q_f32(yp.add(i), vaddq_f32(vld1q_f32(yp.add(i)), prod));
            i += 4;
        }
        while i < n {
            *yp.add(i) += alpha * *xp.add(i);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::online::{fast_exp, fast_exp_block};
    use crate::util::rng::Pcg64;

    fn accelerated() -> Vec<SimdIsa> {
        available().into_iter().filter(|i| i.is_accelerated()).collect()
    }

    fn rand_vec(seed: u64, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        let mut v = vec![0.0; n];
        rng.fill_uniform_f32(&mut v, lo, hi);
        v
    }

    #[test]
    fn scalar_is_always_available_and_active_resolves() {
        assert!(is_available(SimdIsa::Scalar));
        assert!(available().contains(&SimdIsa::Scalar));
        let isa = active();
        assert!(is_available(isa));
        assert!(!isa.label().is_empty());
    }

    #[test]
    fn parse_labels_round_trip() {
        for isa in [SimdIsa::Scalar, SimdIsa::Avx2, SimdIsa::Avx512, SimdIsa::Neon] {
            assert_eq!(SimdIsa::parse(isa.label()), Some(isa));
        }
        assert_eq!(SimdIsa::parse("auto"), None);
        assert_eq!(SimdIsa::parse("mmx"), None);
    }

    /// The widen paths must be exact on every one of the 65536 bit
    /// patterns. NaNs compare by NaN-ness only: hardware `vcvtph2ps`
    /// quiets signalling NaNs where the software decoder preserves them,
    /// and no KV row ever stores a NaN.
    #[test]
    fn widen_is_exact_for_every_bit_pattern() {
        let src: Vec<u16> = (0..=u16::MAX).collect();
        let mut expect_f16 = vec![0.0f32; src.len()];
        let mut expect_bf16 = vec![0.0f32; src.len()];
        widen_f16_scalar(&src, &mut expect_f16);
        widen_bf16_scalar(&src, &mut expect_bf16);
        for isa in accelerated() {
            let mut got = vec![0.0f32; src.len()];
            widen_f16(isa, &src, &mut got);
            for (i, (g, e)) in got.iter().zip(&expect_f16).enumerate() {
                if e.is_nan() {
                    assert!(g.is_nan(), "{} f16 widen of {:#06x}", isa.label(), src[i]);
                } else {
                    assert_eq!(
                        g.to_bits(),
                        e.to_bits(),
                        "{} f16 widen of {:#06x}",
                        isa.label(),
                        src[i]
                    );
                }
            }
            let mut got = vec![0.0f32; src.len()];
            widen_bf16(isa, &src, &mut got);
            for (i, (g, e)) in got.iter().zip(&expect_bf16).enumerate() {
                if e.is_nan() {
                    assert!(g.is_nan(), "{} bf16 widen of {:#06x}", isa.label(), src[i]);
                } else {
                    assert_eq!(
                        g.to_bits(),
                        e.to_bits(),
                        "{} bf16 widen of {:#06x}",
                        isa.label(),
                        src[i]
                    );
                }
            }
        }
    }

    /// Ragged lengths exercise the vector/tail seams of the widen loops.
    #[test]
    fn widen_handles_ragged_tails() {
        for isa in accelerated() {
            for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 33] {
                let src: Vec<u16> = (0..n as u16).map(|i| 0x3c00 + i * 7).collect();
                let mut expect = vec![0.0f32; n];
                widen_f16_scalar(&src, &mut expect);
                let mut got = vec![0.0f32; n];
                widen_f16(isa, &src, &mut got);
                assert_eq!(got, expect, "{} f16 n={n}", isa.label());
                widen_bf16_scalar(&src, &mut expect);
                widen_bf16(isa, &src, &mut got);
                assert_eq!(got, expect, "{} bf16 n={n}", isa.label());
                let qsrc: Vec<i8> = (0..n).map(|i| (i as i32 * 19 - 120) as i8).collect();
                widen_i8_scalar(&qsrc, 0.0173, &mut expect);
                widen_i8(isa, &qsrc, 0.0173, &mut got);
                assert_eq!(got, expect, "{} i8 n={n}", isa.label());
            }
        }
    }

    /// The int8 dequant widen must match the scalar body bit-for-bit on
    /// every one of the 256 quantized values, across scales spanning the
    /// normal range (including awkward non-power-of-two scales and a
    /// subnormal product). Exactness argument: i8→f32 convert is exact,
    /// the single multiply rounds once — identical at any vector width
    /// unless an arm sneaks in FMA or a different convert.
    #[test]
    fn widen_i8_is_exact_for_every_value_and_scale() {
        let src: Vec<i8> = (-128..=127).map(|v| v as i8).collect();
        for &scale in
            &[0.0f32, 1.0, 0.0078125, 0.017331, 3.14159, 1.0e-4, 6.1e-39, 1.0e20, 1.0 / 127.0]
        {
            let mut expect = vec![0.0f32; src.len()];
            widen_i8_scalar(&src, scale, &mut expect);
            for isa in accelerated() {
                let mut got = vec![0.0f32; src.len()];
                widen_i8(isa, &src, scale, &mut got);
                for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        e.to_bits(),
                        "{} i8 widen of {} at scale {scale}",
                        isa.label(),
                        src[i]
                    );
                }
            }
        }
    }

    /// Vector exp vs the scalar kernels, bit for bit, including arguments
    /// engineered to land on rounding ties of `k = round(a·log₂e)` —
    /// the case where a naive ties-to-even vector rounding diverges.
    #[test]
    fn exp_paths_match_fast_exp_bitwise() {
        let mut args = rand_vec(0x5EED, 1024, -100.0, 0.0);
        // Near-tie arguments: y = -(m + 0.5) for integer m maps k to the
        // half-way point; perturb by ±1 ulp to cover both sides too.
        for m in 0..60 {
            let y = -(m as f32) - 0.5;
            let a = y / std::f32::consts::LOG2_E;
            args.push(a);
            args.push(f32::from_bits(a.to_bits() + 1));
            args.push(f32::from_bits(a.to_bits() - 1));
        }
        args.push(0.0);
        args.push(-87.0);
        args.push(-86.999_99);
        args.push(-87.000_01);
        args.push(-200.0);
        for isa in accelerated() {
            // Clamped (fast_exp_block) semantics, including the sum.
            let mut scalar_buf = args.clone();
            let scalar_sum = fast_exp_block(&mut scalar_buf, 0.0);
            let mut vec_buf = args.clone();
            let vec_sum = exp_block(isa, &mut vec_buf, 0.0);
            for (i, (g, e)) in vec_buf.iter().zip(&scalar_buf).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    e.to_bits(),
                    "{} exp_block arg {} = {}",
                    isa.label(),
                    i,
                    args[i]
                );
            }
            assert_eq!(vec_sum.to_bits(), scalar_sum.to_bits(), "{} sum", isa.label());
            // Cutoff (fast_exp) semantics.
            let expect: Vec<f32> = args.iter().map(|&x| fast_exp(x)).collect();
            let expect_sum: f32 = expect.iter().copied().sum();
            let mut vec_buf = args.clone();
            let vec_sum = exp_block_cutoff(isa, &mut vec_buf, 0.0);
            for (i, (g, e)) in vec_buf.iter().zip(&expect).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    e.to_bits(),
                    "{} exp_block_cutoff arg {} = {}",
                    isa.label(),
                    i,
                    args[i]
                );
            }
            assert_eq!(vec_sum.to_bits(), expect_sum.to_bits(), "{} cutoff sum", isa.label());
        }
    }

    #[test]
    fn dots_match_scalar_geometry_bitwise() {
        for isa in accelerated() {
            for &d in &[8usize, 12, 24, 64, 100, 128] {
                let q = rand_vec(1000 + d as u64, 8 * d, -2.0, 2.0);
                let k = rand_vec(2000 + d as u64, d, -2.0, 2.0);
                let mut expect = [0.0f32; 8];
                qk_dots8_scalar(&q, d, &k, &mut expect);
                let mut got = [0.0f32; 8];
                qk_dots8(isa, &q, d, &k, &mut got);
                for r in 0..8 {
                    assert_eq!(
                        got[r].to_bits(),
                        expect[r].to_bits(),
                        "{} qk_dots8 d={d} r={r}",
                        isa.label()
                    );
                }
                let single = dot_kv_f32(isa, &q[..d], &k);
                assert_eq!(
                    single.to_bits(),
                    dot4_scalar(&q[..d], &k).to_bits(),
                    "{} dot_kv_f32 d={d}",
                    isa.label()
                );
            }
        }
    }

    #[test]
    fn axpy_matches_scalar_bitwise() {
        for isa in accelerated() {
            for &d in &[7usize, 16, 24, 64, 128] {
                let v = rand_vec(3000 + d as u64, d, -2.0, 2.0);
                let e8: [f32; 8] = std::array::from_fn(|i| 0.1 + i as f32 * 0.37);
                let base = rand_vec(4000 + d as u64, 8 * d, -1.0, 1.0);
                let mut expect = base.clone();
                for (r, &er) in e8.iter().enumerate() {
                    axpy_scalar(er, &v, &mut expect[r * d..(r + 1) * d]);
                }
                let mut got = base.clone();
                axpy_rows8(isa, &e8, &v, d, &mut got);
                assert_eq!(got, expect, "{} axpy_rows8 d={d}", isa.label());

                let e4: [f32; 4] = std::array::from_fn(|i| 0.3 - i as f32 * 0.21);
                let mut expect = base[..4 * d].to_vec();
                for (r, &er) in e4.iter().enumerate() {
                    axpy_scalar(er, &v, &mut expect[r * d..(r + 1) * d]);
                }
                let mut got = base[..4 * d].to_vec();
                axpy_rows4(isa, &e4, &v, d, &mut got);
                assert_eq!(got, expect, "{} axpy_rows4 d={d}", isa.label());

                let mut expect = base[..d].to_vec();
                axpy_scalar(0.77, &v, &mut expect);
                let mut got = base[..d].to_vec();
                axpy_f32(isa, 0.77, &v, &mut got);
                assert_eq!(got, expect, "{} axpy_f32 d={d}", isa.label());
            }
        }
    }

    #[test]
    fn force_overrides_and_restores() {
        let _serial = force_lock();
        let detected = active();
        for isa in available() {
            force(Some(isa));
            assert_eq!(active(), isa);
        }
        force(None);
        assert!(is_available(active()));
        // Leave the process on its detected path for the other tests.
        let _ = detected;
    }
}
