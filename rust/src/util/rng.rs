//! Deterministic pseudo-random number generation and the distributions the
//! workload generator needs (uniform, exponential, Poisson, Zipf, normal).
//!
//! The offline crate set does not include `rand`, so this module implements
//! PCG-XSH-RR 64/32 (O'Neill, 2014) from scratch. Everything is seeded and
//! reproducible: every experiment in EXPERIMENTS.md records its seed.

/// PCG-XSH-RR 64/32: 64-bit state/LCG, 32-bit output with xorshift+rotate.
///
/// Small, fast, and statistically solid for simulation workloads. Not
/// cryptographic — nothing here needs to be.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different streams with
    /// the same seed are independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's rejection method).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        // Rejection sampling on the top bits to avoid modulo bias.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in `[lo, hi]`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Exponentially distributed value with rate `lambda` (mean `1/lambda`).
    /// Inter-arrival times of a Poisson process.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        // Inverse CDF; `1 - uniform()` avoids ln(0).
        -(1.0 - self.uniform()).ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda`.
    ///
    /// Knuth's product method for small lambda, normal approximation with
    /// continuity correction above 30 (adequate for workload synthesis).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let limit = (-lambda).exp();
            let mut prod = self.uniform();
            let mut n = 0u64;
            while prod > limit {
                n += 1;
                prod *= self.uniform();
            }
            n
        } else {
            let x = self.normal(lambda, lambda.sqrt());
            if x < 0.0 {
                0
            } else {
                (x + 0.5) as u64
            }
        }
    }

    /// Normally distributed value (Box–Muller).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.uniform(); // (0, 1]
        let u2 = self.uniform();
        let mag = (-2.0 * u1.ln()).sqrt();
        mean + std * mag * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` via inverse-CDF on
    /// a precomputed table-free approximation (rejection-inversion would be
    /// overkill; n is small for tenant selection).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        assert!(n > 0);
        // Normalising constant.
        let mut h = 0.0;
        for k in 1..=n {
            h += 1.0 / (k as f64).powf(s);
        }
        let mut u = self.uniform() * h;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len() - 1)]
    }

    /// Fill a slice with uniform f32 values in `[lo, hi)` (weight/tensor init).
    pub fn fill_uniform_f32(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform_f32(lo, hi);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be effectively independent");
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut rng = Pcg64::seeded(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_hits_all_values() {
        let mut rng = Pcg64::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::seeded(4);
        let lambda = 2.5;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn poisson_mean_small_lambda() {
        let mut rng = Pcg64::seeded(5);
        let lambda = 3.0;
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.poisson(lambda) as f64).sum::<f64>() / n as f64;
        assert!((mean - lambda).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn poisson_mean_large_lambda() {
        let mut rng = Pcg64::seeded(6);
        let lambda = 120.0;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.poisson(lambda) as f64).sum::<f64>() / n as f64;
        assert!((mean - lambda).abs() < lambda * 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(7);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal(10.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean {mean}");
        assert!((var - 9.0).abs() < 0.2, "var {var}");
    }

    #[test]
    fn zipf_rank_zero_most_frequent() {
        let mut rng = Pcg64::seeded(8);
        let mut counts = [0usize; 5];
        for _ in 0..20_000 {
            counts[rng.zipf(5, 1.1)] += 1;
        }
        assert!(counts[0] > counts[1]);
        assert!(counts[1] > counts[3]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(9);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
