//! Deterministic failpoint injection (tikv `fail-rs` idiom, rebuilt on the
//! offline crate set).
//!
//! Named sites are compiled into serving hot paths — runner prefill/decode
//! calls, slab allocation, the stepper loop — and evaluate to *nothing* until
//! armed. The disarmed fast path is a single relaxed atomic load of a global
//! armed-site counter, so shipping the sites costs no measurable overhead;
//! this invariant is what lets the chaos CI leg assert the full e2e suite
//! passes with failpoints compiled in but disarmed.
//!
//! # Spec grammar
//!
//! ```text
//! spec   := [prob%] [count*] action [(arg)] [@skip]
//! action := off | panic | err | sleep
//! ```
//!
//! - `prob%`  — fire with the given probability per eligible evaluation,
//!   drawn from a per-site PRNG seeded by `FAILPOINT_SEED` (deterministic
//!   replay: same seed + same evaluation order = same firings).
//! - `count*` — fire at most `count` times, then the site self-disarms.
//! - `@skip`  — ignore the first `skip` evaluations ("fire on the Nth hit"
//!   is spelled `1*action@N-1`).
//! - `err(msg)` returns the message to the caller (mapped to an
//!   `anyhow::Error` at the site); `panic(msg)`/`panic` unwinds;
//!   `sleep(ms)` injects latency; `off` parks the site.
//!
//! Sites are armed programmatically via [`configure`], from a CLI flag via
//! [`configure_list`] (`--fail name=spec,name=spec`), or from the
//! `FAILPOINTS` environment variable via [`arm_from_env`].
//!
//! Failure attribution: sites evaluated on behalf of one sequence use
//! [`fire_tagged`] with a `seq:<id>` tag; the injected panic/error message
//! then carries `[seq:<id>]`, which the gateway supervisor parses back out
//! with [`seq_attribution`] to quarantine only the implicated request.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use crate::util::rng::Pcg64;

/// What an armed site does when it fires.
#[derive(Debug, Clone, PartialEq, Eq)]
enum Action {
    Off,
    /// Unwind with a descriptive payload.
    Panic(String),
    /// Return an error message for the site to surface as `anyhow::Error`.
    Err(String),
    /// Inject latency (milliseconds).
    Sleep(u64),
}

#[derive(Debug)]
struct Site {
    action: Action,
    /// Fire with this probability (percent); `None` = always.
    percent: Option<u32>,
    /// Fire at most this many times, then self-disarm; `None` = unlimited.
    remaining: Option<u64>,
    /// Ignore this many leading evaluations.
    skip: u64,
    /// Total evaluations since configuration.
    hits: u64,
    /// Total firings since configuration.
    fired: u64,
    rng: Pcg64,
}

impl Site {
    fn active(&self) -> bool {
        self.action != Action::Off && self.remaining != Some(0)
    }
}

/// Number of currently-active sites. Zero means every `fire` call returns
/// immediately after one relaxed load — the disarmed no-op invariant.
static ARMED: AtomicUsize = AtomicUsize::new(0);

fn registry() -> MutexGuard<'static, HashMap<String, Site>> {
    static REG: OnceLock<Mutex<HashMap<String, Site>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
        .lock()
        // A panic action unwinding through a caller can poison this lock;
        // the registry is always left consistent, so recover the value.
        .unwrap_or_else(|e| e.into_inner())
}

fn recount(reg: &HashMap<String, Site>) {
    let n = reg.values().filter(|s| s.active()).count();
    ARMED.store(n, Ordering::SeqCst);
}

/// Cheap check used by call sites to skip tag formatting when disarmed.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed) != 0
}

fn site_seed(name: &str) -> u64 {
    // FNV-1a so each site gets a distinct deterministic PRNG stream.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn base_seed() -> u64 {
    std::env::var("FAILPOINT_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xfa11)
}

fn parse_spec(name: &str, spec: &str) -> Result<Site, String> {
    let mut rest = spec.trim();
    let mut skip = 0u64;
    if let Some((head, tail)) = rest.rsplit_once('@') {
        // Only treat `@N` as a skip suffix when N parses; `@` cannot occur
        // inside action args otherwise.
        if let Ok(n) = tail.trim().parse::<u64>() {
            skip = n;
            rest = head.trim();
        }
    }
    let mut percent = None;
    if let Some((p, tail)) = rest.split_once('%') {
        let p: u32 = p.trim().parse().map_err(|_| format!("{name}: bad probability {p:?}"))?;
        if p > 100 {
            return Err(format!("{name}: probability {p} > 100"));
        }
        percent = Some(p);
        rest = tail.trim();
    }
    let mut remaining = None;
    if let Some((c, tail)) = rest.split_once('*') {
        let c: u64 = c.trim().parse().map_err(|_| format!("{name}: bad count {c:?}"))?;
        remaining = Some(c);
        rest = tail.trim();
    }
    let (verb, arg) = match rest.split_once('(') {
        Some((v, a)) => {
            let a = a.strip_suffix(')').ok_or_else(|| format!("{name}: unclosed arg in {rest:?}"))?;
            (v.trim(), Some(a.trim().to_string()))
        }
        None => (rest, None),
    };
    let action = match verb {
        "off" => Action::Off,
        "panic" => Action::Panic(arg.unwrap_or_else(|| "injected panic".to_string())),
        "err" => Action::Err(arg.unwrap_or_else(|| "injected error".to_string())),
        "sleep" => {
            let ms = arg.ok_or_else(|| format!("{name}: sleep needs (ms)"))?;
            Action::Sleep(ms.parse().map_err(|_| format!("{name}: bad sleep ms {ms:?}"))?)
        }
        other => return Err(format!("{name}: unknown action {other:?}")),
    };
    Ok(Site {
        action,
        percent,
        remaining,
        skip,
        hits: 0,
        fired: 0,
        rng: Pcg64::new(base_seed(), site_seed(name)),
    })
}

/// Arm (or re-arm) one site. Spec grammar is documented at module level.
pub fn configure(name: &str, spec: &str) -> Result<(), String> {
    let site = parse_spec(name, spec)?;
    let mut reg = registry();
    reg.insert(name.to_string(), site);
    recount(&reg);
    Ok(())
}

/// Arm a comma/semicolon-separated list: `name=spec,name=spec`.
/// Returns how many sites were configured.
pub fn configure_list(list: &str) -> Result<usize, String> {
    let mut n = 0;
    for entry in list.split([',', ';']) {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (name, spec) =
            entry.split_once('=').ok_or_else(|| format!("bad failpoint entry {entry:?} (want name=spec)"))?;
        configure(name.trim(), spec)?;
        n += 1;
    }
    Ok(n)
}

/// Arm sites from the `FAILPOINTS` environment variable, if set.
/// Returns how many sites were configured (0 when unset — never disarms).
pub fn arm_from_env() -> usize {
    match std::env::var("FAILPOINTS") {
        Ok(list) if !list.trim().is_empty() => match configure_list(&list) {
            Ok(n) => n,
            Err(e) => {
                log::warn!("FAILPOINTS ignored: {e}");
                0
            }
        },
        _ => 0,
    }
}

/// Park one site (keeps its counters readable until re-configured).
pub fn disarm(name: &str) {
    let mut reg = registry();
    if let Some(site) = reg.get_mut(name) {
        site.action = Action::Off;
    }
    recount(&reg);
}

/// Remove every site. Tests use this between scenarios.
pub fn disarm_all() {
    let mut reg = registry();
    reg.clear();
    recount(&reg);
}

/// Total evaluations of a site since it was configured (0 if unknown).
pub fn hits(name: &str) -> u64 {
    registry().get(name).map(|s| s.hits).unwrap_or(0)
}

/// Total firings of a site since it was configured (0 if unknown).
pub fn fired(name: &str) -> u64 {
    registry().get(name).map(|s| s.fired).unwrap_or(0)
}

/// Evaluate a site. Disarmed: returns `None` after one relaxed atomic load.
/// Armed: `sleep` blocks then returns `None`; `panic` unwinds; `err` returns
/// `Some(message)` for the caller to surface as an error.
pub fn fire(name: &str) -> Option<String> {
    if !armed() {
        return None;
    }
    eval(name, None)
}

/// Like [`fire`], but injected panic/error messages carry `[{tag}]` so the
/// supervisor can attribute the failure (tag convention: `seq:<id>`).
pub fn fire_tagged(name: &str, tag: &str) -> Option<String> {
    if !armed() {
        return None;
    }
    eval(name, Some(tag))
}

fn eval(name: &str, tag: Option<&str>) -> Option<String> {
    let mut reg = registry();
    let site = reg.get_mut(name)?;
    if !site.active() {
        return None;
    }
    site.hits += 1;
    if site.hits <= site.skip {
        return None;
    }
    if let Some(p) = site.percent {
        if site.rng.below(100) >= p as u64 {
            return None;
        }
    }
    let mut exhausted = false;
    if let Some(rem) = site.remaining.as_mut() {
        // `active()` guaranteed rem > 0.
        *rem -= 1;
        exhausted = *rem == 0;
    }
    site.fired += 1;
    let action = site.action.clone();
    if exhausted {
        recount(&reg);
    }
    let suffix = tag.map(|t| format!(" [{t}]")).unwrap_or_default();
    match action {
        Action::Off => None,
        Action::Sleep(ms) => {
            drop(reg);
            std::thread::sleep(Duration::from_millis(ms));
            None
        }
        Action::Err(msg) => Some(format!("failpoint {name}: {msg}{suffix}")),
        Action::Panic(msg) => {
            // Drop the guard first so the unwind does not poison the registry.
            drop(reg);
            panic!("failpoint {name}: {msg}{suffix}");
        }
    }
}

/// Parse a `[seq:<id>]` attribution out of a panic payload or error message.
pub fn seq_attribution(msg: &str) -> Option<u64> {
    let start = msg.find("[seq:")? + "[seq:".len();
    let rest = &msg[start..];
    let end = rest.find(']')?;
    rest[..end].trim().parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Failpoints are process-global; lib tests in other modules may run
    // concurrently, so (a) serialize the tests in this module and (b) use
    // `test.*` site names nothing in production evaluates.
    fn guard() -> MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
    }

    struct Reset;
    impl Drop for Reset {
        fn drop(&mut self) {
            disarm_all();
        }
    }

    #[test]
    fn disarmed_is_noop() {
        let _g = guard();
        let _r = Reset;
        disarm_all();
        assert!(!armed());
        assert_eq!(fire("test.nothing"), None);
        assert_eq!(fire_tagged("test.nothing", "seq:1"), None);
    }

    #[test]
    fn err_with_count_and_skip() {
        let _g = guard();
        let _r = Reset;
        configure("test.err", "2*err(boom)@1").unwrap();
        assert!(armed());
        assert_eq!(fire("test.err"), None); // skipped (hit 1)
        assert_eq!(fire("test.err"), Some("failpoint test.err: boom".to_string()));
        assert_eq!(fire_tagged("test.err", "seq:7"), Some("failpoint test.err: boom [seq:7]".to_string()));
        // Count exhausted: self-disarmed.
        assert_eq!(fire("test.err"), None);
        assert!(!armed());
        assert_eq!(hits("test.err"), 3);
        assert_eq!(fired("test.err"), 2);
    }

    #[test]
    fn panic_action_unwinds_with_tag() {
        let _g = guard();
        let _r = Reset;
        configure("test.panic", "1*panic").unwrap();
        let out = std::panic::catch_unwind(|| {
            fire_tagged("test.panic", "seq:42");
        });
        let payload = out.unwrap_err();
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("failpoint test.panic"), "payload: {msg}");
        assert_eq!(seq_attribution(&msg), Some(42));
    }

    #[test]
    fn probability_is_seeded_and_deterministic() {
        let _g = guard();
        let _r = Reset;
        let run = || -> Vec<bool> {
            configure("test.prob", "50%err(x)").unwrap();
            (0..64).map(|_| fire("test.prob").is_some()).collect()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        let fired_count = a.iter().filter(|&&f| f).count();
        assert!(fired_count > 10 && fired_count < 54, "50% of 64 ≈ 32, got {fired_count}");
    }

    #[test]
    fn sleep_injects_latency() {
        let _g = guard();
        let _r = Reset;
        configure("test.sleep", "1*sleep(30)").unwrap();
        let t0 = std::time::Instant::now();
        assert_eq!(fire("test.sleep"), None);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn list_and_env_grammar() {
        let _g = guard();
        let _r = Reset;
        let n = configure_list("test.a=off; test.b=5%sleep(1), test.c=1*err(z)@2").unwrap();
        assert_eq!(n, 3);
        assert!(armed()); // b and c are active even though a is off
        assert!(configure_list("garbage").is_err());
        assert!(configure("test.bad", "explode").is_err());
        assert!(configure("test.bad", "200%err(x)").is_err());
        assert!(configure("test.bad", "sleep").is_err());
    }

    #[test]
    fn attribution_parsing() {
        assert_eq!(seq_attribution("failpoint engine.prefill: boom [seq:19]"), Some(19));
        assert_eq!(seq_attribution("prefill slice failed [seq:3]: io"), Some(3));
        assert_eq!(seq_attribution("no tag here"), None);
        assert_eq!(seq_attribution("[seq:notanum]"), None);
    }
}
