//! Summary statistics and latency histograms for benchmark reporting.
//!
//! `criterion` is not in the offline crate set, so the bench harness
//! (`util::bench`) and the serving metrics build on these primitives.

/// Streaming summary of a set of f64 samples (Welford's online algorithm for
/// mean/variance, plus min/max and a retained sample buffer for percentiles).
///
/// By default every sample is retained (exact percentiles over the whole
/// run). Long-running servers call [`Summary::set_sample_limit`] so the
/// buffer stays bounded: count/mean/variance/min/max remain exact lifetime
/// statistics (they are streaming), while percentiles are computed over a
/// window of the most recent `limit..2*limit` samples.
#[derive(Debug, Clone)]
pub struct Summary {
    samples: Vec<f64>,
    /// Lifetime sample count (samples may be windowed away).
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sample_limit: Option<usize>,
}

impl Default for Summary {
    fn default() -> Self {
        Self::new()
    }
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            samples: Vec::new(),
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sample_limit: None,
        }
    }

    /// Bound the retained percentile buffer. Amortized O(1): the buffer is
    /// allowed to reach `2*limit` before the oldest half is dropped.
    pub fn set_sample_limit(&mut self, limit: Option<usize>) {
        self.sample_limit = limit;
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        if let Some(limit) = self.sample_limit {
            let limit = limit.max(1);
            if self.samples.len() >= 2 * limit {
                let excess = self.samples.len() - limit;
                self.samples.drain(..excess);
            }
        }
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Lifetime number of samples added (not the retained window size).
    pub fn count(&self) -> usize {
        self.n as usize
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Sum over the retained sample window (== lifetime sum when uncapped).
    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    /// Percentile by linear interpolation between closest ranks.
    /// `p` in `[0, 100]`.
    ///
    /// Runs on every `/metrics` scrape, so this is an O(n) rank selection
    /// (`select_nth_unstable_by`), not a full sort, and it orders by
    /// `f64::total_cmp`: a NaN sample (upstream instrumentation bug) ranks
    /// above +inf instead of panicking the scrape — finite percentiles
    /// stay exact, only the extreme quantiles surface the NaN itself.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut buf = self.samples.clone();
        let rank = (p.clamp(0.0, 100.0) / 100.0) * (buf.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let (_, lo_val, above) = buf.select_nth_unstable_by(lo, f64::total_cmp);
        let lo_val = *lo_val;
        if lo == hi {
            return lo_val;
        }
        // `hi == lo + 1`: the next rank is the minimum of the partition
        // above the selected element.
        let hi_val = above.iter().copied().min_by(f64::total_cmp).unwrap_or(lo_val);
        let frac = rank - lo as f64;
        lo_val * (1.0 - frac) + hi_val * frac
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Retained samples (the recent window when a sample limit is set).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Fixed-bucket log-scale latency histogram (microsecond domain).
///
/// Buckets are powers of `growth` starting at `first_bucket`; everything
/// above the last bucket lands in the overflow bucket. This is the shape of
/// histogram serving systems export to dashboards.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

impl LogHistogram {
    /// `first_bucket`: upper bound of the first bucket; `growth`: geometric
    /// growth factor; `n`: number of finite buckets.
    pub fn new(first_bucket: f64, growth: f64, n: usize) -> Self {
        assert!(first_bucket > 0.0 && growth > 1.0 && n > 0);
        let mut bounds = Vec::with_capacity(n);
        let mut b = first_bucket;
        for _ in 0..n {
            bounds.push(b);
            b *= growth;
        }
        LogHistogram { counts: vec![0; n + 1], bounds, total: 0, sum: 0.0 }
    }

    /// Default latency histogram: 1µs .. ~17s in 32 buckets (×1.7 growth).
    pub fn latency_us() -> Self {
        Self::new(1.0, 1.7, 32)
    }

    /// Seconds-domain histogram for serving latencies: 10µs .. ~48s in 30
    /// buckets (×1.7 growth). Wide enough that one scheme serves TTFT,
    /// inter-token gaps, whole steps, and sub-millisecond step phases.
    pub fn time_seconds() -> Self {
        Self::new(1e-5, 1.7, 30)
    }

    pub fn record(&mut self, x: f64) {
        let idx = match self.bounds.iter().position(|&b| x <= b) {
            Some(i) => i,
            None => self.bounds.len(),
        };
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += x;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Sum of every recorded sample (the Prometheus `_sum` series).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Finite bucket upper bounds (ascending). The overflow bucket's bound
    /// is implicitly `+Inf`.
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket counts; `counts().len() == bounds().len() + 1`, the last
    /// entry being the overflow (`+Inf`) bucket.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Approximate quantile from bucket boundaries (upper bound of the bucket
    /// containing the q-th sample).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if i < self.bounds.len() { self.bounds[i] } else { f64::INFINITY };
            }
        }
        f64::INFINITY
    }
}

/// Format a duration in microseconds with an adaptive unit.
pub fn fmt_us(us: f64) -> String {
    if us < 1_000.0 {
        format!("{us:.2}µs")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1_000.0)
    } else {
        format!("{:.2}s", us / 1_000_000.0)
    }
}

/// Format a byte count with an adaptive binary unit.
pub fn fmt_bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = b as f64;
    if b < KIB {
        format!("{b:.0}B")
    } else if b < KIB * KIB {
        format!("{:.1}KiB", b / KIB)
    } else if b < KIB * KIB * KIB {
        format!("{:.1}MiB", b / KIB / KIB)
    } else {
        format!("{:.2}GiB", b / KIB / KIB / KIB)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_limit_windows_percentiles_but_not_moments() {
        let mut s = Summary::new();
        s.set_sample_limit(Some(10));
        for x in 0..100 {
            s.add(x as f64);
        }
        assert_eq!(s.count(), 100, "lifetime count");
        assert!((s.mean() - 49.5).abs() < 1e-9, "streaming mean is exact");
        assert!((s.min() - 0.0).abs() < 1e-12 && (s.max() - 99.0).abs() < 1e-12);
        assert!(s.samples().len() <= 20, "buffer bounded at 2x the limit");
        // Percentiles reflect the recent window only.
        assert!(s.percentile(0.0) >= 80.0, "old samples windowed out");
        assert!(s.percentile(100.0) >= 99.0 - 1e-9);
    }

    #[test]
    fn summary_basic_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn summary_percentiles() {
        let mut s = Summary::new();
        for x in 1..=100 {
            s.add(x as f64);
        }
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn percentiles_tolerate_nan_samples() {
        // A NaN sample must not panic the percentile (it used to poison
        // every /metrics scrape via `partial_cmp(..).unwrap()`); it ranks
        // above +inf under total order, so finite quantiles stay exact.
        let mut s = Summary::new();
        for x in 1..=99 {
            s.add(x as f64);
        }
        s.add(f64::NAN);
        let p50 = s.percentile(50.0);
        assert!(p50.is_finite(), "median poisoned by NaN: {p50}");
        assert!((p50 - 50.0).abs() < 1.0, "median {p50} shifted by the NaN tail");
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        // The top of the distribution is the NaN itself — surfaced, not a
        // panic.
        assert!(s.percentile(100.0).is_nan());
        // Out-of-range p is clamped instead of indexing out of bounds.
        assert!(s.percentile(150.0).is_nan());
        assert!((s.percentile(-5.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn summary_empty_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LogHistogram::latency_us();
        for i in 1..=10_000u64 {
            h.record(i as f64);
        }
        assert_eq!(h.total(), 10_000);
        let q50 = h.quantile(0.5);
        let q99 = h.quantile(0.99);
        assert!(q50 <= q99);
        assert!(q50 >= 1_000.0 && q50 <= 20_000.0, "q50 {q50}");
    }

    #[test]
    fn histogram_overflow_bucket() {
        let mut h = LogHistogram::new(1.0, 2.0, 4); // buckets up to 8
        h.record(1e9);
        assert_eq!(h.quantile(1.0), f64::INFINITY);
    }

    #[test]
    fn histogram_sum_and_bucket_accessors() {
        let mut h = LogHistogram::new(1.0, 2.0, 4); // bounds 1,2,4,8
        for x in [0.5, 3.0, 100.0] {
            h.record(x);
        }
        assert_eq!(h.total(), 3);
        assert!((h.sum() - 103.5).abs() < 1e-12);
        assert_eq!(h.bounds(), &[1.0, 2.0, 4.0, 8.0]);
        assert_eq!(h.counts(), &[1, 0, 1, 0, 1]); // 0.5→b0, 3.0→b2, 100→+Inf
        assert_eq!(h.counts().len(), h.bounds().len() + 1);
        // Count consistency: bucket counts sum to total.
        assert_eq!(h.counts().iter().sum::<u64>(), h.total());
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_us(12.5), "12.50µs");
        assert_eq!(fmt_us(12_500.0), "12.50ms");
        assert_eq!(fmt_us(2_500_000.0), "2.50s");
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(1536), "1.5KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024 * 1024), "3.00GiB");
    }
}
