//! Request types and arrival processes for the end-to-end evaluation.
//!
//! §4.2: "Requests arrive at the server randomly following the Poisson
//! arrival process parameterised by λ (average requests per second)". The
//! generator draws i.i.d. exponential inter-arrival gaps and assigns each
//! request a tenant (uniform or Zipf-skewed) and a completion budget.

use crate::util::rng::Pcg64;

/// One inference request as the router sees it.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    /// Tenant whose system prompt prefixes the prompt.
    pub tenant: usize,
    /// Full prompt tokens (system prompt ++ user query).
    pub prompt: Vec<u32>,
    /// Tokens of the prompt shared with the tenant's other requests.
    pub shared_tokens: usize,
    /// Completion tokens to decode.
    pub max_new_tokens: usize,
}

/// Arrival trace configuration.
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    /// Mean requests per second (the λ of §4.2).
    pub rps: f64,
    /// Number of requests to generate.
    pub n_requests: usize,
    /// Tenants to draw from.
    pub n_tenants: usize,
    /// Zipf exponent for tenant popularity; 0 = uniform.
    pub tenant_skew: f64,
    /// User-query tokens appended after the system prompt.
    pub query_tokens: usize,
    /// Completion tokens per request.
    pub completion_tokens: usize,
    pub seed: u64,
}

/// A generated arrival trace (sorted by arrival time by construction).
#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<Request>,
}

impl Trace {
    /// Generate a Poisson trace. `make_prompt(tenant, rng) -> (tokens,
    /// shared)` supplies the actual prompt (usually `Corpus`-backed).
    pub fn poisson(
        cfg: &TraceConfig,
        mut make_prompt: impl FnMut(usize, &mut Pcg64) -> (Vec<u32>, usize),
    ) -> Trace {
        assert!(cfg.rps > 0.0 && cfg.n_tenants > 0);
        let mut rng = Pcg64::new(cfg.seed, 0);
        let mut t = 0.0f64;
        let mut requests = Vec::with_capacity(cfg.n_requests);
        for id in 0..cfg.n_requests {
            t += rng.exponential(cfg.rps);
            let tenant = if cfg.tenant_skew > 0.0 {
                rng.zipf(cfg.n_tenants, cfg.tenant_skew)
            } else {
                rng.range(0, cfg.n_tenants - 1)
            };
            let (prompt, shared_tokens) = make_prompt(tenant, &mut rng);
            requests.push(Request {
                id: id as u64,
                arrival_s: t,
                tenant,
                prompt,
                shared_tokens,
                max_new_tokens: cfg.completion_tokens,
            });
        }
        Trace { requests }
    }

    /// Synthetic prompts without a tokenizer: `shared` tokens common to the
    /// tenant plus unique filler — used by simulator benches where only
    /// token *identities* matter, not text.
    pub fn poisson_synthetic(cfg: &TraceConfig, system_tokens: usize) -> Trace {
        Self::poisson(cfg, |tenant, rng| {
            let mut prompt: Vec<u32> =
                (0..system_tokens as u32).map(|i| tenant as u32 * 1_000_000 + i).collect();
            // Unique query suffix: high bits keyed by a per-request nonce.
            let nonce = rng.next_u64() as u32 & 0x3FFFFF;
            prompt.extend((0..cfg.query_tokens as u32).map(|i| 0x8000_0000 | (nonce << 8) | i & 0xFF));
            (prompt, system_tokens)
        })
    }

    pub fn duration_s(&self) -> f64 {
        self.requests.last().map(|r| r.arrival_s).unwrap_or(0.0)
    }

    /// Empirical requests-per-second of the trace.
    pub fn empirical_rps(&self) -> f64 {
        if self.requests.len() < 2 {
            return 0.0;
        }
        self.requests.len() as f64 / self.duration_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(rps: f64, n: usize) -> TraceConfig {
        TraceConfig {
            rps,
            n_requests: n,
            n_tenants: 4,
            tenant_skew: 0.0,
            query_tokens: 16,
            completion_tokens: 64,
            seed: 11,
        }
    }

    #[test]
    fn arrivals_are_sorted_and_rate_matches() {
        let trace = Trace::poisson_synthetic(&cfg(2.0, 4000), 100);
        let mut prev = 0.0;
        for r in &trace.requests {
            assert!(r.arrival_s >= prev);
            prev = r.arrival_s;
        }
        let rps = trace.empirical_rps();
        assert!((rps - 2.0).abs() < 0.15, "empirical rps {rps}");
    }

    #[test]
    fn interarrival_is_exponential_enough() {
        // CV (std/mean) of exponential gaps is 1.
        let trace = Trace::poisson_synthetic(&cfg(5.0, 5000), 10);
        let gaps: Vec<f64> = trace
            .requests
            .windows(2)
            .map(|w| w[1].arrival_s - w[0].arrival_s)
            .collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.08, "cv {cv}");
    }

    #[test]
    fn same_tenant_shares_prefix_different_tenants_dont() {
        let trace = Trace::poisson_synthetic(&cfg(1.0, 64), 50);
        let by_tenant: Vec<&Request> =
            trace.requests.iter().filter(|r| r.tenant == trace.requests[0].tenant).collect();
        if by_tenant.len() >= 2 {
            assert_eq!(&by_tenant[0].prompt[..50], &by_tenant[1].prompt[..50]);
        }
        let other = trace.requests.iter().find(|r| r.tenant != trace.requests[0].tenant);
        if let Some(o) = other {
            assert_ne!(&o.prompt[..50], &trace.requests[0].prompt[..50]);
        }
    }

    #[test]
    fn zipf_skews_tenant_popularity() {
        let mut c = cfg(1.0, 3000);
        c.tenant_skew = 1.2;
        let trace = Trace::poisson_synthetic(&c, 10);
        let mut counts = [0usize; 4];
        for r in &trace.requests {
            counts[r.tenant] += 1;
        }
        assert!(counts[0] > counts[3] * 2, "{counts:?}");
    }

    #[test]
    fn deterministic_trace() {
        let a = Trace::poisson_synthetic(&cfg(1.0, 100), 20);
        let b = Trace::poisson_synthetic(&cfg(1.0, 100), 20);
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.prompt, y.prompt);
        }
    }
}
