//! Synthetic multi-tenant system-prompt corpus.
//!
//! §2.1 of the paper motivates PAKV with four real systems whose shared
//! system prompts run 879–4257 tokens (Table 2). Those prompts are not
//! redistributable, so this module synthesises structurally equivalent
//! ones: tool/API definitions with parameter lists, chain-of-thought
//! few-shot examples, and document metadata, stitched until a target token
//! length is reached. Every tenant gets a distinct prompt; every request of
//! a tenant shares that tenant's prompt verbatim — the property PAKV
//! exploits.

use super::tokenizer::Tokenizer;
use crate::util::rng::Pcg64;

/// What the tenant's prompt is made of (mirrors Table 2's "Usage" column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PromptKind {
    /// Tool/function definitions + invocation examples (Chameleon, ToolQA).
    ToolDefinitions,
    /// Chain-of-thought worked examples (CREATOR).
    CotExamples,
    /// Document metadata for QA (PDFTriage).
    DocumentMetadata,
}

impl PromptKind {
    pub const ALL: [PromptKind; 3] =
        [PromptKind::ToolDefinitions, PromptKind::CotExamples, PromptKind::DocumentMetadata];

    pub fn label(&self) -> &'static str {
        match self {
            PromptKind::ToolDefinitions => "tools",
            PromptKind::CotExamples => "cot-examples",
            PromptKind::DocumentMetadata => "doc-metadata",
        }
    }
}

/// One tenant (application) with a fixed shared system prompt.
#[derive(Debug, Clone)]
pub struct Tenant {
    pub id: usize,
    pub kind: PromptKind,
    pub system_prompt: String,
    pub system_tokens: Vec<u32>,
}

/// A corpus of tenants sharing one tokenizer.
pub struct Corpus {
    pub tenants: Vec<Tenant>,
}

impl Corpus {
    /// Synthesize `n_tenants` tenants whose system prompts tokenize to
    /// approximately `target_tokens` each (within one building block).
    pub fn synthesize(tok: &Tokenizer, n_tenants: usize, target_tokens: usize, seed: u64) -> Self {
        let mut tenants = Vec::with_capacity(n_tenants);
        for id in 0..n_tenants {
            let mut rng = Pcg64::new(seed, id as u64);
            let kind = PromptKind::ALL[id % PromptKind::ALL.len()];
            let mut prompt = header(kind, id);
            let mut tokens = tok.encode(&prompt).len();
            let mut block_idx = 0;
            while tokens < target_tokens {
                let block = building_block(kind, id, block_idx, &mut rng);
                tokens += tok.encode(&block).len();
                prompt.push_str(&block);
                block_idx += 1;
            }
            let system_tokens = tok.encode(&prompt);
            tenants.push(Tenant { id, kind, system_prompt: prompt, system_tokens });
        }
        Corpus { tenants }
    }

    /// Generate one user query for a tenant: the task-specific suffix that
    /// differs per request. Returns full prompt tokens (system ++ query).
    pub fn make_request_tokens(
        &self,
        tok: &Tokenizer,
        tenant: usize,
        query_tokens: usize,
        rng: &mut Pcg64,
    ) -> Vec<u32> {
        let t = &self.tenants[tenant % self.tenants.len()];
        let mut tokens = t.system_tokens.clone();
        let query = user_query(rng);
        let mut q = tok.encode(&query);
        // Pad/trim to the requested query length with filler clauses.
        while q.len() < query_tokens {
            q.extend(tok.encode(&user_query(rng)));
        }
        q.truncate(query_tokens);
        tokens.extend(q);
        tokens
    }

    /// Table-2-style statistics: per-tenant token counts.
    pub fn stats(&self) -> CorpusStats {
        let counts: Vec<usize> = self.tenants.iter().map(|t| t.system_tokens.len()).collect();
        let sum: usize = counts.iter().sum();
        CorpusStats {
            tenants: counts.len(),
            avg_tokens: if counts.is_empty() { 0 } else { sum / counts.len() },
            max_tokens: counts.iter().copied().max().unwrap_or(0),
            min_tokens: counts.iter().copied().min().unwrap_or(0),
        }
    }
}

/// Aggregate prompt-length statistics (the paper's Table 2 columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CorpusStats {
    pub tenants: usize,
    pub avg_tokens: usize,
    pub max_tokens: usize,
    pub min_tokens: usize,
}

fn header(kind: PromptKind, id: usize) -> String {
    match kind {
        PromptKind::ToolDefinitions => format!(
            "Instructions: Given the following list of API specifications and the user query, \
             you will choose the most appropriate API for application {id} to invoke and parse \
             the corresponding parameters from the user query. If none of the API descriptions \
             match the user query intent, return not_found(). Your response must strictly \
             follow the syntax of: api_chosen(param1=PARSED_PARAM1, ...).\n\n"
        ),
        PromptKind::CotExamples => format!(
            "You solve math and reasoning problems for workspace {id}. Think step by step. \
             For each problem, write the reasoning chain, then the final answer on its own \
             line. Follow the format of the worked examples below exactly.\n\n"
        ),
        PromptKind::DocumentMetadata => format!(
            "You answer questions about document collection {id}. Use only the metadata and \
             extracted sections below; if the answer is not present, say so. Cite the page \
             number for every claim.\n\n"
        ),
    }
}

/// One reproducible content block of roughly 60–120 tokens.
fn building_block(kind: PromptKind, tenant: usize, idx: usize, rng: &mut Pcg64) -> String {
    match kind {
        PromptKind::ToolDefinitions => {
            let verbs = ["search", "lookup", "list", "create", "update", "translate", "rank"];
            let nouns = ["hotels", "flights", "catalog", "documents", "restaurants", "images", "events"];
            let verb = verbs[rng.range(0, verbs.len() - 1)];
            let noun = nouns[rng.range(0, nouns.len() - 1)];
            format!(
                "- {verb}_{noun}_{tenant}_{idx}(count, offset, query, region, safe_mode): \
                 The {verb} API lets the assistant {verb} {noun} matching a keyword string. \
                 Parameters:\n  - count: [optional] Number of results to return. The default \
                 is 10 and the maximum value is 50.\n  - offset: [optional] Zero-based offset \
                 indicating the number of results to skip before returning results.\n  - \
                 query: [required] The user's query term. The term may not be empty.\n  - \
                 region: [optional] Two-letter market code used to rank results.\n  - \
                 safe_mode: [optional] One of off, moderate, strict. The default is moderate.\n"
            )
        }
        PromptKind::CotExamples => {
            let a = rng.range(12, 97);
            let b = rng.range(3, 41);
            format!(
                "Example {idx}: A vendor sells {a} crates and each crate holds {b} units. \
                 After selling a third of the units, how many remain?\nReasoning: total units \
                 are {a} times {b} which is {}. A third of that is {}. Remaining is total \
                 minus a third, which is {}.\nAnswer: {}\n\n",
                a * b,
                a * b / 3,
                a * b - a * b / 3,
                a * b - a * b / 3
            )
        }
        PromptKind::DocumentMetadata => {
            let pages = rng.range(4, 60);
            format!(
                "Section {idx}: title \"Quarterly operations review part {idx} for tenant \
                 {tenant}\", pages {pages}, author record id {}, keywords: logistics, \
                 forecast, inventory, compliance. Abstract: the section summarises shipment \
                 volumes, staffing levels and exception reports for the period, with tables \
                 on page {} and appendices describing methodology.\n\n",
                rng.range(1000, 9999),
                pages / 2 + 1,
            )
        }
    }
}

fn user_query(rng: &mut Pcg64) -> String {
    let subjects = [
        "the latest shipment report",
        "a flight from Seattle to Austin next Friday",
        "vegan restaurants open on Saturday",
        "the total units across all crates",
        "the author of section twelve",
        "hotels near the convention center under 200 dollars",
        "the compliance exceptions in the appendix",
    ];
    let asks = [
        "Can you find {}?",
        "What is {}?",
        "Please summarise {} briefly.",
        "I need {} right away.",
        "Look up {} and give one suggestion.",
    ];
    let s = subjects[rng.range(0, subjects.len() - 1)];
    let a = asks[rng.range(0, asks.len() - 1)];
    format!(" {} ", a.replace("{}", s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::OnceLock;

    fn tok() -> &'static Tokenizer {
        static TOK: OnceLock<Tokenizer> = OnceLock::new();
        TOK.get_or_init(Tokenizer::default_english)
    }

    #[test]
    fn prompts_hit_target_length() {
        let corpus = Corpus::synthesize(tok(), 4, 800, 42);
        for t in &corpus.tenants {
            let n = t.system_tokens.len();
            assert!((800..1100).contains(&n), "tenant {} has {n} tokens", t.id);
        }
    }

    #[test]
    fn tenants_have_distinct_prompts() {
        let corpus = Corpus::synthesize(tok(), 6, 300, 42);
        for i in 0..corpus.tenants.len() {
            for j in i + 1..corpus.tenants.len() {
                assert_ne!(
                    corpus.tenants[i].system_tokens, corpus.tenants[j].system_tokens,
                    "tenants {i} and {j} collide"
                );
            }
        }
    }

    #[test]
    fn requests_share_tenant_prefix_exactly() {
        let corpus = Corpus::synthesize(tok(), 2, 400, 7);
        let mut rng = Pcg64::seeded(1);
        let a = corpus.make_request_tokens(tok(), 0, 30, &mut rng);
        let b = corpus.make_request_tokens(tok(), 0, 30, &mut rng);
        let sys = corpus.tenants[0].system_tokens.len();
        assert_eq!(&a[..sys], &b[..sys], "system prompt tokens identical");
        assert_ne!(&a[sys..], &b[sys..], "queries differ");
        assert_eq!(a.len(), sys + 30);
    }

    #[test]
    fn stats_summarise() {
        let corpus = Corpus::synthesize(tok(), 3, 500, 9);
        let s = corpus.stats();
        assert_eq!(s.tenants, 3);
        assert!(s.min_tokens <= s.avg_tokens && s.avg_tokens <= s.max_tokens);
        assert!(s.avg_tokens >= 500);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Corpus::synthesize(tok(), 2, 300, 5);
        let b = Corpus::synthesize(tok(), 2, 300, 5);
        assert_eq!(a.tenants[1].system_tokens, b.tenants[1].system_tokens);
    }
}
