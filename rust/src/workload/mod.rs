//! Workload synthesis: tokenizer, multi-tenant system-prompt corpus
//! (§2.1 / Table 2), and Poisson arrival traces (§4.2).

pub mod arrivals;
pub mod corpus;
pub mod tokenizer;

pub use arrivals::{Request, Trace, TraceConfig};
pub use corpus::{Corpus, CorpusStats, PromptKind, Tenant};
pub use tokenizer::Tokenizer;
