//! Deterministic byte-pair-style tokenizer.
//!
//! The paper tokenizes system prompts with OpenAI's tiktoken (Table 2). No
//! tokenizer library exists in the offline crate set, so this module
//! implements a small greedy-BPE tokenizer: a fixed vocabulary of byte
//! tokens plus merges learned once from a seed corpus at construction. It
//! is deterministic, reversible on its training alphabet, and produces
//! ~3.5–4.5 characters/token on English-like text — close enough to
//! tiktoken's ratio that Table-2-style token statistics are meaningful.

use std::collections::HashMap;

/// Greedy longest-match subword tokenizer.
pub struct Tokenizer {
    /// Piece string -> token id. Ids 0..256 are single bytes.
    vocab: HashMap<Vec<u8>, u32>,
    /// Token id -> piece bytes (decode table).
    pieces: Vec<Vec<u8>>,
    /// Longest piece length, bounds the greedy scan.
    max_piece: usize,
}

impl Tokenizer {
    /// Build from a training corpus: byte vocabulary + the `extra` most
    /// frequent pairs merged iteratively (tiny BPE).
    pub fn train(corpus: &str, extra: usize) -> Self {
        let mut pieces: Vec<Vec<u8>> = (0u8..=255).map(|b| vec![b]).collect();
        // Work on the corpus as a sequence of piece indices.
        let mut seq: Vec<u32> = corpus.bytes().map(|b| b as u32).collect();
        for _ in 0..extra {
            // Count adjacent pairs.
            let mut counts: HashMap<(u32, u32), u32> = HashMap::new();
            for w in seq.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            let Some((&pair, &cnt)) = counts.iter().max_by_key(|(p, c)| (**c, std::cmp::Reverse(**p)))
            else {
                break;
            };
            if cnt < 2 {
                break;
            }
            let mut merged = pieces[pair.0 as usize].clone();
            merged.extend_from_slice(&pieces[pair.1 as usize]);
            let new_id = pieces.len() as u32;
            pieces.push(merged);
            // Apply the merge over the sequence.
            let mut out = Vec::with_capacity(seq.len());
            let mut i = 0;
            while i < seq.len() {
                if i + 1 < seq.len() && (seq[i], seq[i + 1]) == pair {
                    out.push(new_id);
                    i += 2;
                } else {
                    out.push(seq[i]);
                    i += 1;
                }
            }
            seq = out;
        }
        let max_piece = pieces.iter().map(|p| p.len()).max().unwrap_or(1);
        let vocab = pieces.iter().enumerate().map(|(i, p)| (p.clone(), i as u32)).collect();
        Tokenizer { vocab, pieces, max_piece }
    }

    /// A tokenizer trained on a built-in English/code-flavoured seed corpus
    /// with 15k merges — the default for workload synthesis.
    pub fn default_english() -> Self {
        Self::train(SEED_CORPUS, 1500)
    }

    pub fn vocab_size(&self) -> usize {
        self.pieces.len()
    }

    /// Greedy longest-match encoding.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let bytes = text.as_bytes();
        let mut out = Vec::with_capacity(bytes.len() / 3);
        let mut i = 0;
        while i < bytes.len() {
            let mut len = self.max_piece.min(bytes.len() - i);
            loop {
                if let Some(&id) = self.vocab.get(&bytes[i..i + len]) {
                    out.push(id);
                    i += len;
                    break;
                }
                len -= 1;
                debug_assert!(len > 0, "byte fallback always matches");
            }
        }
        out
    }

    pub fn decode(&self, tokens: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &t in tokens {
            bytes.extend_from_slice(&self.pieces[t as usize]);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Mean characters per token over a text (compression diagnostics).
    pub fn chars_per_token(&self, text: &str) -> f64 {
        let n = self.encode(text).len();
        if n == 0 {
            0.0
        } else {
            text.len() as f64 / n as f64
        }
    }
}

/// Seed corpus for merge training: English prose + API/JSON-ish text, the
/// register system prompts are written in.
const SEED_CORPUS: &str = r#"
You are a helpful assistant. Given the following list of API specifications
and the user query, you will choose the most appropriate API to invoke and
try to parse the corresponding parameters from the user query. If none of
the API descriptions match the user query intent, you will return not_found.
Your response must strictly follow the syntax of the function call format.
Parameters: count: optional. The number of search results to return in the
response. The default is ten and the maximum value is fifty. offset: the
zero-based offset that indicates the number of results to skip before
returning results. query: required. The user search query term. The term may
not be empty. safe_search: optional. A filter used to filter results for
adult content. language: optional. The language to use for user interface
strings. You may specify the language using either a two-letter or
four-letter code. Following are examples of choosing the API that matches
the user query and parsing parameters. The instructions below describe the
task. Think step by step and explain your reasoning before giving the final
answer. Use the tools when the question requires up to date information or
precise calculation. The document metadata includes the title, the author,
the number of pages and the table of contents. Answer the question using
only the provided context. If the answer is not contained in the context,
say you do not know. Here are a few examples demonstrating the expected
input and output format for the task described above. The assistant should
respond with a single function call and no additional commentary. datetime:
user_query: What is the weather in San Francisco this weekend? api_call:
search(query="weather San Francisco weekend", count=5, language="en")
"#;

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::OnceLock;

    fn tok() -> &'static Tokenizer {
        static TOK: OnceLock<Tokenizer> = OnceLock::new();
        TOK.get_or_init(|| Tokenizer::train(SEED_CORPUS, 300))
    }

    #[test]
    fn roundtrip_ascii() {
        let t = tok();
        let text = "The user search query term may not be empty.";
        assert_eq!(t.decode(&t.encode(text)), text);
    }

    #[test]
    fn roundtrip_unseen_bytes() {
        let t = tok();
        let text = "ünïcode & emoji 🎉 bytes";
        assert_eq!(t.decode(&t.encode(text)), text);
    }

    #[test]
    fn compresses_english() {
        let t = tok();
        let cpt = t.chars_per_token("the parameters of the search query results");
        assert!(cpt > 1.8, "learned merges compress: {cpt} chars/token");
    }

    #[test]
    fn deterministic() {
        let a = Tokenizer::train(SEED_CORPUS, 200);
        let b = Tokenizer::train(SEED_CORPUS, 200);
        let text = "deterministic tokenization of this sentence";
        assert_eq!(a.encode(text), b.encode(text));
    }

    #[test]
    fn shared_prefix_tokenizes_to_shared_prefix() {
        // Critical property for PAKV: same text prefix -> same token prefix.
        let t = tok();
        let sys = "You are a helpful assistant. Use the tools.";
        let a = t.encode(&format!("{sys} Question one?"));
        let b = t.encode(&format!("{sys} A different question."));
        let common = a.iter().zip(&b).take_while(|(x, y)| x == y).count();
        let sys_tokens = t.encode(sys).len();
        assert!(common + 2 >= sys_tokens, "common {common} vs sys {sys_tokens}");
    }

    #[test]
    fn empty_text() {
        assert!(tok().encode("").is_empty());
        assert_eq!(tok().decode(&[]), "");
    }
}
