//! Online-softmax primitives shared by every kernel: the paper's
//! `partial_attn` (Eqn. 1) and `attn_reduce` (Eqn. 2), in the fused form
//! used on CPU (§3.3: on CPU the reduction is cheap enough to run right
//! after each partial, so no temporary `(O, m, n)^{(C)}` buffers are kept).
//!
//! State per (sequence, head) row: running max `m`, normaliser `n`, and the
//! *unnormalised* output accumulator `o` (divide by `n` once at the end).
//!
//! ## Storage dtypes
//!
//! K/V rows are generic over [`KvElem`] — the cache may store `f32`, `f16`
//! or `bf16`. Loads widen each streamed element to an f32 register inside
//! the register-blocked bodies (`to_f32` is the identity for `f32`, a
//! bit-shift for `bf16` and a table-free bit decode for `f16`), while the
//! query rows, weights, softmax statistics and output accumulators stay
//! f32. Half-precision storage therefore halves the streamed K/V bytes —
//! the dominant traffic in the chunk-first phase — without changing
//! accumulation precision.
//!
//! ## SIMD dispatch
//!
//! [`attend_block`] routes through `util/simd.rs` (see DESIGN.md §"The
//! SIMD dispatch seam"): on an accelerated ISA the K/V block is widened to
//! f32 once into a thread-local scratch and an explicit-SIMD f32 body
//! runs; otherwise the generic scalar body below executes unchanged. The
//! scalar path is the bit-identity oracle — every accelerated path must
//! reproduce it bit for bit (same reduction geometry, no FMA contraction),
//! so `PALLAS_SIMD=scalar` and the cross-ISA tests can hold outputs to
//! `assert_eq!` rather than tolerances.

use crate::kvcache::{KvDtype, KvElem};
use crate::util::simd;
use std::cell::RefCell;

/// Accumulator state for a set of rows: `m[r]`, `n[r]`, `o[r * d ..]`.
pub struct OnlineState<'a> {
    pub m: &'a mut [f32],
    pub n: &'a mut [f32],
    pub o: &'a mut [f32],
    pub head_dim: usize,
}

impl OnlineState<'_> {
    pub fn reset(&mut self) {
        self.m.fill(f32::NEG_INFINITY);
        self.n.fill(0.0);
        self.o.fill(0.0);
    }

    /// Finalise: `o /= n` row-wise. Rows that saw no keys stay zero.
    pub fn finish(&mut self) {
        for (r, &n) in self.n.iter().enumerate() {
            if n > 0.0 {
                let inv = 1.0 / n;
                for x in &mut self.o[r * self.head_dim..(r + 1) * self.head_dim] {
                    *x *= inv;
                }
            }
        }
    }
}

/// Fused `partial_attn` + `attn_reduce` for a block of keys against a block
/// of query rows (Eqns. 1 and 2 merged).
///
/// * `q`       — `[rows, d]` f32 query rows (contiguous).
/// * `k`, `v`  — `[len, d]` key/value rows of one chunk/page/tile, at any
///   storage dtype (widened to f32 at load).
/// * `scale`   — `1/√d`.
/// * `state`   — per-row accumulators; updated in place.
/// * `w`       — scratch of at least `len` floats.
///
/// Numerics: the merged update is associative, so processing chunks in any
/// order yields the same result as the two-phase schedule.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn attend_block<E: KvElem>(
    q: &[f32],
    rows: usize,
    d: usize,
    k: &[E],
    v: &[E],
    len: usize,
    scale: f32,
    state: &mut OnlineState<'_>,
    w: &mut [f32],
) {
    debug_assert!(q.len() >= rows * d);
    debug_assert!(k.len() >= len * d && v.len() >= len * d);
    debug_assert!(w.len() >= len);
    debug_assert_eq!(state.head_dim, d);
    // Int8 storage must come through `attend_block_scaled` — the raw
    // quantized integers are meaningless without their group scales.
    debug_assert!(E::DTYPE != KvDtype::Int8, "int8 blocks require attend_block_scaled");
    let isa = simd::active();
    if isa.is_accelerated() {
        attend_block_widened::<E>(isa, q, rows, d, k, v, len, scale, state, w);
    } else {
        attend_block_scalar::<E>(q, rows, d, k, v, len, scale, state, w);
    }
}

/// [`attend_block`] with per-block dequantization scales for quantized
/// storage. `k_scale`/`v_scale` are the owning slab's group scales for this
/// K/V block (one group per head, so a `[len, d]` head-major block has a
/// single scale each); float dtypes pass 1.0 and take the unscaled path
/// unchanged.
///
/// The int8 path *always* pre-widens the block — `dst = (q as f32) ·
/// scale` via [`simd::widen_i8`] — and then runs the f32 bodies, on every
/// ISA including scalar. That makes the dequantization a single-rounding
/// elementwise map (exact int→f32 convert, one f32 multiply), identical at
/// any vector width, so the bit-identity policy holds for int8 exactly as
/// for f16/bf16: the scalar widen + scalar f32 kernel is the oracle, and
/// every accelerated path must reproduce it bit for bit.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn attend_block_scaled<E: KvElem>(
    q: &[f32],
    rows: usize,
    d: usize,
    k: &[E],
    k_scale: f32,
    v: &[E],
    v_scale: f32,
    len: usize,
    scale: f32,
    state: &mut OnlineState<'_>,
    w: &mut [f32],
) {
    if E::DTYPE == KvDtype::Int8 {
        debug_assert!(q.len() >= rows * d);
        debug_assert!(k.len() >= len * d && v.len() >= len * d);
        debug_assert!(w.len() >= len);
        debug_assert_eq!(state.head_dim, d);
        let kq = E::as_i8(&k[..len * d]).expect("int8 dtype exposes an i8 view");
        let vq = E::as_i8(&v[..len * d]).expect("int8 dtype exposes an i8 view");
        let isa = simd::active();
        with_wide_buf(2 * len * d, |buf| {
            let (kw, vw) = buf.split_at_mut(len * d);
            simd::widen_i8(isa, kq, k_scale, kw);
            simd::widen_i8(isa, vq, v_scale, vw);
            if isa.is_accelerated() {
                attend_block_f32(isa, q, rows, d, kw, vw, len, scale, state, w);
            } else {
                attend_block_scalar::<f32>(q, rows, d, kw, vw, len, scale, state, w);
            }
        });
        return;
    }
    debug_assert!(
        k_scale == 1.0 && v_scale == 1.0,
        "dequant scales only apply to int8 storage"
    );
    attend_block::<E>(q, rows, d, k, v, len, scale, state, w);
}

/// Generic scalar body — the bit-identity oracle every SIMD path must
/// reproduce exactly. Its reduction geometries (`dot_d`'s 8 lanes,
/// `dot_kv`'s 4 lanes, `fast_exp_block`'s sequential normaliser) are
/// contract, not implementation detail: `util/simd.rs` replicates them.
#[allow(clippy::too_many_arguments)]
#[inline]
fn attend_block_scalar<E: KvElem>(
    q: &[f32],
    rows: usize,
    d: usize,
    k: &[E],
    v: &[E],
    len: usize,
    scale: f32,
    state: &mut OnlineState<'_>,
    w: &mut [f32],
) {
    // Register-blocked fast path: 8 (then 4) query rows share each streamed
    // K/V row (§Perf: cuts K/V cache traffic 8× in the chunk-first phase —
    // the CPU analogue of the paper's query-matrix tensor-core batching).
    // Inner loops are monomorphized for d = 64 and d = 128, the shapes the
    // paper's models use, and per storage dtype.
    let mut r0 = 0;
    while rows - r0 >= 8 {
        attend_block_rows8(&q[r0 * d..], d, k, v, len, scale, state, r0, w);
        r0 += 8;
    }
    while rows - r0 >= 4 {
        attend_block_rows4(&q[r0 * d..], d, k, v, len, scale, state, r0, w);
        r0 += 4;
    }
    for r in r0..rows {
        let q_row = &q[r * d..(r + 1) * d];
        // W^{(C)} = Q_{r,:} · K^{(C)T}, scaled.
        let mut m_c = f32::NEG_INFINITY;
        for t in 0..len {
            let s = dot_kv(q_row, &k[t * d..(t + 1) * d]) * scale;
            w[t] = s;
            if s > m_c {
                m_c = s;
            }
        }
        // E^{(C)} and n^{(C)}.
        let mut n_c = 0.0f32;
        for t in 0..len {
            let e = fast_exp(w[t] - m_c);
            w[t] = e;
            n_c += e;
        }
        // attn_reduce (Eqn. 2): rescale accumulator and partial, then add.
        let m_old = state.m[r];
        let m_new = m_old.max(m_c);
        let x = (m_c - m_new).exp(); // scales the new partial
        let y = if m_old == f32::NEG_INFINITY { 0.0 } else { (m_old - m_new).exp() };
        let o_row = &mut state.o[r * d..(r + 1) * d];
        if y != 1.0 {
            for o in o_row.iter_mut() {
                *o *= y;
            }
        }
        // O += x * E^{(C)} V^{(C)}.
        for t in 0..len {
            let e = w[t] * x;
            if e != 0.0 {
                axpy_kv(e, &v[t * d..(t + 1) * d], o_row);
            }
        }
        state.n[r] = state.n[r] * y + n_c * x;
        state.m[r] = m_new;
    }
}

/// Max chunk length the register-blocked paths support on their stack
/// weight buffers (8 rows × 512 → 16 KiB, well within any thread stack).
const BLOCK_MAX_LEN: usize = 512;

/// Process 8 query rows (`base_row..base_row+8` of the state) against one
/// K/V block, streaming each K/V row once for all 8 queries. Dispatches to
/// a monomorphized body for the paper's head dims (64, 128) so the inner
/// dot/axpy loops are fully unrolled and vectorized; each body widens the
/// streamed storage elements to f32 registers.
#[allow(clippy::too_many_arguments)]
#[inline]
fn attend_block_rows8<E: KvElem>(
    q: &[f32], // 8 rows, [8, d]
    d: usize,
    k: &[E],
    v: &[E],
    len: usize,
    scale: f32,
    state: &mut OnlineState<'_>,
    base_row: usize,
    w_fallback: &mut [f32],
) {
    if len > BLOCK_MAX_LEN {
        // Rare (chunk sizes are small); fall back to the scalar path.
        for r in 0..8 {
            attend_block_scalar(
                &q[r * d..(r + 1) * d],
                1,
                d,
                k,
                v,
                len,
                scale,
                &mut OnlineState {
                    m: &mut state.m[base_row + r..base_row + r + 1],
                    n: &mut state.n[base_row + r..base_row + r + 1],
                    o: &mut state.o[(base_row + r) * d..(base_row + r + 1) * d],
                    head_dim: d,
                },
                w_fallback,
            );
        }
        return;
    }
    match d {
        64 => attend_block_rows8_body::<64, E>(q, d, k, v, len, scale, state, base_row),
        128 => attend_block_rows8_body::<128, E>(q, d, k, v, len, scale, state, base_row),
        _ => attend_block_rows8_body::<0, E>(q, d, k, v, len, scale, state, base_row),
    }
}

/// 8-row body. `DS` is the compile-time head dim (0 = dynamic); the
/// `if DS != 0` branches fold away per instantiation, so the d=64/d=128
/// versions run with constant trip counts everywhere. `E` is the storage
/// dtype; elements widen to f32 on load.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn attend_block_rows8_body<const DS: usize, E: KvElem>(
    q: &[f32],
    d: usize,
    k: &[E],
    v: &[E],
    len: usize,
    scale: f32,
    state: &mut OnlineState<'_>,
    base_row: usize,
) {
    let d = if DS != 0 { DS } else { d };
    let mut w = [0.0f32; 8 * BLOCK_MAX_LEN];
    let q_rows: [&[f32]; 8] = std::array::from_fn(|r| &q[r * d..(r + 1) * d]);
    let mut m_c = [f32::NEG_INFINITY; 8];
    // W = Q_{8,:} · K^{(C)T}: one pass over each K row feeds 8 dots.
    for t in 0..len {
        let k_t = &k[t * d..(t + 1) * d];
        for r in 0..8 {
            let s = dot_d::<DS, E>(q_rows[r], k_t) * scale;
            w[r * BLOCK_MAX_LEN + t] = s;
            if s > m_c[r] {
                m_c[r] = s;
            }
        }
    }
    // Batched exp + normaliser per row (one vectorizable pass per row).
    let mut n_c = [0.0f32; 8];
    for r in 0..8 {
        n_c[r] = fast_exp_block(&mut w[r * BLOCK_MAX_LEN..r * BLOCK_MAX_LEN + len], m_c[r]);
    }
    // attn_reduce rescale of the accumulators, then one V pass for 8 rows.
    let mut x_scale = [0.0f32; 8];
    for r in 0..8 {
        let row = base_row + r;
        let m_old = state.m[row];
        let m_new = m_old.max(m_c[r]);
        let x = (m_c[r] - m_new).exp();
        let y = if m_old == f32::NEG_INFINITY { 0.0 } else { (m_old - m_new).exp() };
        if y != 1.0 {
            for o in &mut state.o[row * d..(row + 1) * d] {
                *o *= y;
            }
        }
        state.n[row] = state.n[row] * y + n_c[r] * x;
        state.m[row] = m_new;
        x_scale[r] = x;
    }
    let o_base = base_row * d;
    let o8 = &mut state.o[o_base..o_base + 8 * d];
    for t in 0..len {
        let v_t = &v[t * d..(t + 1) * d];
        let mut e = [0.0f32; 8];
        for r in 0..8 {
            e[r] = w[r * BLOCK_MAX_LEN + t] * x_scale[r];
        }
        for i in 0..d {
            let vv = v_t[i].to_f32();
            o8[i] += e[0] * vv;
            o8[d + i] += e[1] * vv;
            o8[2 * d + i] += e[2] * vv;
            o8[3 * d + i] += e[3] * vv;
            o8[4 * d + i] += e[4] * vv;
            o8[5 * d + i] += e[5] * vv;
            o8[6 * d + i] += e[6] * vv;
            o8[7 * d + i] += e[7] * vv;
        }
    }
}

/// Dot product with a compile-time length (`DS == 0` falls back to the
/// dynamic [`dot_kv`]). The fixed-size version slices both operands to `DS`
/// so LLVM drops every bounds check and fully vectorizes — including the
/// widening load of half-precision K elements.
#[inline(always)]
fn dot_d<const DS: usize, E: KvElem>(a: &[f32], b: &[E]) -> f32 {
    if DS == 0 {
        return dot_kv(a, b);
    }
    let a = &a[..DS];
    let b = &b[..DS];
    let mut lanes = [0.0f32; 8];
    let mut i = 0;
    while i + 8 <= DS {
        for l in 0..8 {
            lanes[l] += a[i + l] * b[i + l].to_f32();
        }
        i += 8;
    }
    let mut s = 0.0;
    for l in lanes {
        s += l;
    }
    while i < DS {
        s += a[i] * b[i].to_f32();
        i += 1;
    }
    s
}

/// Process 4 query rows (`base_row..base_row+4` of the state) against one
/// K/V block, streaming each K/V row once for all 4 queries.
#[allow(clippy::too_many_arguments)]
#[inline]
fn attend_block_rows4<E: KvElem>(
    q: &[f32], // 4 rows, [4, d]
    d: usize,
    k: &[E],
    v: &[E],
    len: usize,
    scale: f32,
    state: &mut OnlineState<'_>,
    base_row: usize,
    w_fallback: &mut [f32],
) {
    if len > BLOCK_MAX_LEN {
        // Rare (chunk sizes are small); fall back to the scalar path.
        for r in 0..4 {
            attend_block_scalar(
                &q[r * d..(r + 1) * d],
                1,
                d,
                k,
                v,
                len,
                scale,
                &mut OnlineState {
                    m: &mut state.m[base_row + r..base_row + r + 1],
                    n: &mut state.n[base_row + r..base_row + r + 1],
                    o: &mut state.o[(base_row + r) * d..(base_row + r + 1) * d],
                    head_dim: d,
                },
                w_fallback,
            );
        }
        return;
    }
    let mut w = [0.0f32; 4 * BLOCK_MAX_LEN];
    let (q0, q1, q2, q3) = (&q[0..d], &q[d..2 * d], &q[2 * d..3 * d], &q[3 * d..4 * d]);
    let mut m_c = [f32::NEG_INFINITY; 4];
    for t in 0..len {
        let k_t = &k[t * d..(t + 1) * d];
        // One pass over k_t feeds all four dot products.
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for i in 0..d {
            let kv = k_t[i].to_f32();
            s0 += q0[i] * kv;
            s1 += q1[i] * kv;
            s2 += q2[i] * kv;
            s3 += q3[i] * kv;
        }
        let s = [s0 * scale, s1 * scale, s2 * scale, s3 * scale];
        for r in 0..4 {
            w[r * BLOCK_MAX_LEN + t] = s[r];
            if s[r] > m_c[r] {
                m_c[r] = s[r];
            }
        }
    }
    // Batched exp + normaliser per row.
    let mut n_c = [0.0f32; 4];
    for r in 0..4 {
        n_c[r] = fast_exp_block(&mut w[r * BLOCK_MAX_LEN..r * BLOCK_MAX_LEN + len], m_c[r]);
    }
    // attn_reduce rescale of the accumulators, then one V pass for 4 rows.
    let mut x_scale = [0.0f32; 4];
    for r in 0..4 {
        let row = base_row + r;
        let m_old = state.m[row];
        let m_new = m_old.max(m_c[r]);
        let x = (m_c[r] - m_new).exp();
        let y = if m_old == f32::NEG_INFINITY { 0.0 } else { (m_old - m_new).exp() };
        if y != 1.0 {
            for o in &mut state.o[row * d..(row + 1) * d] {
                *o *= y;
            }
        }
        state.n[row] = state.n[row] * y + n_c[r] * x;
        state.m[row] = m_new;
        x_scale[r] = x;
    }
    let o_base = base_row * d;
    let o4 = &mut state.o[o_base..o_base + 4 * d];
    for t in 0..len {
        let v_t = &v[t * d..(t + 1) * d];
        let e = [
            w[t] * x_scale[0],
            w[BLOCK_MAX_LEN + t] * x_scale[1],
            w[2 * BLOCK_MAX_LEN + t] * x_scale[2],
            w[3 * BLOCK_MAX_LEN + t] * x_scale[3],
        ];
        for i in 0..d {
            let vv = v_t[i].to_f32();
            o4[i] += e[0] * vv;
            o4[d + i] += e[1] * vv;
            o4[2 * d + i] += e[2] * vv;
            o4[3 * d + i] += e[3] * vv;
        }
    }
}

// ---------------------------------------------------------------------------
// Explicit-SIMD path. The storage block is widened to f32 once (exact, so
// the seam relocation cannot change results — see the
// `simd_paths_match_scalar_bitwise` test below) and an f32 body
// mirroring the scalar structure runs on vector primitives that replicate
// the scalar reduction geometries bit for bit.
// ---------------------------------------------------------------------------

thread_local! {
    /// Per-thread f32 scratch for the widened K/V block (grown on demand,
    /// reused across decode steps — same idiom as chunk_tpp's weight
    /// buffers, so the steady state allocates nothing).
    static WIDE_BUF: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

fn with_wide_buf<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    WIDE_BUF.with(|buf| {
        let mut buf = buf.borrow_mut();
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        f(&mut buf[..len])
    })
}

/// Entry for accelerated ISAs: obtain an f32 view of the K/V block (free
/// for f32 storage, one vectorized widening pass for f16/bf16) and run the
/// explicit-SIMD f32 body.
#[allow(clippy::too_many_arguments)]
fn attend_block_widened<E: KvElem>(
    isa: simd::SimdIsa,
    q: &[f32],
    rows: usize,
    d: usize,
    k: &[E],
    v: &[E],
    len: usize,
    scale: f32,
    state: &mut OnlineState<'_>,
    w: &mut [f32],
) {
    let k = &k[..len * d];
    let v = &v[..len * d];
    if let (Some(kf), Some(vf)) = (E::as_f32(k), E::as_f32(v)) {
        attend_block_f32(isa, q, rows, d, kf, vf, len, scale, state, w);
        return;
    }
    with_wide_buf(2 * len * d, |buf| {
        let (kw, vw) = buf.split_at_mut(len * d);
        E::widen_into(k, kw);
        E::widen_into(v, vw);
        attend_block_f32(isa, q, rows, d, kw, vw, len, scale, state, w);
    });
}

/// f32 body of the SIMD path: same row-blocking structure as
/// [`attend_block_scalar`], with the hot loops routed through the
/// `util/simd.rs` primitives.
#[allow(clippy::too_many_arguments)]
fn attend_block_f32(
    isa: simd::SimdIsa,
    q: &[f32],
    rows: usize,
    d: usize,
    k: &[f32],
    v: &[f32],
    len: usize,
    scale: f32,
    state: &mut OnlineState<'_>,
    w: &mut [f32],
) {
    let mut r0 = 0;
    while rows - r0 >= 8 {
        rows8_f32(isa, &q[r0 * d..], d, k, v, len, scale, state, r0, w);
        r0 += 8;
    }
    while rows - r0 >= 4 {
        rows4_f32(isa, &q[r0 * d..], d, k, v, len, scale, state, r0, w);
        r0 += 4;
    }
    for r in r0..rows {
        let q_row = &q[r * d..(r + 1) * d];
        let mut m_c = f32::NEG_INFINITY;
        for t in 0..len {
            let s = simd::dot_kv_f32(isa, q_row, &k[t * d..(t + 1) * d]) * scale;
            w[t] = s;
            if s > m_c {
                m_c = s;
            }
        }
        // fast_exp (cutoff) semantics, matching the scalar tail loop.
        let n_c = simd::exp_block_cutoff(isa, &mut w[..len], m_c);
        let m_old = state.m[r];
        let m_new = m_old.max(m_c);
        let x = (m_c - m_new).exp();
        let y = if m_old == f32::NEG_INFINITY { 0.0 } else { (m_old - m_new).exp() };
        let o_row = &mut state.o[r * d..(r + 1) * d];
        if y != 1.0 {
            for o in o_row.iter_mut() {
                *o *= y;
            }
        }
        for t in 0..len {
            let e = w[t] * x;
            if e != 0.0 {
                simd::axpy_f32(isa, e, &v[t * d..(t + 1) * d], o_row);
            }
        }
        state.n[r] = state.n[r] * y + n_c * x;
        state.m[r] = m_new;
    }
}

/// 8-row SIMD body: [`simd::qk_dots8`] keeps the shared K row in registers
/// across all 8 query dots, [`simd::exp_block`] vectorizes the softmax
/// transform (the ordered scalar normaliser sum stays sequential), and
/// [`simd::axpy_rows8`] runs the V accumulation at full vector width.
#[allow(clippy::too_many_arguments)]
fn rows8_f32(
    isa: simd::SimdIsa,
    q: &[f32], // 8 rows, [8, d]
    d: usize,
    k: &[f32],
    v: &[f32],
    len: usize,
    scale: f32,
    state: &mut OnlineState<'_>,
    base_row: usize,
    w_fallback: &mut [f32],
) {
    if len > BLOCK_MAX_LEN {
        for r in 0..8 {
            attend_block_f32(
                isa,
                &q[r * d..(r + 1) * d],
                1,
                d,
                k,
                v,
                len,
                scale,
                &mut OnlineState {
                    m: &mut state.m[base_row + r..base_row + r + 1],
                    n: &mut state.n[base_row + r..base_row + r + 1],
                    o: &mut state.o[(base_row + r) * d..(base_row + r + 1) * d],
                    head_dim: d,
                },
                w_fallback,
            );
        }
        return;
    }
    let mut w = [0.0f32; 8 * BLOCK_MAX_LEN];
    let mut m_c = [f32::NEG_INFINITY; 8];
    for t in 0..len {
        let k_t = &k[t * d..(t + 1) * d];
        let mut s8 = [0.0f32; 8];
        simd::qk_dots8(isa, q, d, k_t, &mut s8);
        for (r, &s_raw) in s8.iter().enumerate() {
            let s = s_raw * scale;
            w[r * BLOCK_MAX_LEN + t] = s;
            if s > m_c[r] {
                m_c[r] = s;
            }
        }
    }
    let mut n_c = [0.0f32; 8];
    for r in 0..8 {
        n_c[r] = simd::exp_block(isa, &mut w[r * BLOCK_MAX_LEN..r * BLOCK_MAX_LEN + len], m_c[r]);
    }
    let mut x_scale = [0.0f32; 8];
    for r in 0..8 {
        let row = base_row + r;
        let m_old = state.m[row];
        let m_new = m_old.max(m_c[r]);
        let x = (m_c[r] - m_new).exp();
        let y = if m_old == f32::NEG_INFINITY { 0.0 } else { (m_old - m_new).exp() };
        if y != 1.0 {
            for o in &mut state.o[row * d..(row + 1) * d] {
                *o *= y;
            }
        }
        state.n[row] = state.n[row] * y + n_c[r] * x;
        state.m[row] = m_new;
        x_scale[r] = x;
    }
    let o_base = base_row * d;
    let o8 = &mut state.o[o_base..o_base + 8 * d];
    for t in 0..len {
        let v_t = &v[t * d..(t + 1) * d];
        let mut e = [0.0f32; 8];
        for r in 0..8 {
            e[r] = w[r * BLOCK_MAX_LEN + t] * x_scale[r];
        }
        // Row-major vs the scalar body's element-interleaved order: every
        // (row, element) update is independent, so this is bit-identical.
        simd::axpy_rows8(isa, &e, v_t, d, o8);
    }
}

/// 4-row SIMD body. The fused 4-row dots stay scalar on the widened f32
/// data (their fully sequential accumulation is the contract the scalar
/// body fixes); exp and the V pass use the vector primitives.
#[allow(clippy::too_many_arguments)]
fn rows4_f32(
    isa: simd::SimdIsa,
    q: &[f32], // 4 rows, [4, d]
    d: usize,
    k: &[f32],
    v: &[f32],
    len: usize,
    scale: f32,
    state: &mut OnlineState<'_>,
    base_row: usize,
    w_fallback: &mut [f32],
) {
    if len > BLOCK_MAX_LEN {
        for r in 0..4 {
            attend_block_f32(
                isa,
                &q[r * d..(r + 1) * d],
                1,
                d,
                k,
                v,
                len,
                scale,
                &mut OnlineState {
                    m: &mut state.m[base_row + r..base_row + r + 1],
                    n: &mut state.n[base_row + r..base_row + r + 1],
                    o: &mut state.o[(base_row + r) * d..(base_row + r + 1) * d],
                    head_dim: d,
                },
                w_fallback,
            );
        }
        return;
    }
    let mut w = [0.0f32; 4 * BLOCK_MAX_LEN];
    let (q0, q1, q2, q3) = (&q[0..d], &q[d..2 * d], &q[2 * d..3 * d], &q[3 * d..4 * d]);
    let mut m_c = [f32::NEG_INFINITY; 4];
    for t in 0..len {
        let k_t = &k[t * d..(t + 1) * d];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for i in 0..d {
            let kv = k_t[i];
            s0 += q0[i] * kv;
            s1 += q1[i] * kv;
            s2 += q2[i] * kv;
            s3 += q3[i] * kv;
        }
        let s = [s0 * scale, s1 * scale, s2 * scale, s3 * scale];
        for r in 0..4 {
            w[r * BLOCK_MAX_LEN + t] = s[r];
            if s[r] > m_c[r] {
                m_c[r] = s[r];
            }
        }
    }
    let mut n_c = [0.0f32; 4];
    for r in 0..4 {
        n_c[r] = simd::exp_block(isa, &mut w[r * BLOCK_MAX_LEN..r * BLOCK_MAX_LEN + len], m_c[r]);
    }
    let mut x_scale = [0.0f32; 4];
    for r in 0..4 {
        let row = base_row + r;
        let m_old = state.m[row];
        let m_new = m_old.max(m_c[r]);
        let x = (m_c[r] - m_new).exp();
        let y = if m_old == f32::NEG_INFINITY { 0.0 } else { (m_old - m_new).exp() };
        if y != 1.0 {
            for o in &mut state.o[row * d..(row + 1) * d] {
                *o *= y;
            }
        }
        state.n[row] = state.n[row] * y + n_c[r] * x;
        state.m[row] = m_new;
        x_scale[r] = x;
    }
    let o_base = base_row * d;
    let o4 = &mut state.o[o_base..o_base + 4 * d];
    for t in 0..len {
        let v_t = &v[t * d..(t + 1) * d];
        let e = [
            w[t] * x_scale[0],
            w[BLOCK_MAX_LEN + t] * x_scale[1],
            w[2 * BLOCK_MAX_LEN + t] * x_scale[2],
            w[3 * BLOCK_MAX_LEN + t] * x_scale[3],
        ];
        simd::axpy_rows4(isa, &e, v_t, d, o4);
    }
}

/// `attn_reduce` (Eqn. 2) over saved partials: fold one partial
/// `(m_c, n_c, o_c)` into the running accumulator `(m, n, o)`. `o` and
/// `o_c` are *unnormalised* (divide by `n` once at the end). Shared by the
/// buffered and 2D-scheduled kernels so the reduce numerics live in one
/// place. Partials are always f32 regardless of the storage dtype.
#[inline]
pub fn attn_reduce(m: &mut f32, n: &mut f32, o: &mut [f32], m_c: f32, n_c: f32, o_c: &[f32]) {
    debug_assert_eq!(o.len(), o_c.len());
    let m_new = (*m).max(m_c);
    let x = (m_c - m_new).exp();
    let y = if *m == f32::NEG_INFINITY { 0.0 } else { (*m - m_new).exp() };
    for (oi, &ci) in o.iter_mut().zip(o_c) {
        *oi = *oi * y + ci * x;
    }
    *n = *n * y + n_c * x;
    *m = m_new;
}

/// Merge a fresh single key/value row (the token being decoded) into the
/// accumulator — used by the L2 model path where the current token's K/V is
/// produced in the same step (as f32) and is not yet in the cache.
#[inline]
pub fn attend_fresh_row(
    q_row: &[f32],
    k_row: &[f32],
    v_row: &[f32],
    scale: f32,
    m: &mut f32,
    n: &mut f32,
    o_row: &mut [f32],
) {
    let d = q_row.len();
    let s = dot(q_row, k_row) * scale;
    let m_new = m.max(s);
    let x = (s - m_new).exp();
    let y = if *m == f32::NEG_INFINITY { 0.0 } else { (*m - m_new).exp() };
    if y != 1.0 {
        for v in o_row.iter_mut() {
            *v *= y;
        }
    }
    axpy(x, &v_row[..d], o_row);
    *n = *n * y + x;
    *m = m_new;
}

/// Fast exp: 2^k · poly(r) decomposition (Cephes-style), ~2e-7 relative
/// error over the softmax-relevant range. `exp()` dominated kernel profiles
/// (§Perf iteration 3): one libm call per (row, token) — this inlines and
/// vectorises instead.
#[inline]
pub fn fast_exp(x: f32) -> f32 {
    const LOG2E: f32 = std::f32::consts::LOG2_E;
    const LN2_HI: f32 = 0.693_359_4;
    const LN2_LO: f32 = -2.121_944_4e-4;
    // Softmax arguments are ≤ 0 after max-subtraction; anything below -87
    // underflows to 0 in f32 anyway.
    if x < -87.0 {
        return 0.0;
    }
    if x > 88.0 {
        return f32::INFINITY;
    }
    let k = (x * LOG2E).round();
    let r = x - k * LN2_HI - k * LN2_LO;
    // 5th-order minimax polynomial for e^r on [-ln2/2, ln2/2].
    let p = 1.0
        + r * (1.0
            + r * (0.5
                + r * (0.166_666_55 + r * (0.041_665_795 + r * (0.008_333_452 + r * 0.001_388_89)))));
    // Scale by 2^k via exponent bits.
    let bits = ((k as i32 + 127) as u32) << 23;
    p * f32::from_bits(bits)
}

/// Batched softmax-exp over a weight buffer: `w[i] = e^(w[i] - shift)`,
/// returning the sum. `shift` is the running row max, so every argument is
/// ≤ 0 — the overflow branch of [`fast_exp`] is unnecessary and the
/// underflow test is a branchless clamp, which lets LLVM vectorise the
/// whole pass (one `exp` per (row, token) dominated kernel profiles).
#[inline]
pub fn fast_exp_block(w: &mut [f32], shift: f32) -> f32 {
    const LOG2E: f32 = std::f32::consts::LOG2_E;
    const LN2_HI: f32 = 0.693_359_4;
    const LN2_LO: f32 = -2.121_944_4e-4;
    let mut acc = 0.0f32;
    for x in w.iter_mut() {
        // Clamp instead of early-return: e^-87 ≈ 1.6e-38 vanishes against
        // the row sum, and a branch-free body keeps the loop vector-wide.
        let a = (*x - shift).max(-87.0);
        let k = (a * LOG2E).round();
        let r = a - k * LN2_HI - k * LN2_LO;
        let p = 1.0
            + r * (1.0
                + r * (0.5
                    + r * (0.166_666_55
                        + r * (0.041_665_795 + r * (0.008_333_452 + r * 0.001_388_89)))));
        let bits = ((k as i32 + 127) as u32) << 23;
        let e = p * f32::from_bits(bits);
        *x = e;
        acc += e;
    }
    acc
}

/// Dense dot product against a stored K row at any dtype, 4-way unrolled
/// so LLVM vectorises it (the widening load folds into the lane ops).
#[inline]
pub fn dot_kv<E: KvElem>(a: &[f32], b: &[E]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j].to_f32();
        s1 += a[j + 1] * b[j + 1].to_f32();
        s2 += a[j + 2] * b[j + 2].to_f32();
        s3 += a[j + 3] * b[j + 3].to_f32();
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i].to_f32();
    }
    s
}

/// Dense f32 dot product (specialisation of [`dot_kv`] kept for callers
/// with freshly produced f32 rows).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_kv(a, b)
}

/// `y += alpha * x` with `x` stored at any dtype, unrolled.
#[inline]
pub fn axpy_kv<E: KvElem>(alpha: f32, x: &[E], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi.to_f32();
    }
}

/// `y += alpha * x` for f32 rows.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    axpy_kv(alpha, x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{quantize_i8, Bf16, F16, I8};

    fn softmax_attn_ref(q: &[f32], k: &[f32], v: &[f32], len: usize, d: usize) -> Vec<f32> {
        // f64 dense reference for one row.
        let scale = 1.0 / (d as f64).sqrt();
        let w: Vec<f64> = (0..len)
            .map(|t| {
                (0..d).map(|i| q[i] as f64 * k[t * d + i] as f64).sum::<f64>() * scale
            })
            .collect();
        let m = w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let e: Vec<f64> = w.iter().map(|x| (x - m).exp()).collect();
        let n: f64 = e.iter().sum();
        (0..d)
            .map(|i| (0..len).map(|t| e[t] * v[t * d + i] as f64).sum::<f64>() / n)
            .map(|x| x as f32)
            .collect()
    }

    fn rand_vec(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        let mut v = vec![0.0; n];
        rng.fill_uniform_f32(&mut v, -2.0, 2.0);
        v
    }

    #[test]
    fn single_block_equals_dense_softmax() {
        let (d, len) = (8, 16);
        let q = rand_vec(1, d);
        let k = rand_vec(2, len * d);
        let v = rand_vec(3, len * d);
        let scale = 1.0 / (d as f32).sqrt();
        let (mut m, mut n, mut o) = (vec![0.0f32; 1], vec![0.0f32; 1], vec![0.0f32; d]);
        let mut state = OnlineState { m: &mut m, n: &mut n, o: &mut o, head_dim: d };
        state.reset();
        let mut w = vec![0.0f32; len];
        attend_block(&q, 1, d, &k, &v, len, scale, &mut state, &mut w);
        state.finish();
        let expect = softmax_attn_ref(&q, &k, &v, len, d);
        for (g, e) in o.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-5, "{g} vs {e}");
        }
    }

    #[test]
    fn split_blocks_match_single_block() {
        // Associativity: processing [0..6) then [6..16) == one pass.
        let (d, len) = (4, 16);
        let q = rand_vec(4, d);
        let k = rand_vec(5, len * d);
        let v = rand_vec(6, len * d);
        let scale = 1.0 / (d as f32).sqrt();
        let run = |splits: &[usize]| {
            let (mut m, mut n, mut o) = (vec![0.0f32; 1], vec![0.0f32; 1], vec![0.0f32; d]);
            let mut state = OnlineState { m: &mut m, n: &mut n, o: &mut o, head_dim: d };
            state.reset();
            let mut w = vec![0.0f32; len];
            let mut start = 0;
            for &end in splits {
                attend_block(
                    &q,
                    1,
                    d,
                    &k[start * d..end * d],
                    &v[start * d..end * d],
                    end - start,
                    scale,
                    &mut state,
                    &mut w,
                );
                start = end;
            }
            state.finish();
            o
        };
        let whole = run(&[16]);
        let pieces = run(&[6, 16]);
        let many = run(&[1, 2, 5, 9, 16]);
        for i in 0..d {
            assert!((whole[i] - pieces[i]).abs() < 1e-5);
            assert!((whole[i] - many[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn block_order_is_irrelevant() {
        let (d, len) = (4, 8);
        let q = rand_vec(7, d);
        let k = rand_vec(8, len * d);
        let v = rand_vec(9, len * d);
        let scale = 1.0 / (d as f32).sqrt();
        let run = |order: &[(usize, usize)]| {
            let (mut m, mut n, mut o) = (vec![0.0f32; 1], vec![0.0f32; 1], vec![0.0f32; d]);
            let mut state = OnlineState { m: &mut m, n: &mut n, o: &mut o, head_dim: d };
            state.reset();
            let mut w = vec![0.0f32; len];
            for &(s, e) in order {
                attend_block(&q, 1, d, &k[s * d..e * d], &v[s * d..e * d], e - s, scale, &mut state, &mut w);
            }
            state.finish();
            o
        };
        let fwd = run(&[(0, 4), (4, 8)]);
        let rev = run(&[(4, 8), (0, 4)]);
        for i in 0..d {
            assert!((fwd[i] - rev[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn multi_row_block_matches_per_row() {
        let (d, len, rows) = (8, 8, 3);
        let q = rand_vec(10, rows * d);
        let k = rand_vec(11, len * d);
        let v = rand_vec(12, len * d);
        let scale = 1.0 / (d as f32).sqrt();
        let (mut m, mut n, mut o) = (vec![0.0f32; rows], vec![0.0f32; rows], vec![0.0f32; rows * d]);
        let mut state = OnlineState { m: &mut m, n: &mut n, o: &mut o, head_dim: d };
        state.reset();
        let mut w = vec![0.0f32; len];
        attend_block(&q, rows, d, &k, &v, len, scale, &mut state, &mut w);
        state.finish();
        for r in 0..rows {
            let expect = softmax_attn_ref(&q[r * d..(r + 1) * d], &k, &v, len, d);
            for i in 0..d {
                assert!((o[r * d + i] - expect[i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn fresh_row_merge_equals_inclusion() {
        // Attending chunk + fresh row == attending (chunk ∪ row) at once.
        let (d, len) = (4, 5);
        let q = rand_vec(13, d);
        let k = rand_vec(14, (len + 1) * d);
        let v = rand_vec(15, (len + 1) * d);
        let scale = 1.0 / (d as f32).sqrt();

        let expect = softmax_attn_ref(&q, &k, &v, len + 1, d);

        let (mut m, mut n, mut o) = (vec![0.0f32; 1], vec![0.0f32; 1], vec![0.0f32; d]);
        let mut state = OnlineState { m: &mut m, n: &mut n, o: &mut o, head_dim: d };
        state.reset();
        let mut w = vec![0.0f32; len];
        attend_block(&q, 1, d, &k[..len * d], &v[..len * d], len, scale, &mut state, &mut w);
        attend_fresh_row(
            &q,
            &k[len * d..],
            &v[len * d..],
            scale,
            &mut state.m[0],
            &mut state.n[0],
            &mut state.o[..d],
        );
        state.finish();
        for i in 0..d {
            assert!((o[i] - expect[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn blocked_rows_match_per_row_all_widths() {
        // Exercise the 8-row, 4-row and scalar tails together (rows = 21 →
        // two 8-blocks, one 4-block, one scalar row) at the monomorphized
        // head dims (64, 128) and a dynamic one (24).
        for &d in &[24usize, 64, 128] {
            let (len, rows) = (40, 21);
            let q = rand_vec(100 + d as u64, rows * d);
            let k = rand_vec(200 + d as u64, len * d);
            let v = rand_vec(300 + d as u64, len * d);
            let scale = 1.0 / (d as f32).sqrt();
            let (mut m, mut n, mut o) =
                (vec![0.0f32; rows], vec![0.0f32; rows], vec![0.0f32; rows * d]);
            let mut state = OnlineState { m: &mut m, n: &mut n, o: &mut o, head_dim: d };
            state.reset();
            let mut w = vec![0.0f32; len];
            attend_block(&q, rows, d, &k, &v, len, scale, &mut state, &mut w);
            state.finish();
            for r in 0..rows {
                let expect = softmax_attn_ref(&q[r * d..(r + 1) * d], &k, &v, len, d);
                for i in 0..d {
                    let got = o[r * d + i];
                    assert!(
                        (got - expect[i]).abs() < 2e-5 * (1.0 + expect[i].abs()),
                        "d {d} row {r} i {i}: {got} vs {}",
                        expect[i]
                    );
                }
            }
        }
    }

    /// The half-precision kernels must equal the f32 kernel run on the
    /// widened values: quantisation happens at the load seam only, every
    /// downstream operation is the same f32 arithmetic.
    #[test]
    fn half_precision_blocks_equal_f32_on_widened_values() {
        for &d in &[24usize, 64, 128] {
            let (len, rows) = (40, 21);
            let q = rand_vec(400 + d as u64, rows * d);
            let k = rand_vec(500 + d as u64, len * d);
            let v = rand_vec(600 + d as u64, len * d);
            let scale = 1.0 / (d as f32).sqrt();

            let k16: Vec<F16> = k.iter().map(|&x| F16::from_f32(x)).collect();
            let v16: Vec<F16> = v.iter().map(|&x| F16::from_f32(x)).collect();
            let kb: Vec<Bf16> = k.iter().map(|&x| Bf16::from_f32(x)).collect();
            let vb: Vec<Bf16> = v.iter().map(|&x| Bf16::from_f32(x)).collect();

            let run_f32 = |kw: Vec<f32>, vw: Vec<f32>| {
                let (mut m, mut n, mut o) =
                    (vec![0.0f32; rows], vec![0.0f32; rows], vec![0.0f32; rows * d]);
                let mut state = OnlineState { m: &mut m, n: &mut n, o: &mut o, head_dim: d };
                state.reset();
                let mut w = vec![0.0f32; len];
                attend_block(&q, rows, d, &kw, &vw, len, scale, &mut state, &mut w);
                state.finish();
                o
            };

            // f16 path vs f32 on the widened f16 values: bit-identical.
            let widened_k: Vec<f32> = k16.iter().map(|x| x.to_f32()).collect();
            let widened_v: Vec<f32> = v16.iter().map(|x| x.to_f32()).collect();
            let expect16 = run_f32(widened_k, widened_v);
            let (mut m, mut n, mut o) =
                (vec![0.0f32; rows], vec![0.0f32; rows], vec![0.0f32; rows * d]);
            let mut state = OnlineState { m: &mut m, n: &mut n, o: &mut o, head_dim: d };
            state.reset();
            let mut w = vec![0.0f32; len];
            attend_block(&q, rows, d, &k16, &v16, len, scale, &mut state, &mut w);
            state.finish();
            assert_eq!(o, expect16, "f16 kernel d={d} must match widened-f32 kernel exactly");

            // Same for bf16.
            let widened_k: Vec<f32> = kb.iter().map(|x| x.to_f32()).collect();
            let widened_v: Vec<f32> = vb.iter().map(|x| x.to_f32()).collect();
            let expect_b = run_f32(widened_k, widened_v);
            let (mut m, mut n, mut o) =
                (vec![0.0f32; rows], vec![0.0f32; rows], vec![0.0f32; rows * d]);
            let mut state = OnlineState { m: &mut m, n: &mut n, o: &mut o, head_dim: d };
            state.reset();
            attend_block(&q, rows, d, &kb, &vb, len, scale, &mut state, &mut w);
            state.finish();
            assert_eq!(o, expect_b, "bf16 kernel d={d} must match widened-f32 kernel exactly");
        }
    }

    fn quantize_block(x: &[f32]) -> (Vec<I8>, f32) {
        let max_abs = x.iter().fold(0f32, |m, v| m.max(v.abs()));
        let scale = max_abs / 127.0;
        (x.iter().map(|&v| I8(quantize_i8(v, scale))).collect(), scale)
    }

    /// The int8 kernel must equal the f32 kernel run on the dequantized
    /// values exactly: dequantization happens once at the load seam
    /// (`widen_i8` — exact convert + one multiply), then the arithmetic is
    /// identical — the int8 analogue of the half-precision contract above.
    #[test]
    fn int8_blocks_equal_f32_on_dequantized_values() {
        for &d in &[24usize, 64, 128] {
            let (len, rows) = (40, 21);
            let q = rand_vec(420 + d as u64, rows * d);
            let k = rand_vec(520 + d as u64, len * d);
            let v = rand_vec(620 + d as u64, len * d);
            let scale = 1.0 / (d as f32).sqrt();

            let (kq, k_scale) = quantize_block(&k);
            let (vq, v_scale) = quantize_block(&v);
            let deq_k: Vec<f32> = kq.iter().map(|x| x.0 as f32 * k_scale).collect();
            let deq_v: Vec<f32> = vq.iter().map(|x| x.0 as f32 * v_scale).collect();

            let (mut m, mut n, mut o) =
                (vec![0.0f32; rows], vec![0.0f32; rows], vec![0.0f32; rows * d]);
            let mut state = OnlineState { m: &mut m, n: &mut n, o: &mut o, head_dim: d };
            state.reset();
            let mut w = vec![0.0f32; len];
            attend_block(&q, rows, d, &deq_k, &deq_v, len, scale, &mut state, &mut w);
            state.finish();
            let expect = o.clone();

            let (mut m, mut n, mut o) =
                (vec![0.0f32; rows], vec![0.0f32; rows], vec![0.0f32; rows * d]);
            let mut state = OnlineState { m: &mut m, n: &mut n, o: &mut o, head_dim: d };
            state.reset();
            attend_block_scaled(
                &q, rows, d, &kq, k_scale, &vq, v_scale, len, scale, &mut state, &mut w,
            );
            state.finish();
            assert_eq!(o, expect, "int8 kernel d={d} must match dequantized-f32 kernel exactly");
        }
    }

    /// Every available ISA reproduces the scalar int8 path (scalar widen +
    /// scalar f32 kernel) bit for bit — the int8 leg of
    /// `simd_paths_match_scalar_bitwise`.
    #[test]
    fn int8_simd_paths_match_scalar_bitwise() {
        use crate::util::simd;
        let _serial = simd::force_lock();

        fn run(
            q: &[f32],
            rows: usize,
            d: usize,
            k: &[I8],
            ks: f32,
            v: &[I8],
            vs: f32,
            len: usize,
        ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let scale = 1.0 / (d as f32).sqrt();
            let (mut m, mut n, mut o) =
                (vec![0.0f32; rows], vec![0.0f32; rows], vec![0.0f32; rows * d]);
            let mut state = OnlineState { m: &mut m, n: &mut n, o: &mut o, head_dim: d };
            state.reset();
            let mut w = vec![0.0f32; len];
            attend_block_scaled(q, rows, d, k, ks, v, vs, len, scale, &mut state, &mut w);
            state.finish();
            (m, n, o)
        }

        for &(d, len, rows) in
            &[(24usize, 43usize, 21usize), (64, 43, 21), (128, 43, 9), (24, 600, 13)]
        {
            let q = rand_vec(710 + d as u64 + len as u64, rows * d);
            let k = rand_vec(810 + d as u64 + len as u64, len * d);
            let v = rand_vec(910 + d as u64 + len as u64, len * d);
            let (kq, ks) = quantize_block(&k);
            let (vq, vs) = quantize_block(&v);

            simd::force(Some(simd::SimdIsa::Scalar));
            let base = run(&q, rows, d, &kq, ks, &vq, vs, len);
            for isa in simd::available() {
                simd::force(Some(isa));
                assert_eq!(
                    run(&q, rows, d, &kq, ks, &vq, vs, len),
                    base,
                    "{} int8 d={d} len={len}",
                    isa.label()
                );
            }
            simd::force(None);
        }
    }

    #[test]
    fn fast_exp_block_matches_elementwise() {
        let mut w = rand_vec(77, 63);
        let shift = w.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let expect: Vec<f32> = w.iter().map(|&x| fast_exp(x - shift)).collect();
        let expect_sum: f32 = expect.iter().sum();
        let sum = fast_exp_block(&mut w, shift);
        for (g, e) in w.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-6, "{g} vs {e}");
        }
        assert!((sum - expect_sum).abs() < 1e-4 * (1.0 + expect_sum.abs()));
    }

    #[test]
    fn fast_exp_block_deep_negative_underflows_to_zeroish() {
        let mut w = vec![-500.0f32, 0.0];
        let sum = fast_exp_block(&mut w, 0.0);
        assert!(w[0] < 1e-30, "deeply negative arg ~0, got {}", w[0]);
        assert!((w[1] - 1.0).abs() < 1e-6);
        assert!((sum - 1.0).abs() < 1e-6);
    }

    #[test]
    fn extreme_logits_stay_finite() {
        let d = 4;
        let q = vec![100.0f32; d];
        let k = vec![100.0f32; 2 * d];
        let v = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let scale = 1.0;
        let (mut m, mut n, mut o) = (vec![0.0f32; 1], vec![0.0f32; 1], vec![0.0f32; d]);
        let mut state = OnlineState { m: &mut m, n: &mut n, o: &mut o, head_dim: d };
        state.reset();
        let mut w = vec![0.0f32; 2];
        attend_block(&q, 1, d, &k, &v, 2, scale, &mut state, &mut w);
        state.finish();
        assert!(o.iter().all(|x| x.is_finite()));
        // Equal logits → average of the two value rows.
        assert!((o[0] - 3.0).abs() < 1e-4);
    }

    /// The core tentpole invariant: every available ISA path produces the
    /// scalar kernel's output bit for bit — (m, n, o) all of them — for
    /// every storage dtype, across the 8-row/4-row/tail blocking and the
    /// long-block fallback.
    #[test]
    fn simd_paths_match_scalar_bitwise() {
        use crate::util::simd;
        // Serialise against other tests that flip the global dispatch.
        let _serial = simd::force_lock();

        fn run<E: KvElem>(
            q: &[f32],
            rows: usize,
            d: usize,
            k: &[E],
            v: &[E],
            len: usize,
        ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let scale = 1.0 / (d as f32).sqrt();
            let (mut m, mut n, mut o) =
                (vec![0.0f32; rows], vec![0.0f32; rows], vec![0.0f32; rows * d]);
            let mut state = OnlineState { m: &mut m, n: &mut n, o: &mut o, head_dim: d };
            state.reset();
            let mut w = vec![0.0f32; len];
            attend_block(q, rows, d, k, v, len, scale, &mut state, &mut w);
            state.finish();
            (m, n, o)
        }

        // len = 43 leaves ragged vector tails; len = 600 exercises the
        // > BLOCK_MAX_LEN per-row fallback. rows = 21 covers two 8-blocks,
        // one 4-block and a scalar tail row.
        for &(d, len, rows) in &[(24usize, 43usize, 21usize), (64, 43, 21), (128, 43, 9), (24, 600, 13)]
        {
            let q = rand_vec(700 + d as u64 + len as u64, rows * d);
            let k = rand_vec(800 + d as u64 + len as u64, len * d);
            let v = rand_vec(900 + d as u64 + len as u64, len * d);
            let k16: Vec<F16> = k.iter().map(|&x| F16::from_f32(x)).collect();
            let v16: Vec<F16> = v.iter().map(|&x| F16::from_f32(x)).collect();
            let kb: Vec<Bf16> = k.iter().map(|&x| Bf16::from_f32(x)).collect();
            let vb: Vec<Bf16> = v.iter().map(|&x| Bf16::from_f32(x)).collect();

            simd::force(Some(simd::SimdIsa::Scalar));
            let base_f32 = run(&q, rows, d, &k, &v, len);
            let base_f16 = run(&q, rows, d, &k16, &v16, len);
            let base_bf16 = run(&q, rows, d, &kb, &vb, len);

            for isa in simd::available() {
                simd::force(Some(isa));
                assert_eq!(
                    run(&q, rows, d, &k, &v, len),
                    base_f32,
                    "{} f32 d={d} len={len}",
                    isa.label()
                );
                assert_eq!(
                    run(&q, rows, d, &k16, &v16, len),
                    base_f16,
                    "{} f16 d={d} len={len}",
                    isa.label()
                );
                assert_eq!(
                    run(&q, rows, d, &kb, &vb, len),
                    base_bf16,
                    "{} bf16 d={d} len={len}",
                    isa.label()
                );
            }
            simd::force(None);
        }
    }
}
